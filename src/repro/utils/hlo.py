"""Extract collective-communication byte counts from lowered/compiled HLO.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic, so the roofline's collective term is derived here by parsing the HLO
text: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes the byte size of its operands.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  bf16[16,4096,512]{2,1,0}   or  f32[] or  (f32[8,128], u32[8])
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
# HLO instruction line:  %name = <shape(s)> op-name(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", re.MULTILINE
)


def _shape_bytes(shape_text: str) -> int:
    """Total bytes for all array shapes appearing in ``shape_text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclass
class CollectiveStats:
    """Byte totals per collective kind plus op counts."""

    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind[k]} bytes={self.bytes_by_kind[k]:,}"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "(no collectives)"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse HLO text and sum output-shape bytes of every collective op.

    We count the *result* shape of each collective (the data that actually
    crosses links, modulo algorithm factors); `-start` variants are counted,
    matching `-done` pairs are skipped to avoid double counting.
    """
    stats = CollectiveStats()
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVE_KINDS:
            if op == c or op == c + "-start":
                kind = c
                break
            if op == c + "-done":  # counted at -start
                kind = None
                break
        if kind is None:
            continue
        b = _shape_bytes(shape_text)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def count_op(hlo_text: str, op_name: str) -> int:
    """Count instructions of a given HLO op (e.g. 'fusion', 'transpose')."""
    pat = re.compile(rf"=\s*[^=]*?\b{re.escape(op_name)}\(")
    return len(pat.findall(hlo_text))


def duplicate_fusion_ratio(hlo_text: str) -> float:
    """Crude remat indicator: ratio of dot ops to unique dot shapes.

    Remat-inserted recompute shows up as the same dot shape appearing many
    times. Ratio 1.0 = no duplication.
    """
    shapes = re.findall(r"=\s*(\S+)\s+dot\(", hlo_text)
    if not shapes:
        return 1.0
    from collections import Counter

    c = Counter(shapes)
    return len(shapes) / max(1, len(c))
