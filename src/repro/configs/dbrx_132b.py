"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained). [hf:databricks/dbrx-base]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        source="hf:databricks/dbrx-base",
        block_pattern=("attn",),
        n_experts=16,
        top_k=4,
        capacity_factor=1.25,
        activation="silu",
        gated_mlp=True,
        rope_theta=500_000.0,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("dbrx-132b", config, smoke)
