"""Model zoo: generic LM covering all assigned families + the paper's GCN."""
