"""Tests for round scheduling: SyncScheduler/AsyncScheduler parity, the
staleness-weighted aggregation, the virtual clock, the scheduler registry,
and the vectorized PaperCostModel against the original per-client loop."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    AsyncScheduler,
    BaseCallback,
    FedAvg,
    FedEngine,
    PaperCostModel,
    RoundScheduler,
    StalenessWeightedAggregator,
    SyncScheduler,
    WeightedFedAvg,
    available_schedulers,
    build_scheduler,
    method_config,
    register_scheduler,
    staleness_discount,
)
from repro.federated.costs import (
    BYTES_F32,
    CostMeter,
    VirtualClock,
    embed_sync_bytes,
    model_bytes,
    seq_sum,
)

PARITY_KEYS = ("test_acc", "test_loss", "tau", "comm_total", "comm_embed",
               "flops", "wall_clock")


# ---------------------------------------------------------------------------
# async/sync parity (the scheduler's correctness contract)
# ---------------------------------------------------------------------------

def test_async_full_quorum_matches_sync_bitwise(small_fed):
    """Zero delay heterogeneity + full quorum: every merge is one whole fresh
    cohort, so the async engine must reproduce the synchronous history
    bit-for-bit (trajectory, costs, and final snapshot)."""
    g, fed = small_fed
    mcfg = method_config("fedais", tau0=4)
    kw = dict(rounds=3, clients_per_round=3, seed=0)
    sync = FedEngine(g, fed, mcfg, **kw).run()
    asy = FedEngine(g, fed, mcfg, scheduler=AsyncScheduler(), **kw).run()
    for k in PARITY_KEYS:
        assert sync.history[k] == asy.history[k], f"history[{k!r}] diverged"
    assert sync.final == asy.final
    # async extras exist and report an all-fresh run
    assert asy.history["staleness_max"] == [0, 0, 0]
    assert asy.history["merged"] == [3, 3, 3]
    # the virtual clock reproduces the (cumulative) lockstep wall-clock meter
    assert asy.history["virtual_time"] == sync.history["wall_clock"]


def test_async_heterogeneous_delays_overlap(small_fed):
    """Partial quorum + heterogeneous client speeds: stragglers merge late
    (staleness > 0) and the overlapped wall-clock beats lockstep billing."""
    g, fed = small_fed
    mcfg = method_config("fedais", tau0=4)
    kw = dict(rounds=3, clients_per_round=3, seed=0)
    rng = np.random.default_rng(0)
    factors = np.exp(rng.normal(0.0, 0.8, fed.n_clients))
    sync = FedEngine(g, fed, mcfg, **kw).run()
    het = FedEngine(g, fed, mcfg, **kw,
                    scheduler=AsyncScheduler(quorum=2, speed_factors=factors)).run()
    assert max(het.history["staleness_max"]) >= 1
    assert het.history["merged"] == [2, 2, 2]
    assert het.history["wall_clock"][-1] < sync.history["wall_clock"][-1]
    # virtual clock is monotone and matches the cumulative wall-clock meter
    assert het.history["virtual_time"] == het.history["wall_clock"]
    assert all(np.isfinite(het.history["test_loss"]))


def test_async_scheduler_via_method_config_and_registry(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais", scheduler="async"), rounds=1)
    assert isinstance(eng.scheduler, AsyncScheduler)
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, scheduler="sync")
    assert isinstance(eng.scheduler, SyncScheduler)
    with pytest.raises(KeyError, match="unknown scheduler"):
        FedEngine(g, fed, method_config("fedais"), rounds=1, scheduler="bogus")


def test_scheduler_registry():
    assert set(available_schedulers()) >= {"sync", "async"}
    assert isinstance(build_scheduler("sync"), SyncScheduler)
    sched = build_scheduler("async", quorum=4)
    assert isinstance(sched, AsyncScheduler) and sched.quorum == 4
    assert isinstance(build_scheduler("async"), RoundScheduler)
    with pytest.raises(KeyError, match="already registered"):
        register_scheduler("sync", SyncScheduler)


def test_async_scheduler_validation(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, clients_per_round=3)
    state = eng.init_state()
    with pytest.raises(ValueError, match="quorum"):
        AsyncScheduler(quorum=5).run(eng, state)
    with pytest.raises(ValueError, match="speed_factors"):
        AsyncScheduler(speed_factors=np.ones(3)).run(eng, state)


def test_async_scheduler_rejects_conflicting_staleness_config(small_fed):
    """Scheduler staleness knobs only parameterize its default wrapper; with
    an explicitly staleness-aware engine aggregator they must fail fast, not
    be silently discarded."""
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais", aggregator="staleness"),
                    rounds=1, clients_per_round=3)
    state = eng.init_state()
    with pytest.raises(ValueError, match="already a StalenessWeightedAggregator"):
        AsyncScheduler(staleness_mode="exp", staleness_a=1.0).run(eng, state)
    # default knobs defer to the aggregator's own configuration: runs fine
    res = FedEngine(g, fed, method_config("fedais", aggregator="staleness"),
                    rounds=1, clients_per_round=3, seed=0,
                    scheduler=AsyncScheduler()).run()
    assert np.isfinite(res.final["loss"])


def test_async_rounds_zero_is_noop(small_fed):
    """rounds=0 must not burn (or even dispatch) a cohort — SyncScheduler is
    a no-op there and the async engine must match, RNG state included."""
    g, fed = small_fed
    kw = dict(rounds=0, clients_per_round=3, seed=0)
    sync = FedEngine(g, fed, method_config("fedais"), **kw).run()
    asy = FedEngine(g, fed, method_config("fedais"), **kw,
                    scheduler=AsyncScheduler()).run()
    assert asy.history == {} == sync.history
    assert asy.final == sync.final
    assert asy.final["comm_total_bytes"] == 0.0


def test_async_bills_unmerged_dispatches(small_fed):
    """Every dispatched update's comm/compute is billed even if the run ends
    before it merges; only merged updates appear in the history rows."""
    g, fed = small_fed
    res = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3, seed=0,
                    scheduler=AsyncScheduler(quorum=2)).run()
    eng = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3)
    from repro.federated.costs import model_bytes

    # dispatched: 3 (initial) + 2 (after merge 1) = 5; merged: 2 + 2 = 4
    assert res.final["comm_model_bytes"] == 5 * 2 * model_bytes(eng.n_params)
    assert res.history["comm_total"][-1] < res.final["comm_total_bytes"]
    assert res.history["merged"] == [2, 2]


# ---------------------------------------------------------------------------
# staleness weighting
# ---------------------------------------------------------------------------

def test_staleness_discount_modes():
    s = np.asarray([0, 1, 3])
    np.testing.assert_allclose(staleness_discount(s, mode="poly", a=0.5),
                               [1.0, 2 ** -0.5, 0.5])
    np.testing.assert_allclose(staleness_discount(s, mode="exp", a=1.0),
                               np.exp([-0.0, -1.0, -3.0]))
    np.testing.assert_allclose(staleness_discount(s, mode="const"), [1, 1, 1])
    with pytest.raises(ValueError, match="staleness mode"):
        staleness_discount(s, mode="nope")


def test_staleness_aggregator_fresh_delegates_to_base():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    agg = StalenessWeightedAggregator(base=FedAvg())
    out = agg.aggregate(stacked, None, np.asarray([0, 0]))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(FedAvg().aggregate(stacked)["w"]))
    # no staleness argument at all behaves like the base too
    np.testing.assert_array_equal(np.asarray(agg.aggregate(stacked)["w"]), [5.0])


def test_staleness_aggregator_discounts_stale_updates():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    agg = StalenessWeightedAggregator(base=FedAvg(), mode="poly", a=1.0)
    # second update has staleness 3 -> weight 1/4; mean = 10*(0.25/1.25) = 2.0
    out = agg.aggregate(stacked, None, np.asarray([0, 3]))
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0], rtol=1e-6)


def test_staleness_aggregator_rejects_undeclared_custom_base():
    """A base without `uses_weights` (median, trimmed mean, ...) cannot be
    silently replaced by a weighted mean on stale merges — fresh merges
    delegate, stale ones fail fast."""
    class Median:
        def aggregate(self, stacked_params, weights=None):
            return {"w": jnp.median(stacked_params["w"], axis=0)}

    stacked = {"w": jnp.asarray([[0.0], [10.0], [20.0]])}
    agg = StalenessWeightedAggregator(base=Median())
    np.testing.assert_array_equal(
        np.asarray(agg.aggregate(stacked, None, np.asarray([0, 0, 0]))["w"]),
        [10.0])   # all fresh: the base rule applies
    with pytest.raises(TypeError, match="uses_weights"):
        agg.aggregate(stacked, None, np.asarray([0, 2, 0]))


def test_staleness_aggregator_composes_with_base_weights():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    agg = StalenessWeightedAggregator(base=WeightedFedAvg(), mode="poly", a=1.0)
    # base weights (1, 3), staleness (0, 1) -> effective (1, 1.5)
    out = agg.aggregate(stacked, jnp.asarray([1.0, 3.0]), np.asarray([0, 1]))
    np.testing.assert_allclose(np.asarray(out["w"]), [6.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock_no_overlap_bills_like_sync():
    clock = VirtualClock()
    # dispatched exactly at now: billed time is client_time + overhead, the
    # synchronous formula, with no float round-trip through absolute times
    assert clock.merge_elapsed(0.0, 0.125, 0.25) == 0.125 + 0.25
    assert clock.now == 0.375
    assert clock.merge_elapsed(clock.now, 0.5, 0.1) == 0.5 + 0.1


def test_virtual_clock_buffered_arrival_bills_overhead_only():
    clock = VirtualClock(now=10.0)
    # the quorum-completing update arrived before the previous merge ended
    assert clock.merge_elapsed(8.0, 1.0, 0.25) == 0.25
    assert clock.now == 10.25


# ---------------------------------------------------------------------------
# vectorized PaperCostModel vs the original O(m) per-client loop
# ---------------------------------------------------------------------------

def _loop_round_cost(model, engine, state, sel, stats):
    """The pre-vectorization PaperCostModel.round_cost, verbatim."""
    fed, mcfg = engine.fed, engine.mcfg
    cost = CostMeter()
    n_sync = np.asarray(stats["n_sync"])
    n_pulled = np.asarray(stats["n_ghost_pulled"])
    sizes = fed.client_sizes[sel]
    extra_bytes = engine.strategy.round_model_bytes(engine)
    per_client_compute = []
    for i, _k in enumerate(sel):
        comm_model = 2 * model_bytes(engine.n_params) + extra_bytes
        comm_embed = embed_sync_bytes(n_pulled[i], (engine.F, engine.H1))
        nodes_processed = sizes[i] + mcfg.local_epochs * min(
            engine.bsz, max(int(sizes[i]), 1))
        flops = 3.0 * engine.fwd_flops_node * nodes_processed \
            + engine.strategy.extra_flops(engine, sizes[i])
        cost.comm_model_bytes += comm_model
        cost.comm_embed_bytes += comm_embed
        cost.compute_flops += flops
        per_client_compute.append(model.delay.compute_time(flops))
    o = model.delay.comm_time(
        cost.comm_embed_bytes / max(len(sel), 1)
        + 2 * model_bytes(engine.n_params))
    cost.wall_clock_s = max(per_client_compute) + o / max(state.tau, 1)
    cost.sync_events = int(n_sync.sum())
    return cost


class _RecordingCostModel(PaperCostModel):
    def __init__(self):
        super().__init__()
        self.calls = []

    def round_cost(self, engine, state, sel, stats):
        cost = super().round_cost(engine, state, sel, stats)
        self.calls.append((np.asarray(sel).copy(), stats, state.tau, cost))
        return cost


@pytest.mark.parametrize("method", ["fedais", "fedsage+"])
def test_vectorized_round_cost_matches_loop_exactly(small_fed, method):
    """The numpy-vectorized meter must equal the per-client Python loop
    bit-for-bit on real engine traffic (incl. the generator's extra costs)."""
    g, fed = small_fed
    model = _RecordingCostModel()
    eng = FedEngine(g, fed, method_config(method, tau0=2), rounds=2,
                    clients_per_round=4, seed=0, cost_model=model)
    eng.run()
    state = eng.init_state()   # only .tau is read by the cost model
    assert model.calls
    for sel, stats, tau, vec_cost in model.calls:
        state.tau = tau
        ref = _loop_round_cost(model, eng, state, sel, stats)
        assert vec_cost.comm_model_bytes == ref.comm_model_bytes
        assert vec_cost.comm_embed_bytes == ref.comm_embed_bytes
        assert vec_cost.compute_flops == ref.compute_flops
        assert vec_cost.wall_clock_s == ref.wall_clock_s
        assert vec_cost.sync_events == ref.sync_events


def test_seq_sum_matches_python_accumulation():
    rng = np.random.default_rng(3)
    x = rng.standard_normal(1000) * rng.uniform(1, 1e12, 1000)
    acc = 0.0
    for v in x:
        acc += v
    assert seq_sum(x) == acc
    assert seq_sum([]) == 0.0
    assert seq_sum(np.full(7, 0.1)) * BYTES_F32 == (0.1 + 0.1 + 0.1 + 0.1
                                                    + 0.1 + 0.1 + 0.1) * 4


# ---------------------------------------------------------------------------
# final-eval reuse (no duplicate server eval on the last round)
# ---------------------------------------------------------------------------

def test_run_reuses_last_round_eval(small_fed, monkeypatch):
    import repro.api.engine as engine_mod

    g, fed = small_fed
    calls = {"n": 0}
    real = engine_mod.evaluate_global

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(engine_mod, "evaluate_global", counting)
    # default stack: EvalCallback scores the last round; run() must not re-eval
    res = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3, seed=0).run()
    assert calls["n"] == 0          # callback evals route through callbacks.py
    assert res.final["acc"] == res.history["test_acc"][-1]
    assert res.final["loss"] == res.history["test_loss"][-1]

    # a stack without EvalCallback leaves no cached eval: run() evaluates
    calls["n"] = 0
    res2 = FedEngine(g, fed, method_config("fedais"), rounds=1,
                     clients_per_round=3, seed=0,
                     callbacks=[BaseCallback()]).run()
    assert calls["n"] == 1
    assert np.isfinite(res2.final["loss"])


def test_async_history_extras_absent_under_sync(small_fed):
    g, fed = small_fed
    res = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    clients_per_round=3, seed=0).run()
    assert "staleness_max" not in res.history
    assert "virtual_time" not in res.history


# ---------------------------------------------------------------------------
# BanditStrategy reward attribution under async partial quorums
# ---------------------------------------------------------------------------

class _RecordingBandit:
    """Stands in for FanoutBandit: records (client, reward) update calls."""

    def __init__(self):
        self.updates = []

    def choose(self, k):
        return 10

    def update(self, k, reward):
        self.updates.append((int(k), float(reward)))


def _bandit_harness(n_clients=3):
    from types import SimpleNamespace

    from repro.api.strategies import BanditStrategy

    eng = SimpleNamespace(fed=SimpleNamespace(n_clients=n_clients), seed=0)
    strat = BanditStrategy(method_config("fedgraph"))
    state = SimpleNamespace(round=0, last_staleness=None)
    strat.setup(eng, state)
    strat.bandit = _RecordingBandit()
    return eng, strat, state


def _stats(losses):
    # BanditStrategy reads epoch_losses means; one epoch keeps it literal
    return {"epoch_losses": np.asarray(losses, np.float64).reshape(-1, 1)}


def test_bandit_duplicate_in_flight_rewards_oldest_to_freshest():
    """A client selected twice while in flight merges both updates in one
    buffer, restacked by dispatch version. Rewards must telescope oldest ->
    freshest — the reward stream a sequential run would have produced — and
    the strategy's last-seen loss must end at the FRESHEST update, matching
    the engine write-back's dedup-keeps-freshest rule."""
    eng, strat, state = _bandit_harness()
    state.round, state.last_staleness = 0, None
    strat.post_round(eng, state, np.array([0]), _stats([1.0]))

    # merge at version 2: two in-flight updates from client 0 (dispatched at
    # versions 1 and 2), already sorted by dispatch version by the scheduler
    state.round, state.last_staleness = 2, np.array([1, 0])
    strat.post_round(eng, state, np.array([0, 0]), _stats([0.9, 0.8]))
    state.last_staleness = None
    assert strat.bandit.updates == [
        (0, 0.0),                        # first observation: no baseline
        (0, pytest.approx(1.0 - 0.9)),   # v1 vs the v0 loss
        (0, pytest.approx(0.9 - 0.8)),   # v2 vs the v1 loss
    ]
    assert strat.last_client_loss[0] == pytest.approx(0.8)
    assert strat.last_reward_version[0] == 2


def test_bandit_skips_out_of_order_straggler_reward():
    """A straggler can merge AFTER a fresher update from the same client
    (partial quorums reorder arrivals across merges). Its loss predates the
    strategy's baseline, so rewarding it would credit the fanout arm with
    an inverted improvement — the audit pins that it is skipped and the
    baseline keeps the freshest loss."""
    eng, strat, state = _bandit_harness()
    # version-1 update merges first (fresh)
    state.round, state.last_staleness = 1, np.array([0])
    strat.post_round(eng, state, np.array([0]), _stats([0.5]))
    n_updates = len(strat.bandit.updates)

    # the version-0 straggler (staleness 2) arrives one merge later with the
    # worse loss it computed before the fresh update existed
    state.round, state.last_staleness = 2, np.array([2])
    strat.post_round(eng, state, np.array([0]), _stats([1.4]))
    assert len(strat.bandit.updates) == n_updates     # no reward recorded
    assert strat.last_client_loss[0] == pytest.approx(0.5)
    assert strat.last_reward_version[0] == 1

    # a later in-order update resumes rewarding against the kept baseline
    state.round, state.last_staleness = 3, np.array([0])
    strat.post_round(eng, state, np.array([0]), _stats([0.3]))
    assert strat.bandit.updates[-1] == (0, pytest.approx(0.5 - 0.3))


def test_bandit_async_engine_run_with_duplicates(small_fed):
    """End-to-end: fedgraph under a partial quorum with re-selection while
    in flight completes and keeps per-client reward versions monotone."""
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedgraph"), rounds=4,
                    clients_per_round=3, seed=0,
                    scheduler=AsyncScheduler(quorum=2, concurrency=4))
    res = eng.run()
    assert np.isfinite(res.final["loss"])
    assert (eng.strategy.last_reward_version >= -1).all()
