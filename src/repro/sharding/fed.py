"""Client-axis sharding for the federated engine (and its dry-run).

The fused executor (FedEngine._build_fused_chunk) vmaps LocalUpdate over
the m selected clients of each round. On a multi-device mesh that cohort
axis is the natural unit of scale-out: every device trains m/D of the
cohort against replicated global state, server aggregation lowers to a
weighted all-reduce (``jax.lax.psum`` inside the shard-mapped body —
exactly WeightedFedAvg's sum(w*x)/sum(w), plain FedAvg when the weights
are uniform), and the historical/ghost write-back all-gathers the
cohort's fresh embeddings across devices — the embedding-synchronization
network phase of the real deployment.

``build_sharded_chunk`` is the sharded twin of the engine's fused chunk:
the same scanned ``round_step`` signature (plus an explicit per-client
weight stack), with the client half wrapped in ``shard_map`` over a
``("clients",)`` mesh axis. ``launch/fed_dryrun.py`` lowers exactly this
chunk on the production chip counts to report its collectives;
``tests/test_sharding.py`` pins it allclose to the unsharded fused
executor on a forced multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Ragged cohorts (m not divisible by the mesh axis) are padded with dummy
clients built from three no-op guarantees:

* client id ``n_clients`` is out of range — JAX clamps out-of-bounds
  *gathers* (the dummy trains on a real client's data, harmlessly) and
  DROPS out-of-bounds *scatters* (the dummy's hist/ghost/prev_loss
  write-back never lands);
* aggregation weight 0 — the weighted psum ignores the dummy's params;
* the PRNG chain splits for the REAL cohort only (dummies get a zero
  key), so padded runs stay on the exact key trajectory of the
  unsharded executor.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.federated.quant import check_sync_dtype, quant_roundtrip

CLIENT_AXIS = "clients"


def make_client_mesh(n_devices: Optional[int] = None, *,
                     axis: str = CLIENT_AXIS) -> Mesh:
    """A flat ``(n_devices,)`` mesh with one client-sharding axis. On CPU,
    force fake devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (before the JAX backend initializes)."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_client_mesh needs 1..{len(devs)} devices, asked for {n} "
            "(force more with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


def client_axis_of(mesh: Mesh) -> Optional[str]:
    """The mesh axis the client cohort shards over: ``"clients"`` if
    present, else the sole axis of a 1-axis mesh, else None."""
    if CLIENT_AXIS in mesh.shape:
        return CLIENT_AXIS
    if len(mesh.shape) == 1:
        return next(iter(mesh.shape))
    return None


def cohort_padding(m: int, n_shards: int) -> int:
    """Dummy clients appended so the cohort splits evenly across shards."""
    return (-m) % n_shards


def replicate_to_mesh(tree, mesh: Mesh):
    """Commit every leaf to the mesh fully replicated (a no-op for leaves
    already there) so jit donation can update buffers in place from the
    first sharded chunk onward."""
    sh = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


def pairwise_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic fp32 binary-tree reduction over the leading axis:
    pairs sum left-to-right level by level, so the association order is
    fixed by the leading-axis length alone (never by how XLA schedules an
    all-reduce). Used by ``reduce="pairwise"`` merges on both the 1-D
    client mesh and the 2-D pod mesh (repro.sharding.tables)."""
    while x.shape[0] > 1:
        n = x.shape[0]
        even = (n // 2) * 2
        y = x[0:even:2] + x[1:even:2]
        if n % 2:
            y = jnp.concatenate([y, x[even:]], axis=0)
        x = y
    return x[0]


def weighted_merge(axes, w, reduce: str):
    """The sharded executors' aggregation rule: sum(w·x)/sum(w) across the
    mesh ``axes`` — a weighted psum all-reduce (``reduce="psum"``) or a
    deterministic fp32 binary tree over all-gathered per-device partial
    sums (``reduce="pairwise"``). Returns the per-leaf merge function
    ``wmean(x, old)``: when every weight is zero (a round where the whole
    cohort dropped out under a FaultPlan — never a healthy run, where
    padding always leaves real positive weights) the merge degrades to
    the carried ``old`` leaf instead of dividing 0/0 into NaN params.
    With any surviving weight the guard is exact: ``max(wsum, tiny)``
    equals ``wsum`` and the ``where`` passes the quotient through
    bit-unchanged."""
    if reduce == "psum":
        wsum = jax.lax.psum(w.sum(), axes)

        def wmean(x, old):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            num = jax.lax.psum((x * wb).sum(axis=0), axes)
            return jnp.where(wsum > 0.0, num / jnp.maximum(wsum, 1e-12), old)
    else:   # "pairwise": association fixed by device count, not by XLA
        wsum = pairwise_sum(jax.lax.all_gather(w.sum(), axes))

        def wmean(x, old):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            num = pairwise_sum(
                jax.lax.all_gather((x * wb).sum(axis=0), axes, axis=0))
            return jnp.where(wsum > 0.0, num / jnp.maximum(wsum, 1e-12), old)
    return wmean


def _client_step(vm, mesh: Mesh, axis: str, reduce: str):
    """The per-round client half, shard-mapped over the cohort axis:
    vmapped LocalUpdate on each device's cohort shard + weighted merge
    (all-reduce, or the deterministic pairwise tree). Per-client outputs
    stay sharded on their leading axis (out_specs P(axis)); the aggregated
    params come back replicated."""

    def step(params, client, feats_all, hist1_all, h1s, ages, gfs, pls,
             tau, fanouts, eoff, keys, w):
        out = vm(params, client, feats_all, hist1_all, h1s, ages, gfs, pls,
                 tau, fanouts, eoff, keys)
        new_params, new_hist1, new_age, new_ghost, stats = out
        wmean = weighted_merge(axis, w, reduce)
        agg = jax.tree_util.tree_map(wmean, new_params, params)
        return agg, new_hist1, new_age, new_ghost, stats

    c, r = P(axis), P()
    return shard_map(
        step, mesh=mesh,
        in_specs=(r, c, r, r, c, c, c, c, r, c, r, c, c),
        out_specs=(r, c, c, c, c),
        check_rep=False)


def build_sharded_chunk(vm, mesh: Mesh, axis: str, m_real: int,
                        light_stats: Sequence[str], *,
                        reduce: str = "psum",
                        sync_dtype: str = "fp32"):
    """The sharded twin of FedEngine._build_fused_chunk: one jitted donated
    chunk scanning ``round_step`` over S rounds, with the vmapped client
    half shard-mapped over ``axis``.

    Same argument order as the unsharded chunk plus ``w_stack`` (S, m_pad)
    — per-client aggregation weights with zeros on padding — between
    ``fan_stack`` and ``eoffs``. ``sel_stack``/``fan_stack`` arrive padded
    to a multiple of the mesh axis; ``m_real`` is the true cohort size
    (static), which fixes the PRNG split count and the slice of per-round
    stats streamed back to the host tail. ``reduce`` picks the merge:
    ``"psum"`` (weighted all-reduce) or ``"pairwise"`` (fp32 fixed tree
    over gathered partials — the same ``merge_reduce`` knob the pod mesh
    honors, so 1-D meshes no longer silently fall back to psum).
    ``sync_dtype`` round-trips the written-back float rows through the
    repro.federated.quant codec (the write-back IS a wire in the real
    deployment); ``"fp32"`` adds zero trace ops.
    """
    if reduce not in ("psum", "pairwise"):
        raise ValueError(f"unknown reduce {reduce!r}; known: psum | pairwise")
    check_sync_dtype(sync_dtype)
    step = _client_step(vm, mesh, axis, reduce)
    light_stats = tuple(light_stats)

    def chunk(params, hist1, age, ghost_feat, prev_loss, key, arrays,
              sel_stack, fan_stack, w_stack, eoffs, tau):
        m_pad = sel_stack.shape[1]
        pad = m_pad - m_real

        def round_step(carry, xs):
            params, hist1, age, ghost_feat, prev_loss, key = carry
            sel, fanouts, w, eoff = xs
            # the unsharded executor's exact key chain: split for the real
            # cohort only, dummies ride along on a constant zero key
            ks = jax.random.split(key, m_real + 1)
            key, keys = ks[0], ks[1:]
            if pad:
                keys = jnp.concatenate(
                    [keys, jnp.zeros((pad,) + keys.shape[1:], keys.dtype)])
            client = {k: v[sel] for k, v in arrays.items()}
            out = step(params, client, arrays["features"], hist1,
                       hist1[sel], age[sel], ghost_feat[sel], prev_loss[sel],
                       tau, fanouts, eoff, keys, w)
            params, new_hist1, new_age, new_ghost_feat, stats = out
            loss_wb = stats["loss_all"]
            if sync_dtype != "fp32":
                new_hist1 = quant_roundtrip(new_hist1, sync_dtype)
                new_ghost_feat = quant_roundtrip(new_ghost_feat, sync_dtype)
                loss_wb = quant_roundtrip(loss_wb, sync_dtype)
            # out-of-range padding ids make these scatters drop, never land
            hist1 = hist1.at[sel].set(new_hist1)
            age = age.at[sel].set(new_age)
            ghost_feat = ghost_feat.at[sel].set(new_ghost_feat)
            prev_loss = prev_loss.at[sel].set(loss_wb)
            light = {k: stats[k][:m_real] for k in light_stats}
            return (params, hist1, age, ghost_feat, prev_loss, key), light

        return jax.lax.scan(round_step,
                            (params, hist1, age, ghost_feat, prev_loss, key),
                            (sel_stack, fan_stack, w_stack, eoffs))

    return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4, 5))


def abstract_chunk_args(mesh: Mesh, *, n_clients: int, cohort: int,
                        n_max: int, g_max: int, n_feat: int, n_classes: int,
                        max_deg: int = 16, rounds: int = 1):
    """ShapeDtypeStructs (with replicated NamedShardings) matching
    ``build_sharded_chunk``'s signature, for lowering the chunk without
    real data — the dry-run path. ``cohort`` is the padded cohort size the
    chunk receives (a multiple of the mesh's client axis)."""
    from repro.models.gcn import HIDDEN, gcn_init

    r = NamedSharding(mesh, P())

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=r)

    params = jax.eval_shape(
        lambda: gcn_init(jax.random.PRNGKey(0), n_feat, n_classes))
    params = jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=r),
        params)
    K, n_tot = n_clients, n_max + g_max
    arrays = {
        "features": sds((K, n_max, n_feat), jnp.float32),
        "labels": sds((K, n_max), jnp.int32),
        "node_mask": sds((K, n_max), jnp.float32),
        "train_mask": sds((K, n_max), jnp.float32),
        "nbr_idx": sds((K, n_max, max_deg), jnp.int32),
        "nbr_mask": sds((K, n_max, max_deg), jnp.float32),
        "ghost_owner": sds((K, g_max), jnp.int32),
        "ghost_row": sds((K, g_max), jnp.int32),
        "ghost_mask": sds((K, g_max), jnp.float32),
    }
    return (
        params,
        sds((K, n_tot, HIDDEN[0]), jnp.float32),   # hist1
        sds((K, n_tot), jnp.int32),                # age
        sds((K, g_max, n_feat), jnp.float32),      # ghost features
        sds((K, n_max), jnp.float32),              # prev loss
        sds((2,), jnp.uint32),                     # PRNG key chain head
        arrays,
        sds((rounds, cohort), jnp.int32),          # sel_stack
        sds((rounds, cohort), jnp.int32),          # fan_stack
        sds((rounds, cohort), jnp.float32),        # w_stack
        sds((rounds,), jnp.int32),                 # eoffs
        sds((), jnp.int32),                        # tau
    )
