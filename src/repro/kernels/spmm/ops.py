"""Public wrapper for the block-sparse SpMM kernel.

``block_spmm(a, x)`` pads to tile multiples, computes the block mask on the
fly (inside jit — a cheap max-reduce per tile), runs the Pallas kernel and
slices the padding off. ``neighbor_mean`` expresses the paper's padded
neighbor-list aggregation as an SpMM against a normalised adjacency built
from (idx, mask) — the form the FedGCN layer uses.

``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.spmm.spmm import spmm_pallas


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "block_d", "interpret"))
def block_spmm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_n: int = 128,
    block_m: int = 128,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = A @ X via the block-skipping Pallas kernel. a (N, M), x (M, D)."""
    interpret = resolve_interpret(interpret)
    N, D = a.shape[0], x.shape[1]
    ap = _pad_to(a, block_n, block_m)
    xp = _pad_to(x, block_m, block_d)
    nb_n, nb_m = ap.shape[0] // block_n, ap.shape[1] // block_m
    tiles = ap.reshape(nb_n, block_n, nb_m, block_m)
    mask = (jnp.abs(tiles).max(axis=(1, 3)) > 0).astype(jnp.int32)   # (nb_n, nb_m)
    y = spmm_pallas(
        ap, xp, mask,
        block_n=block_n, block_m=block_m, block_d=block_d, interpret=interpret,
    )
    return y[:N, :D]


def adjacency_from_neighbors(nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray, m: int) -> jnp.ndarray:
    """Dense row-normalised adjacency (N, m) from a padded neighbor list."""
    N, K = nbr_idx.shape
    deg = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    w = nbr_mask / deg                                               # (N, K)
    a = jnp.zeros((N, m), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(N)[:, None], (N, K))
    return a.at[rows, nbr_idx].add(w)


def neighbor_mean(
    features: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray, *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Mean-aggregate neighbor features via the SpMM kernel."""
    a = adjacency_from_neighbors(nbr_idx, nbr_mask, features.shape[0])
    return block_spmm(a, features, interpret=interpret).astype(features.dtype)
