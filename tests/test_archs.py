"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward + one train step + one decode step on CPU; shapes + no NaNs.
Plus prefill/decode consistency and chunked-attention parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import lm
from repro.optim import adamw_init
from repro.optim.schedules import constant

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.n_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype) * 0.02
    if cfg.n_encoder_layers:
        batch["enc_frames"] = jax.random.normal(
            ks[3], (B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype) * 0.02
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {
        "gemma3-12b", "dbrx-132b", "deepseek-67b", "nemotron-4-15b",
        "llama3-405b", "arctic-480b", "whisper-large-v3", "rwkv6-1.6b",
        "recurrentgemma-2b", "internvl2-2b",
    }
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_dims(arch):
    """Full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected
    # layer pattern covers n_layers exactly
    assert cfg.n_units * len(cfg.block_pattern) + len(cfg.remainder_pattern) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    batch = _batch(cfg, key)
    B, S = batch["tokens"].shape

    logits, aux = lm.lm_forward(params, cfg, batch["tokens"],
                                image_embeds=batch.get("image_embeds"),
                                enc_frames=batch.get("enc_frames"))
    total = S + (cfg.n_image_tokens or 0)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = jax.jit(lm.make_train_step(cfg, constant(1e-3)))
    p2, o2, metrics = step(params, adamw_init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_loss(arch, key):
    """A few steps on a repeated batch must reduce the loss (learnable)."""
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    batch = _batch(cfg, key)
    step = jax.jit(lm.make_train_step(cfg, constant(3e-3)))
    opt = adamw_init(params)
    first = None
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    B, max_len = 2, 32
    if cfg.n_encoder_layers:
        enc_out = jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype) * 0.02
        state = lm.init_decode_state(params, cfg, B, max_len, enc_out=enc_out)
    else:
        state = lm.init_decode_state(params, cfg, B, max_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = lm.decode_step(params, cfg, state, tok, jnp.asarray(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma3-12b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "whisper-large-v3",
                                  "internvl2-2b", "dbrx-132b"])
def test_prefill_decode_consistency(arch, key):
    """prefill(S) + decode(token S) == full forward over S+1 tokens."""
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.n_image_tokens:
        kw["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype) * 0.02
    if cfg.n_encoder_layers:
        kw["enc_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype) * 0.02
    logits_full, _ = lm.lm_forward(params, cfg, tokens, **kw)
    gt = logits_full[:, -1]
    _, state = lm.lm_prefill(params, cfg, tokens[:, :S], max_len=32, **kw)
    P = cfg.n_image_tokens or 0
    dec, _ = lm.decode_step(params, cfg, state, tokens[:, S:S + 1], jnp.asarray(P + S))
    scale = float(jnp.max(jnp.abs(gt))) + 1e-6
    err = float(jnp.max(jnp.abs(gt - dec[:, 0])))
    assert err < 2e-2 * max(scale, 1.0), f"prefill/decode mismatch: {err} vs scale {scale}"


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma3-12b"])
def test_chunked_attention_parity(arch, key):
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 13), 0, cfg.vocab_size)
    full, _ = lm.lm_forward(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk_size=4)
    chunked, _ = lm.lm_forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(chunked, np.float32),
                               np.asarray(full, np.float32), atol=2e-5)


def test_scan_vs_unrolled_parity(key):
    """scan-over-layers and python-unrolled layers are numerically identical."""
    cfg = get_smoke_config("gemma3-12b")
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    a, _ = lm.lm_forward(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    b, _ = lm.lm_forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6)


def test_param_count_analytic_close(key):
    """Analytic param_count matches the real tree within 3%."""
    from repro.utils.tree import tree_count_params
    for arch in ["deepseek-67b", "rwkv6-1.6b", "recurrentgemma-2b", "dbrx-132b"]:
        cfg = get_smoke_config(arch)
        params = lm.init_lm(key, cfg)
        real = tree_count_params(params)
        analytic = cfg.param_count()
        assert abs(real - analytic) / real < 0.03, (arch, real, analytic)


def test_full_config_param_counts_sane():
    """Full-config analytic parameter counts land near the advertised sizes."""
    expect = {
        "llama3-405b": (380e9, 440e9),
        "dbrx-132b": (110e9, 150e9),
        "deepseek-67b": (60e9, 75e9),
        "arctic-480b": (380e9, 520e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"
