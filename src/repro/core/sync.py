"""Adaptive embedding synchronization (paper Eq. 9-11 + the delay model).

Theorem 2 bounds the expected min squared gradient norm after runtime
c_total by  2(F0 - Finf)/(eta c_total) * (c + o/tau) + eta^2 lam^2 zeta^2 (tau-1):
larger tau amortises communication o but adds staleness noise. Minimising
over tau gives Eq. (10); the practical parameter-free rule (Eq. 11) tracks
sqrt(F_t / F_0):

    tau_t = ceil( sqrt(F(theta_t) / F(theta_0)) * tau_0 )

so synchronization becomes *more frequent as the loss decays* — exactly the
schedule the convergence condition (Thm. 3 / Eq. 12) wants.
"""
from __future__ import annotations

import math


def tau_theoretical(
    f_t: float, f_inf: float, o: float, eta: float, c_total: float,
    lam: float, zeta2: float,
) -> float:
    """Eq. (10): optimal tau from the error bound (needs lam, zeta)."""
    denom = eta ** 3 * c_total * lam ** 2 * zeta2
    if denom <= 0:
        return 1.0
    return math.sqrt(max(0.0, 2.0 * (f_t - f_inf) * o) / denom)


def adaptive_tau(f_t: float, f_0: float, tau0: int, *, tau_min: int = 1, tau_max: int = 64) -> int:
    """Eq. (11): the practical parameter-free rule (F_inf approximated by 0)."""
    if f_0 <= 0.0 or not math.isfinite(f_t) or not math.isfinite(f_0):
        return tau0
    tau = math.ceil(math.sqrt(max(f_t, 0.0) / f_0) * tau0)
    return max(tau_min, min(tau_max, tau))


def error_bound(f0: float, f_inf: float, eta: float, lam: float, zeta2: float,
                c: float, o: float, tau: float, c_total: float) -> float:
    """The Theorem-2 bound itself (Eq. 9) — used by tests to verify Eq. (10)
    actually minimises it, and by the benchmark that plots the trade-off."""
    term1 = 2.0 * (f0 - f_inf) / (eta * c_total) * (c + o / tau)
    term2 = eta ** 2 * lam ** 2 * zeta2 * (tau - 1.0)
    return term1 + term2


def delay_model(c_epoch: list[float] | tuple, o: float, tau: float) -> dict:
    """Paper's runtime model: full sync c_syn = max_k c_k + o; periodic
    c_avg = max_k mean(c_k) + o / tau."""
    c_syn = max(c_epoch) + o
    c_avg = max(c_epoch) + o / max(tau, 1.0)
    return {"c_syn": c_syn, "c_avg": c_avg, "speedup": c_syn / max(c_avg, 1e-12)}
