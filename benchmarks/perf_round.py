"""Round-throughput benchmark: fused scanned executor vs stepwise loop.

The figure of merit is training-round throughput (rounds/s) of the
SyncScheduler hot path — the number every selector/method sweep pays per
grid point. The fused executor runs every round between eval boundaries as
one donated ``lax.scan`` XLA call; the stepwise loop pays per-round
dispatch, eager aggregation/write-back copies of the (K, n_tot, H1) tables,
and a host sync for cost accounting. The eval-side hot spot (full-graph
forward, O(N*K*F) per eval) is timed per aggregation backend alongside.

Writes ``BENCH_round.json`` at the repo root (the perf trajectory seed) and
``benchmarks/results/perf_round.json``. Exits non-zero from the CLI if the
fused executor is not faster than stepwise — the CI perf-smoke gate.

    PYTHONPATH=src python -m benchmarks.perf_round --quick
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit_csv, fed_setup, save_rows

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time_run(make_engine, repeats: int = 3) -> float:
    """Median wall-clock of a full engine.run() after compile warmups."""
    eng = make_engine()
    eng.run()                                   # warmup 1: compiles
    eng.run()                                   # warmup 2: allocator settles
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.run()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def run(quick: bool = True) -> list[dict]:
    from repro.api import FedEngine, SyncScheduler, method_config
    from repro.federated.server import build_eval_graph, evaluate_global
    from repro.models.gcn import AGG_BACKENDS, gcn_init

    # Cross-device regime: many clients, small sampled cohort. The stepwise
    # loop's per-round cost is dominated by the eager full-table copies
    # (hist1/age/ghost_feat scale with K, not with the cohort), which is
    # exactly what the donated scanned executor eliminates.
    ds = "pubmed"
    scale = 16 if quick else 8
    n_clients = 256
    m = 4 if quick else 8
    rounds = 20 if quick else 40
    g, fed = fed_setup(ds, scale, n_clients, "0.5")
    mcfg = method_config("fedais", tau0=4)

    # eval only at the scan boundaries (round 0 + last): both variants pay
    # the same two server evals, so the delta is pure round-loop overhead
    def make(fused):
        return FedEngine(g, fed, mcfg, rounds=rounds, clients_per_round=m,
                         seed=0, eval_every=rounds,
                         scheduler=SyncScheduler(fused=fused))

    rows = []
    secs = {}
    for name, fused in (("stepwise", False), ("fused", True)):
        dt = _time_run(lambda: make(fused))
        secs[name] = dt
        rows.append({
            "variant": name,
            "rounds": rounds,
            "clients": n_clients,
            "cohort": m,
            "rounds_per_s": rounds / dt,
            "ms_per_round": dt / rounds * 1e3,
        })
    speedup = secs["stepwise"] / secs["fused"]
    rows[1]["speedup_vs_stepwise"] = speedup

    # ---- eval aggregation backends (the per-round server-side hot spot) ----
    params = gcn_init(jax.random.PRNGKey(0), g.n_features, g.n_classes)
    for be in AGG_BACKENDS:
        eg = build_eval_graph(g, backend=be)
        evaluate_global(params, eg, "test")     # warmup/compile
        t0 = time.perf_counter()
        n_reps = 5
        for _ in range(n_reps):
            evaluate_global(params, eg, "test")
        rows.append({
            "variant": f"eval_{be}",
            "ms_per_eval": (time.perf_counter() - t0) / n_reps * 1e3,
        })

    payload = {
        "bench": "round_throughput",
        "backend": jax.default_backend(),
        "quick": quick,
        "fused_speedup": speedup,
        "rows": rows,
    }
    with open(os.path.join(REPO_ROOT, "BENCH_round.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    emit_csv("perf_round", rows)
    save_rows("perf_round", rows)
    speedup = next(r["speedup_vs_stepwise"] for r in rows
                   if r.get("speedup_vs_stepwise") is not None)
    print(f"# fused speedup vs stepwise: {speedup:.2f}x")
    if speedup < 1.0:
        print("# FAIL: fused executor slower than the step-by-step loop")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
