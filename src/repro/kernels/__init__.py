"""Pallas TPU kernels for the compute hot spots.

Each kernel package has three modules:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py     — jit'd public wrapper (padding, reshapes, GQA mapping)
    ref.py     — pure-jnp oracle used by the allclose/hypothesis test sweeps

Kernels:
    spmm            blocked block-sparse neighbor aggregation (the FedGCN hot
                    spot — the paper's gather/scatter re-blocked for the MXU)
    flash_attention causal/sliding-window GQA attention, online softmax
    wkv6            RWKV6 linear recurrence, state resident in VMEM

Kernels are validated in ``interpret=True`` mode on CPU; on-device they
compile for TPU. The LM/GCN default paths use XLA einsum implementations —
kernels are opt-in via ``use_pallas`` flags (CPU dry-runs must not trace
pallas_call bodies for 512 fake devices).
"""
