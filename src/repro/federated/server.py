"""FL server: client selection, FedAvg aggregation, global evaluation, and
the adaptive-tau update (Algorithm 1 lines 1-8)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sync import adaptive_tau
from repro.models.gcn import AGG_BACKENDS, gcn_full_forward, per_node_loss


def select_clients(rng: np.random.Generator, n_clients: int, m: int) -> np.ndarray:
    return rng.choice(n_clients, size=min(m, n_clients), replace=False)


def fedavg(stacked_params):
    """Mean over the leading (selected-client) axis — Algorithm 1 line 7."""
    return jax.tree_util.tree_map(lambda x: x.mean(axis=0), stacked_params)


def fedavg_weighted(stacked_params, weights: jnp.ndarray):
    w = weights / jnp.maximum(weights.sum(), 1e-12)

    def avg(x):
        wshape = (len(w),) + (1,) * (x.ndim - 1)
        return (x * w.reshape(wshape)).sum(axis=0)

    return jax.tree_util.tree_map(avg, stacked_params)


# ---------------------------------------------------------------------------
# evaluation (server holds the test set — paper §Experimental Settings)
# ---------------------------------------------------------------------------

def build_eval_graph(graph, max_deg: int = 32, seed: int = 0,
                     backend: str = "gather") -> dict:
    """``backend`` picks the full-forward neighbor aggregation (see
    models.gcn.neighbor_aggregate); ``segment``/``spmm`` precompute their
    static aggregation operands here (CSR edge arrays / the row-normalised
    adjacency) so every per-round eval and layer reuses them."""
    from repro.graph.csr import build_padded_neighbors, csr_from_padded

    if backend not in AGG_BACKENDS:
        raise ValueError(f"unknown eval backend {backend!r}; known: {AGG_BACKENDS}")
    idx, mask = build_padded_neighbors(graph.adjacency_lists(), max_deg, seed=seed)
    csr = None
    adj = None
    if backend == "segment":
        c = csr_from_padded(idx, mask)
        csr = {k: jnp.asarray(v) for k, v in c.items()}
    elif backend == "spmm":
        from repro.kernels.spmm.ops import adjacency_from_neighbors

        adj = adjacency_from_neighbors(jnp.asarray(idx), jnp.asarray(mask),
                                       graph.n_nodes)
    return {
        "features": jnp.asarray(graph.features),
        "labels": jnp.asarray(graph.labels),
        "nbr_idx": jnp.asarray(idx),
        "nbr_mask": jnp.asarray(mask),
        "test_mask": jnp.asarray(graph.test_mask),
        "val_mask": jnp.asarray(graph.val_mask),
        "n_classes": graph.n_classes,
        "backend": backend,
        "csr": csr,
        "adj": adj,
    }


@functools.partial(jax.jit, static_argnames=("backend",))
def _eval_logits(params, features, nbr_idx, nbr_mask, csr=None, adj=None,
                 backend: str = "gather"):
    return gcn_full_forward(params, features, nbr_idx, nbr_mask,
                            backend=backend, csr=csr, adj=adj)


def evaluate_global(params, eval_graph: dict, split: str = "test") -> dict:
    logits = _eval_logits(params, eval_graph["features"],
                          eval_graph["nbr_idx"], eval_graph["nbr_mask"],
                          csr=eval_graph.get("csr"),
                          adj=eval_graph.get("adj"),
                          backend=eval_graph.get("backend", "gather"))
    mask = np.asarray(eval_graph[f"{split}_mask"])
    labels = np.asarray(eval_graph["labels"])[mask]
    lg = np.asarray(logits, np.float32)[mask]
    nll = np.asarray(per_node_loss(jnp.asarray(lg), jnp.asarray(labels)))
    pred = lg.argmax(-1)
    acc = float((pred == labels).mean()) if len(labels) else 0.0
    return {
        "acc": acc,
        "loss": float(nll.mean()) if len(labels) else float("inf"),
        "f1": macro_f1(labels, pred, eval_graph["n_classes"]),
        "auc": macro_ovr_auc(labels, lg),
    }


def macro_f1(labels: np.ndarray, pred: np.ndarray, n_classes: int) -> float:
    f1s = []
    for c in range(n_classes):
        tp = float(((pred == c) & (labels == c)).sum())
        fp = float(((pred == c) & (labels != c)).sum())
        fn = float(((pred != c) & (labels == c)).sum())
        if tp + fp + fn == 0:
            continue
        f1s.append(2 * tp / max(2 * tp + fp + fn, 1e-12))
    return float(np.mean(f1s)) if f1s else 0.0


def macro_ovr_auc(labels: np.ndarray, logits: np.ndarray) -> float:
    """Macro one-vs-rest AUC via the rank statistic (no sklearn offline)."""
    aucs = []
    for c in np.unique(labels):
        pos = logits[labels == c, c]
        neg = logits[labels != c, c]
        if len(pos) == 0 or len(neg) == 0:
            continue
        ranks = np.argsort(np.argsort(np.concatenate([pos, neg])))
        r_pos = ranks[: len(pos)].sum() + len(pos)  # 1-based
        auc = (r_pos - len(pos) * (len(pos) + 1) / 2) / (len(pos) * len(neg))
        aucs.append(auc)
    return float(np.mean(aucs)) if aucs else 0.5


def update_tau(mcfg, test_loss: float, initial_loss: float, tau0: int) -> int:
    """Algorithm 1 line 8: adaptive (Eq. 11) or fixed interval."""
    if mcfg.adaptive_sync:
        return adaptive_tau(test_loss, initial_loss, tau0)
    return tau0
