"""Pallas TPU kernels for the compute hot spots.

Each kernel package has three modules:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py     — jit'd public wrapper (padding, reshapes, GQA mapping)
    ref.py     — pure-jnp oracle used by the allclose/hypothesis test sweeps

Kernels:
    spmm            blocked block-sparse neighbor aggregation (the FedGCN hot
                    spot — the paper's gather/scatter re-blocked for the MXU)
    flash_attention causal/sliding-window GQA attention, online softmax
    wkv6            RWKV6 linear recurrence, state resident in VMEM

Every public wrapper takes ``interpret=None`` meaning auto-detect: compiled
on TPU, interpreter elsewhere (see ``resolve_interpret``). Callers that
never pass the flag therefore get the compiled kernel on device instead of
silently running interpret-mode. The LM/GCN default paths use XLA einsum
implementations — kernels are opt-in via ``use_pallas`` flags (CPU dry-runs
must not trace pallas_call bodies for 512 fake devices).
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret`` argument.

    ``None`` (the default everywhere) auto-detects: run the compiled Pallas
    kernel on TPU, fall back to the interpreter on every other backend (CPU
    tests/CI, GPU). An explicit bool always wins — tests force
    ``interpret=True`` and on-device debugging can force ``False``.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
