"""Paper Fig. 4: total computation and communication cost per method to a
fixed accuracy target (the 91.77% / 85.59% savings headline)."""
from __future__ import annotations

from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup

METHODS = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph", "fedais")


def run(quick: bool = True) -> list[dict]:
    ds = "coauthor"
    g, fed = fed_setup(ds, 32 if quick else 64, 16, "0.5")
    rounds = 15 if quick else 50
    rows = []
    results = {}
    for m in METHODS:
        mcfg = method_config(m, tau0=4 if m == "fedais" else (2 if m == "fedpns" else 1))
        res = FedEngine(g, fed, mcfg, rounds=rounds, clients_per_round=5,
                        seed=0, target_acc=None).run()
        results[m] = res
    target = 0.9 * max(r.final["acc"] for r in results.values())
    for m, res in results.items():
        # cost at first round reaching target (or total if never)
        idx = next((i for i, a in enumerate(res.history["test_acc"]) if a >= target), None)
        comm = res.history["comm_total"][idx] if idx is not None else res.final["comm_total_bytes"]
        flops = res.history["flops"][idx] if idx is not None else res.final["compute_flops"]
        wall = res.history["wall_clock"][idx] if idx is not None else res.final["wall_clock_s"]
        rows.append({
            "method": m,
            "reached_target": idx is not None,
            "comm_mb": round(comm / 1e6, 2),
            "compute_gflops": round(flops / 1e9, 2),
            "wall_clock_s": round(wall, 2),
            "final_acc": round(res.final["acc"] * 100, 2),
        })
    ais = next(r for r in rows if r["method"] == "fedais")
    worst_comm = max(r["comm_mb"] for r in rows if r["method"] != "fedais")
    worst_fl = max(r["compute_gflops"] for r in rows if r["method"] != "fedais")
    rows.append({
        "method": "SAVINGS",
        "comm_saving_pct": round(100 * (1 - ais["comm_mb"] / worst_comm), 1),
        "compute_saving_pct": round(100 * (1 - ais["compute_gflops"] / worst_fl), 1),
    })
    return rows
