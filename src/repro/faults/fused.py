"""Fault-aware twin of ``FedEngine._build_fused_chunk``.

The plain fused chunk assumes every cohort member's update merges. Under
a non-empty ``FaultPlan`` the engine routes through this builder instead:
the same scanned ``round_step`` (identical PRNG chain, identical vmapped
LocalUpdate on the *real* cohort ids), extended with three per-round
per-member stacks evaluated on the host from the plan —

* ``w_stack``   (S, m) aggregation weights, 0.0 for dropped members
  (adding a 0.0-weighted, zeroed row to a float sum is exact, so the
  masked merge reproduces the stepwise subset merge bit-for-bit);
* ``cmult_stack`` (S, m) corruption multipliers (NaN / inf /
  corrupt_scale on corrupted members, 1.0 elsewhere) applied to the
  uploaded params in-trace;
* an in-trace ``UpdateGuard``: per-member all-finite check plus optional
  L2 delta-norm ceiling; members failing it get weight 0 and are counted
  into the streamed ``n_quarantined`` stat.

Members that are dropped OR quarantined also lose their historical
write-back: their scatter ids are rewritten to the out-of-range row K,
which JAX drops (the same no-op guarantee the sharded executors' padding
relies on). When *no* member survives a round, the merge falls back to
the carried params — a server no-op round, exactly like the stepwise
path's empty merge.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.federated.quant import check_sync_dtype, quant_roundtrip

__all__ = ["build_faulty_chunk"]


def build_faulty_chunk(vm, light_stats: Sequence[str], *,
                       uses_weights: bool, finite_guard: bool = True,
                       max_norm: Optional[float] = None,
                       sync_dtype: str = "fp32"):
    """Build the jitted fault-aware fused chunk.

    ``uses_weights`` selects the merge rule to reproduce exactly:
    WeightedFedAvg's normalize-then-sum when True, FedAvg's sum-then-
    divide when False. ``finite_guard=False`` disables the in-trace
    guard (matching an engine constructed with ``guard=None``, where
    non-finite updates poison the merge — by explicit user choice).
    ``sync_dtype`` round-trips the written-back float rows through the
    repro.federated.quant codec, matching the other executors' wire.
    """
    check_sync_dtype(sync_dtype)
    light_stats = tuple(light_stats)

    def chunk(params, hist1, age, ghost_feat, prev_loss, key, arrays,
              sel_stack, fan_stack, w_stack, cmult_stack, eoffs, tau):
        m = sel_stack.shape[1]
        K = hist1.shape[0]

        def bcast(v, x):
            return v.reshape((m,) + (1,) * (x.ndim - 1))

        def round_step(carry, xs):
            params, hist1, age, ghost_feat, prev_loss, key = carry
            sel, fanouts, w, cmult, eoff = xs
            ks = jax.random.split(key, m + 1)       # same chain as dispatch
            key, keys = ks[0], ks[1:]
            client = {k: v[sel] for k, v in arrays.items()}
            out = vm(params, client, arrays["features"], hist1,
                     hist1[sel], age[sel], ghost_feat[sel], prev_loss[sel],
                     tau, fanouts, eoff, keys)
            new_params, new_hist1, new_age, new_ghost_feat, stats = out

            # corruption: poison the uploaded params (NaN/inf/scale), not
            # the client's local state — the client itself is healthy
            new_params = jax.tree_util.tree_map(
                lambda x: x * bcast(cmult, x).astype(x.dtype), new_params)

            # finite/norm guard over each member's uploaded params
            if finite_guard:
                ok = jnp.ones((m,), bool)
                sumsq = jnp.zeros((m,), jnp.float32)
                for x, r in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)):
                    flat = x.reshape(m, -1)
                    ok &= jnp.all(jnp.isfinite(flat), axis=1)
                    if max_norm is not None:
                        d = flat - r.reshape(1, -1)
                        d = jnp.where(jnp.isfinite(d), d, 0.0)
                        sumsq += jnp.sum(d * d, axis=1)
                if max_norm is not None:
                    ok &= jnp.sqrt(sumsq) <= max_norm
            else:
                ok = jnp.ones((m,), bool)

            dispatched = w > 0.0                    # not dropped by the plan
            alive = dispatched & ok
            n_quar = jnp.sum(dispatched & ~ok)

            # zero non-survivor rows BEFORE weighting: NaN * 0 is NaN, and
            # a zeroed row added to a float sum is exact — so the masked
            # full-m merge equals the stepwise survivor-subset merge
            safe = jax.tree_util.tree_map(
                lambda x: jnp.where(bcast(alive, x), x, jnp.zeros((), x.dtype)),
                new_params)
            wa = jnp.where(alive, w, 0.0)
            if uses_weights:                        # WeightedFedAvg, exactly
                wn = wa / jnp.maximum(wa.sum(), 1e-12)
                merged = jax.tree_util.tree_map(
                    lambda x: (x * bcast(wn, x)).sum(axis=0), safe)
            else:                                   # FedAvg (mean), exactly
                count = jnp.maximum(alive.sum(), 1)
                merged = jax.tree_util.tree_map(
                    lambda x: x.sum(axis=0) / count, safe)
            any_alive = alive.any()
            params = jax.tree_util.tree_map(
                lambda mrg, old: jnp.where(any_alive, mrg, old),
                merged, params)

            # non-survivors lose their write-back too: out-of-range row K
            # makes the scatter drop (same trick as sharded dummy padding)
            wb = jnp.where(alive, sel, K)
            loss_wb = stats["loss_all"]
            new_hist1_wb, new_ghost_feat_wb = new_hist1, new_ghost_feat
            if sync_dtype != "fp32":
                new_hist1_wb = quant_roundtrip(new_hist1, sync_dtype)
                new_ghost_feat_wb = quant_roundtrip(new_ghost_feat, sync_dtype)
                loss_wb = quant_roundtrip(loss_wb, sync_dtype)
            hist1 = hist1.at[wb].set(new_hist1_wb)
            age = age.at[wb].set(new_age)
            ghost_feat = ghost_feat.at[wb].set(new_ghost_feat_wb)
            prev_loss = prev_loss.at[wb].set(loss_wb)

            light = {k: stats[k] for k in light_stats}
            light["n_quarantined"] = n_quar
            return (params, hist1, age, ghost_feat, prev_loss, key), light

        return jax.lax.scan(round_step,
                            (params, hist1, age, ghost_feat, prev_loss, key),
                            (sel_stack, fan_stack, w_stack, cmult_stack, eoffs))

    return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4, 5))
