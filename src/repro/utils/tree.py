"""Pytree helpers used across the framework (no flax/optax in container)."""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, *trees)


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return tree_map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return tree_map(lambda x: x * s, tree)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, elementwise over matching pytrees."""
    return tree_map(lambda a, b: alpha * a + b, x, y)


def tree_dot(a: PyTree, b: PyTree):
    """Inner product between two pytrees."""
    leaves = tree_map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_l2_norm(tree: PyTree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_count_params(tree: PyTree) -> int:
    return int(sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(
        sum(math.prod(x.shape) * jnp.dtype(x.dtype).itemsize for x in jax.tree_util.tree_leaves(tree))
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return tree_map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_isfinite(tree: PyTree):
    """True iff every floating leaf is finite everywhere."""
    leaves = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree_util.tree_leaves(tree)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
    ]
    if not leaves:
        return jnp.bool_(True)
    return jnp.stack(leaves).all()


def tree_shapes(tree: PyTree) -> PyTree:
    return tree_map(lambda x: tuple(x.shape), tree)


def tree_to_shape_dtype(tree: PyTree, sharding_fn: Callable | None = None) -> PyTree:
    """Convert a tree of arrays (or ShapeDtypeStructs) to ShapeDtypeStructs.

    ``sharding_fn(path, leaf)`` may attach a sharding; used by the dry-run.
    """

    def conv(path, x):
        sharding = sharding_fn(path, x) if sharding_fn is not None else None
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)

    return jax.tree_util.tree_map_with_path(conv, tree)


def tree_random_like(key, tree: PyTree, scale: float = 0.02) -> PyTree:
    """Fill a ShapeDtypeStruct tree with random normals (tests/examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    new = [
        jax.random.normal(k, l.shape, l.dtype) * scale
        if jnp.issubdtype(l.dtype, jnp.floating)
        else jnp.zeros(l.shape, l.dtype)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new)


def global_norm_clip(tree: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    norm = tree_l2_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return tree_scale(tree, scale), norm


def format_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def format_count(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}E"


def stable_hash(s: str) -> int:
    """Deterministic 32-bit hash (python hash() is salted per-process)."""
    h = 2166136261
    for c in s.encode():
        h = ((h ^ c) * 16777619) & 0xFFFFFFFF
    return h


def np_one_hot(x: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((*x.shape, n), dtype=np.float32)
    np.put_along_axis(out, x[..., None], 1.0, axis=-1)
    return out
