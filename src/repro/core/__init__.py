"""FedAIS core: the paper's contribution as composable JAX modules.

    importance.py   adaptive importance-based sampling       (Eq. 7-8)
    historical.py   historical embedding store + staleness   (Eq. 6)
    sync.py         adaptive embedding synchronization       (Eq. 9-11)
    variance.py     variance decomposition diagnostics       (Eq. 3-5, Thm. 1)
    fedais.py       Algorithm 1 — the composed trainer
"""
from repro.core.importance import importance_probs, loss_delta_scores, sample_batch
from repro.core.sync import adaptive_tau, delay_model, tau_theoretical
from repro.core.historical import HistoricalState, init_historical, push_embeddings, staleness_metrics

__all__ = [
    "importance_probs",
    "loss_delta_scores",
    "sample_batch",
    "adaptive_tau",
    "delay_model",
    "tau_theoretical",
    "HistoricalState",
    "init_historical",
    "push_embeddings",
    "staleness_metrics",
]
