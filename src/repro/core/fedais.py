"""FedAIS Algorithm 1 — the client LocalUpdate and its method-space.

One ``MethodConfig`` describes every method in the paper (FedAIS, its
ablations FedAIS1/FedAIS2, and the five baselines) as feature toggles over
the same LocalUpdate, so cost/accuracy comparisons are apples-to-apples.

``make_local_update(mcfg, dims)`` returns a jit-compiled function running J
local epochs for ONE client: importance-sampled batches (Eq. 7-8), forward
with historical embeddings (Eq. 6), local Adam steps, historical pushes, and
ghost pulls every tau epochs. It is vmapped over the selected clients by the
simulator — the cross-client pull then lowers to a gather over the stacked
client axis (the all-to-all of the real deployment).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.historical import pull_ghosts, pull_ghosts_prefetched, push_embeddings
from repro.federated.quant import check_sync_dtype, quant_roundtrip
from repro.core.importance import (
    importance_probs,
    loss_delta_scores,
    sample_batch,
    stable_rank,
    uniform_probs,
)
from repro.models.gcn import AGG_BACKENDS, gcn_batch_forward, per_node_loss
from repro.optim import adamw_init, adamw_update


@dataclass(frozen=True)
class MethodConfig:
    name: str = "fedais"
    importance_sampling: bool = True     # FedAIS / FedAIS1 (off: uniform/all)
    adaptive_sync: bool = True           # FedAIS / FedAIS2 (off: fixed tau)
    use_all_samples: bool = False        # FedAll/FedPNS/FedGraph/FedSage+/FedAIS2
    sample_ratio: float = 0.7            # r: fraction of local nodes per epoch
    neighbor_fanout: int = 10            # max sampled neighbors per node
    tau0: int = 2                        # initial / fixed sync interval
    local_epochs: int = 4                # J
    lr: float = 0.01
    use_generator: bool = False          # FedSage+: impute ghosts, no sync
    bandit_fanout: bool = False          # FedGraph-lite: learned fanout
    use_ghosts: bool = True              # FedLocal ablation: ignore cross-client
    batch_cap: int = 256                 # padded batch size upper bound
    # repro.api resolution hooks (string keys into the api registries):
    strategy: str = "auto"               # method-strategy kind; "auto" infers
    aggregator: str = "fedavg"           # server aggregation ("fedavg"|"weighted")
    scheduler: str = "sync"              # round scheduling ("sync"|"async")


def batch_size_for(mcfg: MethodConfig, n_max: int) -> int:
    if mcfg.use_all_samples:
        return n_max
    return max(1, min(mcfg.batch_cap, int(round(n_max * mcfg.sample_ratio))))


# vmap axes of local_update over the selected-client cohort: per-client
# slices map on their leading axis; params / full tables / scalars broadcast
VMAP_IN_AXES = (None, 0, None, None, 0, 0, 0, 0, None, 0, None, 0)
# ghost_source="prefetched": the two table-snapshot args become per-client
# pre-gathered (g_max, F)/(g_max, H1) source rows and map on their leading axis
VMAP_IN_AXES_PREFETCHED = (None, 0, 0, 0, 0, 0, 0, 0, None, 0, None, 0)


def make_vmapped_update(mcfg: MethodConfig, n_max: int, g_max: int, h1_dim: int,
                        *, ghost_source: str = "tables",
                        sync_dtype: str = "fp32",
                        train_backend: str = "gather"):
    """The cohort-stacked LocalUpdate every executor vmaps over the selected
    clients — shared by the engine's stepwise/fused paths and the sharded
    round_step (repro.sharding.fed), so all of them run one computation.
    ``ghost_source="prefetched"`` builds the pod-sharded variant (see
    ``make_local_update``)."""
    axes = VMAP_IN_AXES if ghost_source == "tables" else VMAP_IN_AXES_PREFETCHED
    return jax.vmap(make_local_update(mcfg, n_max, g_max, h1_dim,
                                      ghost_source=ghost_source,
                                      sync_dtype=sync_dtype,
                                      train_backend=train_backend),
                    in_axes=axes)


def make_local_update(mcfg: MethodConfig, n_max: int, g_max: int, h1_dim: int,
                      *, ghost_source: str = "tables",
                      sync_dtype: str = "fp32",
                      train_backend: str = "gather"):
    """Build the jit-able LocalUpdate for one client (Algorithm 1 lines 10-19).

    ``ghost_source`` picks where the tau-gated embedding sync reads from:

    * ``"tables"`` (default): gather from the replicated round-start
      snapshots ``feats_all`` (K, n_max, F) / ``hist1_all`` (K, n_tot, H1).
    * ``"prefetched"``: the same two positional arguments instead carry THIS
      client's pre-gathered ghost-source rows — (g_max, F) owner features
      and (g_max, H1) owner layer-1 rows, exchanged cross-pod by the
      table-sharded executor before the cohort step. Same values (both are
      round-start snapshots), so the two modes are computationally
      identical per client.

    ``sync_dtype`` selects the ghost-pull wire format (repro.federated.
    quant): in ``"tables"`` mode the pulled feature/h1 rows are
    round-tripped through the codec here — the semantic anchor every
    single-host executor shares. In ``"prefetched"`` mode the rows arrive
    already wire-quantized (the pod executor encodes the physical
    all-to-all and the partition-time feature exchange), so this function
    applies no second round-trip. ``"fp32"`` adds zero trace ops.

    ``train_backend`` selects the *batch* neighbor aggregation inside both
    ``gcn_batch_forward`` calls (the per-epoch loss pass and the training
    step): ``gather`` is the bit-parity default; ``segment`` derives its
    jit-stable bucketed CSR in-trace from the sampled batch rows and never
    materializes the (b, K, d) gather; ``spmm`` runs the Pallas kernel
    (grads flow through its custom VJP). Allclose parity across backends is
    pinned per method by tests/test_train_backend.py.
    """
    if ghost_source not in ("tables", "prefetched"):
        raise ValueError(f"unknown ghost_source {ghost_source!r}; "
                         "known: tables | prefetched")
    if train_backend not in AGG_BACKENDS:
        raise ValueError(f"unknown train_backend {train_backend!r}; "
                         f"known: {AGG_BACKENDS}")
    check_sync_dtype(sync_dtype)
    bsz = batch_size_for(mcfg, n_max)

    def local_update(
        params: Any,                # global model from server
        client: dict,               # this client's stacked-slice arrays
        feats_all: jnp.ndarray,     # (K, n_max, F) — ghost pull source
                                    #   [prefetched: (g_max, F) source rows]
        hist1_all: jnp.ndarray,     # (K, n_tot, H1) — ghost pull source (snapshot)
                                    #   [prefetched: (g_max, H1) source rows]
        hist1: jnp.ndarray,         # (n_tot, H1) this client's table
        age: jnp.ndarray,           # (n_tot,)
        ghost_feat: jnp.ndarray,    # (g_max, F) current synced ghost features
        prev_loss: jnp.ndarray,     # (n_max,) loss at previous round (-1 = never)
        tau: jnp.ndarray,           # scalar int32 — current sync interval
        fanout: jnp.ndarray,        # scalar int32 — neighbor fanout (bandit-controllable)
        epoch_offset: jnp.ndarray,  # scalar int32 — global batch-epoch counter (t*J)
        key: jnp.ndarray,
    ):
        train_mask = client["train_mask"] * client["node_mask"]

        # ---- lines 11-12: loss pass + selection probabilities ----
        all_idx = jnp.arange(n_max)
        logits_all, _, _ = gcn_batch_forward(
            params, client["features"], ghost_feat, hist1,
            client["nbr_idx"], client["nbr_mask"], all_idx,
            backend=train_backend,
        )
        loss_all = per_node_loss(logits_all, client["labels"]) * client["node_mask"]
        if mcfg.importance_sampling:
            scores = loss_delta_scores(loss_all, prev_loss, train_mask)
            probs = importance_probs(scores, train_mask)
        else:
            probs = uniform_probs(train_mask)

        opt_state = adamw_init(params)
        n_sync = jnp.zeros((), jnp.int32)
        n_ghost_pulled = jnp.zeros((), jnp.float32)

        def epoch(carry, j):
            params, opt_state, hist1, age, ghost_feat, n_sync, n_pulled, key = carry
            key, k_batch, k_nbr = jax.random.split(key, 3)

            # ---- line 14: batch selection ----
            if mcfg.use_all_samples:
                batch_idx = all_idx
                valid = train_mask > 0
            else:
                batch_idx, valid = sample_batch(k_batch, probs, bsz, train_mask)

            # ---- neighbor fanout subsampling ----
            b_nbr_mask = client["nbr_mask"][batch_idx]
            ranks = jax.random.uniform(k_nbr, b_nbr_mask.shape)
            ranks = jnp.where(b_nbr_mask > 0, ranks, 2.0)
            # one stable top-k over mantissa-quantized keys (see
            # importance.stable_rank) instead of the old double argsort over
            # raw keys. NOTE: quantization coarsens the keys, so near-equal
            # draws can tie and resolve by slot index where the raw-key path
            # ordered them by value — seeded trajectories differ from the
            # pre-quantization code (deliberate: same jitter-insensitivity
            # scheme as sample_batch; tests pin new-vs-old on shared keys)
            order = stable_rank(ranks)
            keep = (order < fanout).astype(jnp.float32)
            if not mcfg.use_ghosts:
                keep = keep * (client["nbr_idx"][batch_idx] < n_max)

            # ---- lines 15-17: sync every tau epochs (pull ghosts) ----
            # j is the GLOBAL batch-epoch counter (Algorithm 1: the paper's j
            # runs over local batch training epochs; tau gates it across
            # rounds — round 0 epoch 0 always syncs as the warm-up).
            # Only the ghosts the current batch actually references are
            # transferred ("the selected cross-client neighbor embeddings",
            # Algorithm 1 line 16) — importance sampling thus directly
            # shrinks the communication volume.
            j_global = epoch_offset + j
            do_sync = ((j_global % jnp.maximum(tau, 1)) == 0) & jnp.asarray(
                mcfg.use_ghosts and not mcfg.use_generator)

            b_idx_rows = client["nbr_idx"][batch_idx]
            referenced = (b_idx_rows >= n_max) & (b_nbr_mask * keep > 0) & valid[:, None]
            slot = jnp.where(referenced, b_idx_rows - n_max, 0)
            need = jnp.zeros((g_max,), jnp.float32).at[slot.reshape(-1)].max(
                referenced.reshape(-1).astype(jnp.float32))
            need = need * client["ghost_mask"]

            def pull(_):
                if ghost_source == "tables":
                    gf, gh = pull_ghosts(hist1_all, feats_all,
                                         client["ghost_owner"],
                                         client["ghost_row"],
                                         client["ghost_mask"])
                else:
                    gf, gh = pull_ghosts_prefetched(feats_all, hist1_all,
                                                    client["ghost_mask"])
                if sync_dtype != "fp32" and ghost_source == "tables":
                    gf = quant_roundtrip(gf, sync_dtype)
                    gh = quant_roundtrip(gh, sync_dtype)
                new_ghost_feat = jnp.where(need[:, None] > 0, gf, ghost_feat)
                new_hist = hist1.at[n_max:].set(
                    jnp.where(need[:, None] > 0, gh, hist1[n_max:]))
                return new_ghost_feat, new_hist, n_sync + 1, n_pulled + need.sum()

            def nopull(_):
                return ghost_feat, hist1, n_sync, n_pulled

            ghost_feat, hist1, n_sync, n_pulled = jax.lax.cond(do_sync, pull, nopull, None)

            # ---- line 18: batch forward/backward + local step ----
            def batch_loss(p):
                logits, h1, _ = gcn_batch_forward(
                    p, client["features"], ghost_feat, hist1,
                    client["nbr_idx"], client["nbr_mask"], batch_idx,
                    nbr_keep=keep, backend=train_backend,
                )
                w = valid.astype(jnp.float32) * train_mask[batch_idx]
                nll = per_node_loss(logits, client["labels"][batch_idx])
                return (nll * w).sum() / jnp.maximum(w.sum(), 1.0), h1

            (loss, h1), grads = jax.value_and_grad(batch_loss, has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params, mcfg.lr)

            # ---- historical push of fresh in-batch embeddings ----
            hist1, age = push_embeddings(hist1, age, batch_idx, h1,
                                         valid & (client["node_mask"][batch_idx] > 0))
            return (params, opt_state, hist1, age, ghost_feat, n_sync, n_pulled, key), loss

        carry = (params, opt_state, hist1, age, ghost_feat, n_sync, n_ghost_pulled, key)
        carry, epoch_losses = jax.lax.scan(epoch, carry, jnp.arange(mcfg.local_epochs))
        params, opt_state, hist1, age, ghost_feat, n_sync, n_ghost_pulled, key = carry

        stats = {
            "loss_all": loss_all,                 # becomes prev_loss next round
            "epoch_losses": epoch_losses,
            "n_sync": n_sync,
            "n_ghost_pulled": n_ghost_pulled,
            "mean_importance_entropy": -jnp.sum(
                jnp.where(probs > 0, probs * jnp.log(jnp.maximum(probs, 1e-30)), 0.0)),
        }
        return params, hist1, age, ghost_feat, stats

    return local_update
