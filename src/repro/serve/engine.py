"""QueryEngine: micro-batched node-classification queries over a ServedModel.

Concurrent requests are packed into padded micro-batches at a small fixed
set of bucket shapes; every compute path (both cache policies + the
background refresh) is jitted once per bucket during :meth:`warmup`, so no
query ever triggers a recompile afterwards (``trace_count`` is the probe the
tests pin).

``fused`` (the default) serves each bucket as ONE aggregate→layer→logits
XLA call whose ``segment`` operands are the jit-stable bucketed CSR derived
*in-trace* from the padded batch rows (``graph/csr.bucketed_csr_from_padded``
via ``models/gcn.neighbor_aggregate``) — no per-query host CSR build, no
edge-array transfer. ``fused=False`` keeps the decomposed two-call
reference pipeline (an aggregate call, a host hop, then a layer→logits
call, with the batch adjacency host-lowered as padded-CSR edge arrays per
chunk) — same numbers, measurably slower; ``launch/serve_fed`` times the
two against each other into BENCH_serve.json's ``fused`` section.

``cache_policy`` is the paper's accuracy-vs-cost trade-off moved to
inference time:

* ``"historical"`` — layer-1 embeddings are *read* from the warm table
  (one aggregation + one dense layer per query; stale rows are served
  as-is and surface in the hit-rate ledger until refreshed);
* ``"fresh"`` — layer-1 is recomputed for the query's 1-hop neighborhood
  and scattered over the table (exactly ``gcn_batch_forward``'s fresh-rows
  semantics), giving exact logits at ~(max_deg+1)x the embed compute.

Degraded modes (all off by default, counters on the engine):

* ``fallback`` — when the fresh path raises or returns non-finite logits
  (e.g. poisoned streaming features), the batch is re-served from the warm
  historical cache instead of failing (``n_fallbacks``);
* ``deadline_ms`` — a ``"fresh"`` batch whose queueing delay already
  exceeds the deadline is downgraded to ``"historical"`` — cheaper and
  still warm — rather than making the queue worse (``n_degraded``);
* ``max_queue`` — :meth:`admit` rejects new requests outright once the
  queue passes this occupancy, shedding load explicitly (``n_rejected``)
  instead of letting latency grow without bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.quant import decode as quant_decode
from repro.federated.quant import encode as quant_encode
from repro.graph.csr import csr_from_padded
from repro.models.gcn import _sage_layer, neighbor_aggregate
from repro.serve.model import ServedModel

CACHE_POLICIES = ("historical", "fresh")
DEFAULT_BUCKETS = (8, 32, 128)


class QueryEngine:
    """Serves node-classification queries from a :class:`ServedModel`."""

    def __init__(self, model: ServedModel, *,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 cache_policy: str = "historical",
                 deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 fallback: bool = True,
                 fused: bool = True):
        if cache_policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache_policy {cache_policy!r}; "
                             f"known: {CACHE_POLICIES}")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be positive, got {deadline_ms}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.model = model
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets!r}")
        self.cache_policy = cache_policy
        # graceful-degradation knobs + their observable counters
        self.deadline_ms = deadline_ms
        self.max_queue = max_queue
        self.fallback = bool(fallback)
        self.n_rejected = 0      # requests shed at admission (queue full)
        self.n_degraded = 0      # fresh batches downgraded past deadline_ms
        self.n_fallbacks = 0     # fresh chunks re-served from the warm cache
        # incremented inside the traced bodies: bumps exactly when XLA
        # (re)compiles a serve shape — the no-recompile-after-warmup probe
        self.trace_count = 0
        self.trace_count_after_warmup: int | None = None
        self.fused = bool(fused)
        if self.fused:
            self._fn_hist = jax.jit(self._hist_impl)
            self._fn_fresh = jax.jit(self._fresh_impl)
            self._fn_refresh = jax.jit(self._refresh_impl,
                                       donate_argnums=(2, 3))
        else:
            # two-call reference pipeline: aggregate, host hop, head
            self._fn_agg_hist = jax.jit(self._agg_hist_impl)
            self._fn_head = jax.jit(self._head_impl)
            self._fn_embed = jax.jit(self._embed_impl)
            self._fn_classify = jax.jit(self._classify_impl)
            self._fn_refresh = jax.jit(self._refresh_twocall_impl,
                                       donate_argnums=(2, 3))

    # ------------------------------------------------------------------
    # traced compute (one XLA program per bucket shape, cached by jit)
    # ------------------------------------------------------------------

    def _agg(self, table, idx, mask, seg=None):
        """Mean-aggregate ``table`` rows for the padded batch rows — the
        serving twin of ``models.gcn.neighbor_aggregate`` (same math per
        backend, batch-shaped operands). ``seg=None`` (the fused path)
        derives the segment backend's bucketed CSR in-trace; the two-call
        path passes the host-built padded edge arrays instead. Per-segment
        summation order is identical either way, so the logits agree bit
        for bit."""
        return neighbor_aggregate(table, idx, mask,
                                  backend=self.model.backend, csr=seg)

    # -- fused: one aggregate→layer→logits body per (bucket, policy) -----

    def _hist_impl(self, params, h1, h1s, qrows, b_idx, b_mask):
        self.trace_count += 1
        # dequant-on-read: the cache stays resident in its wire format;
        # fp32 decode is the identity (bit-identical jaxpr to pre-codec)
        h1 = quant_decode(h1, h1s, self.model.cache_dtype)
        agg1 = self._agg(h1, b_idx, b_mask)
        h2 = _sage_layer(params, 1, h1[qrows], agg1)
        return h2 @ params["w_cls"] + params["b_cls"]

    def _fresh_impl(self, params, feat, h1, h1s, qrows, b_idx, b_mask,
                    rrows, rvalid, r_idx, r_mask):
        self.trace_count += 1
        h1 = quant_decode(h1, h1s, self.model.cache_dtype)
        agg0 = self._agg(feat, r_idx, r_mask)
        h1r = _sage_layer(params, 0, feat[rrows], agg0)
        fresh = jnp.where(rvalid[:, None] > 0, h1r, h1[rrows])
        table1 = h1.at[rrows].set(fresh)
        agg1 = self._agg(table1, b_idx, b_mask)
        h2 = _sage_layer(params, 1, table1[qrows], agg1)
        return h2 @ params["w_cls"] + params["b_cls"]

    def _refresh_impl(self, params, feat, h1, h1s, rrows, rvalid, r_idx,
                      r_mask):
        self.trace_count += 1
        dt = self.model.cache_dtype
        agg0 = self._agg(feat, r_idx, r_mask)
        h1r = _sage_layer(params, 0, feat[rrows], agg0)
        if dt == "fp32":
            return (h1.at[rrows].set(
                jnp.where(rvalid[:, None] > 0, h1r, h1[rrows])), h1s)
        # quantized cache: encode only the refreshed rows and scatter
        # payload + scale — untouched rows keep their exact stored bits
        qf, sf = quant_encode(h1r, dt)
        h1 = h1.at[rrows].set(jnp.where(rvalid[:, None] > 0, qf, h1[rrows]))
        if sf is not None:
            h1s = h1s.at[rrows].set(
                jnp.where(rvalid[:, None] > 0, sf, h1s[rrows]))
        return h1, h1s

    # -- two-call reference: aggregate call, host hop, head call ---------

    def _agg_hist_impl(self, h1, h1s, qrows, b_idx, b_mask, seg):
        self.trace_count += 1
        h1 = quant_decode(h1, h1s, self.model.cache_dtype)
        return h1[qrows], self._agg(h1, b_idx, b_mask, seg)

    def _head_impl(self, params, h1q, agg1):
        self.trace_count += 1
        h2 = _sage_layer(params, 1, h1q, agg1)
        return h2 @ params["w_cls"] + params["b_cls"]

    def _embed_impl(self, params, feat, h1, h1s, rrows, rvalid, r_idx,
                    r_mask, seg_r):
        self.trace_count += 1
        h1 = quant_decode(h1, h1s, self.model.cache_dtype)
        agg0 = self._agg(feat, r_idx, r_mask, seg_r)
        h1r = _sage_layer(params, 0, feat[rrows], agg0)
        fresh = jnp.where(rvalid[:, None] > 0, h1r, h1[rrows])
        return h1.at[rrows].set(fresh)

    def _classify_impl(self, params, table1, qrows, b_idx, b_mask, seg_b):
        self.trace_count += 1
        agg1 = self._agg(table1, b_idx, b_mask, seg_b)
        h2 = _sage_layer(params, 1, table1[qrows], agg1)
        return h2 @ params["w_cls"] + params["b_cls"]

    def _refresh_twocall_impl(self, params, feat, h1, h1s, rrows, rvalid,
                              r_idx, r_mask, seg):
        self.trace_count += 1
        dt = self.model.cache_dtype
        agg0 = self._agg(feat, r_idx, r_mask, seg)
        h1r = _sage_layer(params, 0, feat[rrows], agg0)
        if dt == "fp32":
            return (h1.at[rrows].set(
                jnp.where(rvalid[:, None] > 0, h1r, h1[rrows])), h1s)
        qf, sf = quant_encode(h1r, dt)
        h1 = h1.at[rrows].set(jnp.where(rvalid[:, None] > 0, qf, h1[rrows]))
        if sf is not None:
            h1s = h1s.at[rrows].set(
                jnp.where(rvalid[:, None] > 0, sf, h1s[rrows]))
        return h1, h1s

    # ------------------------------------------------------------------
    # host-side batching
    # ------------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _seg_operands(self, idx: np.ndarray, mask: np.ndarray) -> dict | None:
        """Padded-CSR edge arrays for the batch rows, fixed-shape per bucket:
        real edges from ``csr_from_padded``, padding routed to an overflow
        segment the traced compute slices off. Two-call mode only — the
        fused bodies derive the same operands in-trace, skipping this host
        build and its device transfer entirely."""
        if self.fused or self.model.backend != "segment":
            return None
        b = idx.shape[0]
        e_cap = b * idx.shape[1]
        c = csr_from_padded(idx, mask)
        e = len(c["src"])
        src = np.zeros(e_cap, np.int32)
        src[:e] = c["src"]
        dst = np.full(e_cap, b, np.int32)
        dst[:e] = c["dst"]
        return {"src": src, "dst": dst, "inv_deg": c["inv_deg"]}

    def _refresh_call(self, rrows, rvalid, r_idx, r_mask):
        """Dispatch the background-refresh body for the active mode."""
        model = self.model
        if self.fused:
            return self._fn_refresh(model.params, model.feat, model.h1,
                                    model.h1_scale, rrows, rvalid, r_idx,
                                    r_mask)
        return self._fn_refresh(model.params, model.feat, model.h1,
                                model.h1_scale, rrows, rvalid, r_idx, r_mask,
                                self._seg_operands(r_idx, r_mask))

    def _pad_rows(self, rows: np.ndarray, cap: int):
        padded = np.zeros(cap, np.int32)
        padded[: len(rows)] = rows
        valid = np.zeros(cap, np.float32)
        valid[: len(rows)] = 1.0
        return padded, valid

    def _serve_chunk(self, ids: np.ndarray, policy: str):
        """One padded micro-batch through the pre-jitted bucket shape."""
        model, store = self.model, self.model.store
        b = self._bucket_for(len(ids))
        q, _ = self._pad_rows(ids, b)
        b_idx, b_mask = store.neighbors(q)
        seg_b = self._seg_operands(b_idx, b_mask)
        n = len(ids)
        # cache rows this chunk reads under "historical": the query rows
        # plus their real neighbors (the hit-rate denominator)
        touched = np.unique(np.concatenate(
            [q[:n].astype(np.int64), b_idx[:n][b_mask[:n] > 0].astype(np.int64)]))
        hit_rate = float(model.valid[touched].mean()) if len(touched) else 1.0
        fell_back = False
        if policy == "fresh":
            r = np.unique(np.concatenate(
                [q.astype(np.int64), b_idx[b_mask > 0].astype(np.int64)]))
            r_cap = b * (store.max_deg + 1)
            rrows, rvalid = self._pad_rows(r, r_cap)
            r_idx, r_mask = store.neighbors(rrows)
            seg_r = self._seg_operands(r_idx, r_mask)
            try:
                if self.fused:
                    logits = np.asarray(self._fn_fresh(
                        model.params, model.feat, model.h1, model.h1_scale,
                        q, b_idx, b_mask, rrows, rvalid, r_idx, r_mask))
                else:
                    table1 = self._fn_embed(
                        model.params, model.feat, model.h1, model.h1_scale,
                        rrows, rvalid, r_idx, r_mask, seg_r)
                    logits = np.asarray(self._fn_classify(
                        model.params, table1, q, b_idx, b_mask, seg_b))
                if self.fallback and not np.isfinite(logits[:n]).all():
                    raise ArithmeticError("non-finite fresh logits")
            except Exception:
                if not self.fallback:
                    raise
                # degrade, don't fail: the warm historical cache still has
                # the last good embeddings for these rows
                self.n_fallbacks += 1
                fell_back = True
                policy = "historical"
        if policy == "historical":
            if self.fused:
                logits = self._fn_hist(model.params, model.h1,
                                       model.h1_scale, q, b_idx, b_mask)
            else:
                h1q, agg1 = self._fn_agg_hist(model.h1, model.h1_scale, q,
                                              b_idx, b_mask, seg_b)
                logits = self._fn_head(model.params, h1q, agg1)
        info = {"bucket": b, "real": n, "touched": len(touched),
                "hit_rate": hit_rate, "policy": policy, "fell_back": fell_back}
        return np.asarray(logits)[:n], info

    # ------------------------------------------------------------------
    # public serving surface
    # ------------------------------------------------------------------

    def warmup(self) -> int:
        """Compile every (bucket, policy) serve shape plus the refresh shapes
        with inert dummy batches. After this, serving any query mix must not
        trace again (pinned via ``trace_count``). Returns the trace count."""
        model = self.model
        for b in self.buckets:
            dummy = np.zeros(b, np.int64)
            for policy in CACHE_POLICIES:
                self._serve_chunk(dummy, policy)
            # refresh shape: rvalid all-zero makes the table write a no-op
            rrows = np.zeros(b, np.int32)
            rvalid = np.zeros(b, np.float32)
            r_idx, r_mask = model.store.neighbors(rrows)
            model.h1, model.h1_scale = self._refresh_call(
                rrows, rvalid, r_idx, r_mask)
        self.trace_count_after_warmup = self.trace_count
        return self.trace_count

    def query(self, node_ids, policy: str | None = None) -> np.ndarray:
        """Logits (n, C) for one request (a list/array of node ids)."""
        [logits], _ = self.serve_batch([node_ids], policy=policy)
        return logits

    def admit(self, queue_depth: int) -> bool:
        """Admission control: False (and ``n_rejected`` bumps) when the
        queue is already at ``max_queue`` — explicit load shedding beats
        unbounded latency. Always True when ``max_queue`` is unset."""
        if self.max_queue is not None and queue_depth >= self.max_queue:
            self.n_rejected += 1
            return False
        return True

    def degraded_snapshot(self) -> dict:
        """The degradation counters, for ledgers / bench payloads."""
        return {"n_rejected": self.n_rejected, "n_degraded": self.n_degraded,
                "n_fallbacks": self.n_fallbacks}

    def serve_batch(self, requests, policy: str | None = None,
                    queue_ms: float | None = None):
        """Pack concurrent requests into padded micro-batches and serve them.

        Returns ``(per_request_logits, info)`` where info carries the bucket
        occupancy and cache hit-rate the latency ledger records.
        ``queue_ms`` is the batch's queueing delay so far: a ``"fresh"``
        batch already past ``deadline_ms`` is downgraded to the cheaper
        ``"historical"`` policy (``info["policy"]`` reports what actually
        ran).
        """
        policy = self.cache_policy if policy is None else policy
        if policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache_policy {policy!r}")
        if (policy == "fresh" and self.deadline_ms is not None
                and queue_ms is not None and queue_ms > self.deadline_ms):
            policy = "historical"
            self.n_degraded += 1
        lens = []
        parts = []
        for r in requests:
            ids = np.asarray(r, np.int64).reshape(-1)
            self.model.store._check_ids(ids, "query")
            lens.append(len(ids))
            parts.append(ids)
        flat = np.concatenate(parts) if parts else np.zeros(0, np.int64)
        bmax = self.buckets[-1]
        outs, chunks = [], []
        for i in range(0, len(flat), bmax):
            logits, info = self._serve_chunk(flat[i: i + bmax], policy)
            outs.append(logits)
            chunks.append(info)
        all_logits = np.concatenate(outs) if outs else np.zeros((0, 1))
        per_request = []
        off = 0
        for ln in lens:
            per_request.append(all_logits[off: off + ln])
            off += ln
        tot_touch = sum(c["touched"] for c in chunks) or 1
        info = {
            "chunks": chunks,
            "bucket": chunks[0]["bucket"] if chunks else 0,
            "occupancy": (sum(c["real"] for c in chunks)
                          / max(sum(c["bucket"] for c in chunks), 1)),
            "hit_rate": sum(c["hit_rate"] * c["touched"] for c in chunks)
            / tot_touch,
            "policy": policy,
            "fell_back": any(c["fell_back"] for c in chunks),
        }
        self.model.step += 1
        return per_request, info

    # ------------------------------------------------------------------
    # streaming updates + background refresh
    # ------------------------------------------------------------------

    def add_edges(self, edges) -> np.ndarray:
        """Streaming edge insert: mutate the adjacency and invalidate exactly
        the affected cached rows (the edge endpoints)."""
        affected = self.model.store.add_edges(edges)
        self.model.invalidate(affected)
        return affected

    def add_nodes(self, feats, edges=None):
        """Streaming node insert (optionally with attachment edges):
        invalidates the new nodes' 1-hop neighborhood. If the insert grew
        the store past its allocation, the device mirrors re-allocate and
        every bucket shape re-warms BEFORE any feature write — a scatter
        past the old capacity would silently drop (JAX OOB-scatter rule),
        and the first post-growth query must not trace."""
        ids, affected = self.model.store.add_nodes(feats, edges)
        if self.model.ensure_capacity():
            self.warmup()
        self.model.set_features(ids, self.model.store.features[ids])
        self.model.invalidate(affected)
        return ids, affected

    def refresh(self, max_rows: int | None = None) -> int:
        """Background refresh batch: re-embed up to ``max_rows`` invalidated
        cache rows through the bucket-shaped layer-0 path. Returns the
        number of rows re-embedded."""
        model = self.model
        rows = model.invalid_rows()
        if max_rows is not None:
            rows = rows[:max_rows]
        bmax = self.buckets[-1]
        total = 0
        for i in range(0, len(rows), bmax):
            chunk = rows[i: i + bmax]
            b = self._bucket_for(len(chunk))
            rrows, rvalid = self._pad_rows(chunk, b)
            r_idx, r_mask = model.store.neighbors(rrows)
            model.h1, model.h1_scale = self._refresh_call(
                rrows, rvalid, r_idx, r_mask)
            model.mark_written(chunk)
            total += len(chunk)
        return total
