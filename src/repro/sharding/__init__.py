from repro.sharding.specs import (
    activation_rules,
    batch_spec,
    decode_state_spec,
    param_spec_tree,
)

__all__ = ["activation_rules", "batch_spec", "decode_state_spec", "param_spec_tree"]
