"""Benchmark driver: one module per paper table/figure + roofline + kernels.

    PYTHONPATH=src python -m benchmarks.run            # quick mode (CI)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sweeps
    PYTHONPATH=src python -m benchmarks.run --only fig3_comm
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

from benchmarks.common import emit_csv, save_rows

BENCHMARKS = [
    "table2_accuracy",   # paper Table 2
    "fig3_comm",         # paper Fig. 3
    "fig4_costs",        # paper Fig. 4 (savings headline)
    "fig5_ablation",     # paper Fig. 5
    "fig6_clients",      # paper Fig. 6
    "fig7_sensitivity",  # paper Fig. 7
    "fig8_async",        # extension: sync vs async scheduling wall-clock
    "perf_round",        # round throughput: fused scanned executor vs stepwise
    "perf_serve",        # serving latency: checkpoint-backed online inference
    "kernel_bench",      # kernel layer (us_per_call + oracle deltas)
    "roofline",          # §Roofline from the dry-run artifacts
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", default=None, choices=[*BENCHMARKS, None])
    args = ap.parse_args()

    names = [args.only] if args.only else BENCHMARKS
    failures = 0
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            rows = mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures += 1
            continue
        emit_csv(name, rows)
        save_rows(name, rows)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
