"""Federated training simulator — compatibility shim.

The 224-line ``run_federated`` monolith that used to live here is now the
composable ``repro.api.FedEngine`` (protocols for client selection,
aggregation, sync control, cost accounting, and round callbacks; a
string-keyed method registry replaces the ``use_generator``/``bandit_fanout``
if-branches). This module keeps the legacy entry point and result type alive
for existing callers; tests/test_api.py proves the engine reproduces the
legacy loop's per-round history bit-for-bit.

Prefer the new surface for new code::

    from repro.api import FedEngine
    res = FedEngine(graph, fed, "fedais", rounds=30).run()
"""
from __future__ import annotations

from repro.api.engine import FedEngine, RunResult  # noqa: F401  (re-export)
from repro.core.fedais import MethodConfig
from repro.federated.costs import DelayModel
from repro.federated.partition import FederatedGraph
from repro.graph.data import GraphData


def run_federated(
    graph: GraphData,
    fed: FederatedGraph,
    mcfg: MethodConfig,
    *,
    rounds: int = 30,
    clients_per_round: int = 10,
    seed: int = 0,
    target_acc: float | None = None,
    delay: DelayModel = DelayModel(),
    eval_every: int = 1,
    verbose: bool = False,
) -> RunResult:
    """Legacy entry point: build a default-configured FedEngine and run it."""
    return FedEngine(
        graph, fed, mcfg,
        rounds=rounds, clients_per_round=clients_per_round, seed=seed,
        target_acc=target_acc, delay=delay, eval_every=eval_every,
        verbose=verbose,
    ).run()
