"""Tests for the centralized graph-sampling families (related-work section)."""
import numpy as np
import pytest

from repro.graph.csr import build_padded_neighbors, degree_stats
from repro.graph.data import make_dataset
from repro.graph.sampling import layer_wise_sample, node_wise_sample, subgraph_sample


@pytest.fixture(scope="module")
def padded():
    g = make_dataset("pubmed", scale=64, seed=1)
    idx, mask = build_padded_neighbors(g.adjacency_lists(), 16)
    return g, idx, mask


def test_build_padded_neighbors_consistency(padded):
    g, idx, mask = padded
    assert idx.shape == mask.shape
    assert idx.shape[0] == g.n_nodes
    # masked slots index valid nodes
    assert (idx[mask > 0] < g.n_nodes).all()
    stats = degree_stats(mask)
    assert 0 < stats["mean"] <= 16


def test_node_wise_sample_caps_fanout(padded):
    g, idx, mask = padded
    rng = np.random.default_rng(0)
    new_idx, new_mask = node_wise_sample(idx, mask, fanout=4, rng=rng)
    assert new_mask.shape[1] == 4
    assert (new_mask.sum(1) <= 4).all()
    # sampled neighbors are a subset of the originals
    for i in range(0, g.n_nodes, max(1, g.n_nodes // 20)):
        orig = set(idx[i][mask[i] > 0].tolist())
        kept = set(new_idx[i][new_mask[i] > 0].tolist())
        assert kept <= orig


def test_node_wise_sample_noop_when_fanout_large(padded):
    g, idx, mask = padded
    rng = np.random.default_rng(0)
    new_idx, new_mask = node_wise_sample(idx, mask, fanout=999, rng=rng)
    np.testing.assert_array_equal(new_idx, idx)


def test_layer_wise_sample_budget(padded):
    g, idx, mask = padded
    rng = np.random.default_rng(0)
    _, new_mask = layer_wise_sample(idx, mask, g.n_nodes, budget=g.n_nodes // 4, rng=rng)
    # only neighbors inside the sampled layer survive
    assert new_mask.sum() < mask.sum()
    survivors = np.unique(idx[new_mask > 0])
    assert len(survivors) <= g.n_nodes // 4


def test_subgraph_sample_partition(padded):
    g, idx, mask = padded
    rng = np.random.default_rng(0)
    parts = subgraph_sample(g.edges, g.n_nodes, n_parts=4, rng=rng)
    assert parts.shape == (g.n_nodes,)
    assert set(np.unique(parts)) <= {0, 1, 2, 3}
