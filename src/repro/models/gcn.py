"""GraphSAGE-style GCN (the paper's model: 2 hidden layers, 256/128) with
historical-embedding support — the JAX realisation of paper Eq. (2)/(6).

The client-side forward prunes the computation graph to the batch nodes plus
their direct 1-hop neighbors; deeper recursion is replaced by table lookups:
layer-0 neighbors read exact own features / synced ghost features, layer-1
neighbors read fresh in-batch values scattered over the historical table.
Gradients flow only through fresh (in-batch) entries — GNNAutoScale
semantics extended across clients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

HIDDEN = (256, 128)


def gcn_init(key, n_features: int, n_classes: int, hidden=HIDDEN, dtype=jnp.float32) -> dict:
    dims = (n_features, *hidden)
    ks = jax.random.split(key, 2 * len(hidden) + 1)
    params: dict = {}
    for l in range(len(hidden)):
        params[f"w_self{l}"] = dense_init(ks[2 * l], dims[l], dims[l + 1], dtype)
        params[f"w_nbr{l}"] = dense_init(ks[2 * l + 1], dims[l], dims[l + 1], dtype)
        params[f"b{l}"] = jnp.zeros((dims[l + 1],), dtype)
    params["w_cls"] = dense_init(ks[-1], hidden[-1], n_classes, dtype)
    params["b_cls"] = jnp.zeros((n_classes,), dtype)
    return params


AGG_BACKENDS = ("gather", "segment", "spmm")


def _aggregate(table: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray) -> jnp.ndarray:
    """Mean-aggregate neighbor rows. table (M, d); nbr_idx/mask (b, K)."""
    gathered = table[nbr_idx] * nbr_mask[..., None]
    deg = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    return gathered.sum(1) / deg


def neighbor_aggregate(
    table: jnp.ndarray,
    nbr_idx: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    *,
    backend: str = "gather",
    csr: dict | None = None,
    adj: jnp.ndarray | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Mean-aggregate neighbor rows through a pluggable backend.

    ``gather``   the dense (b, K, d) gather — current semantics and the
                 bit-parity default (the training batch path uses it
                 unconditionally: its batch shapes are dynamic).
    ``segment``  CSR ``segment_sum`` over the E real edges — needs the
                 precomputed ``csr`` dict from ``graph.csr.csr_from_padded``;
                 never materializes the padded (b, K, d) gather.
    ``spmm``     the block-sparse Pallas kernel (kernels/spmm) against a
                 row-normalised adjacency; ``interpret`` auto-detects
                 (compiled on TPU, interpreter elsewhere). The adjacency
                 depends only on the static neighbor list — pass the
                 precomputed ``adj`` (build_eval_graph does) so it is built
                 once per graph, not per layer per call.

    ``segment``/``spmm`` are numerically equivalent to ``gather`` within FP
    tolerance (different summation order), pinned by tests/test_fused.py.
    """
    if backend == "gather":
        return _aggregate(table, nbr_idx, nbr_mask)
    if backend == "segment":
        if csr is None:
            raise ValueError("segment backend needs csr=csr_from_padded(...)")
        seg = jax.ops.segment_sum(table[csr["src"]], csr["dst"],
                                  num_segments=nbr_idx.shape[0])
        return seg * csr["inv_deg"][:, None]
    if backend == "spmm":
        from repro.kernels.spmm.ops import adjacency_from_neighbors, block_spmm

        if adj is None:
            adj = adjacency_from_neighbors(nbr_idx, nbr_mask, table.shape[0])
        return block_spmm(adj, table, interpret=interpret).astype(table.dtype)
    raise ValueError(f"unknown aggregation backend {backend!r}; known: {AGG_BACKENDS}")


def _sage_layer(params: dict, l: int, h_self: jnp.ndarray, h_agg: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.relu(
        h_self @ params[f"w_self{l}"] + h_agg @ params[f"w_nbr{l}"] + params[f"b{l}"]
    )


def gcn_batch_forward(
    params: dict,
    features: jnp.ndarray,      # (n, F) own features
    ghost_feat: jnp.ndarray,    # (g, F) synced ghost features (historical l=0)
    hist1: jnp.ndarray,         # (n + g, H1) historical layer-1 embeddings
    nbr_idx: jnp.ndarray,       # (n, K) into [own | ghost]
    nbr_mask: jnp.ndarray,      # (n, K)
    batch_idx: jnp.ndarray,     # (b,) rows of this batch
    nbr_keep: jnp.ndarray | None = None,   # optional (b, K) extra neighbor mask
):
    """Returns (logits (b, C), fresh_h1 (b, H1), h2 (b, H2))."""
    table0 = jnp.concatenate([features, ghost_feat], axis=0)
    b_idx = nbr_idx[batch_idx]
    b_mask = nbr_mask[batch_idx]
    if nbr_keep is not None:
        b_mask = b_mask * nbr_keep

    h_self0 = features[batch_idx]
    agg0 = _aggregate(table0, b_idx, b_mask)
    h1 = _sage_layer(params, 0, h_self0, agg0)                  # (b, 256)

    # fresh in-batch values over the historical table (stop-grad on history)
    table1 = jax.lax.stop_gradient(hist1).at[batch_idx].set(h1)
    agg1 = _aggregate(table1, b_idx, b_mask)
    h2 = _sage_layer(params, 1, h1, agg1)                       # (b, 128)

    logits = h2 @ params["w_cls"] + params["b_cls"]
    return logits, h1, h2


def gcn_full_forward(params, features, nbr_idx, nbr_mask, *,
                     backend: str = "gather", csr: dict | None = None,
                     adj: jnp.ndarray | None = None,
                     interpret: bool | None = None):
    """Exact full-graph forward (server-side evaluation; no history).

    This is the per-round O(N·K·F) eval hot spot; ``backend`` selects the
    neighbor-aggregation implementation (see ``neighbor_aggregate``).
    """
    h = features
    for l in range(len(HIDDEN)):
        agg = neighbor_aggregate(h, nbr_idx, nbr_mask, backend=backend,
                                 csr=csr, adj=adj, interpret=interpret)
        h = _sage_layer(params, l, h, agg)
    return h @ params["w_cls"] + params["b_cls"]


def per_node_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """(b, C), (b,) -> (b,) cross-entropy per node (no reduction)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return lse - gold


def gcn_param_count(n_features: int, n_classes: int, hidden=HIDDEN) -> int:
    dims = (n_features, *hidden)
    total = 0
    for l in range(len(hidden)):
        total += 2 * dims[l] * dims[l + 1] + dims[l + 1]
    total += hidden[-1] * n_classes + n_classes
    return total


def gcn_flops_per_node(n_features: int, n_classes: int, avg_deg: float, hidden=HIDDEN) -> float:
    """Forward FLOPs per training node (matmuls + aggregation)."""
    dims = (n_features, *hidden)
    fl = 0.0
    for l in range(len(hidden)):
        fl += 2 * 2 * dims[l] * dims[l + 1]       # self + nbr matmuls
        fl += 2 * avg_deg * dims[l]               # mean aggregation
    fl += 2 * hidden[-1] * n_classes
    return fl
