"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_fed():
    """A small federated graph shared by the federated tests."""
    from repro.graph.data import make_dataset
    from repro.federated.partition import partition_graph

    g = make_dataset("pubmed", scale=32, seed=0)
    fed = partition_graph(g, 8, alpha=0.5, seed=0)
    return g, fed
