from repro.data.pipeline import TokenPipeline, make_lm_batch

__all__ = ["TokenPipeline", "make_lm_batch"]
