"""Flash attention Pallas kernel: causal / sliding-window, GQA-aware.

Online-softmax blockwise attention (Dao et al.) re-tiled for TPU VMEM: the
(block_q x head_dim) query tile and running (m, l, acc) statistics stay in
VMEM scratch across the sequential kv-block grid dimension; each step is one
MXU (bq x hd)@(hd x bk) matmul plus VPU rescaling. Fully-masked kv blocks
(above the causal diagonal / outside the sliding window) are skipped with
``pl.when`` so local attention costs O(S * window) not O(S^2).

GQA is handled in the BlockSpec index maps: query head h reads kv head
h // (H // Hkv) — no materialised repeat of K/V in HBM.

Layouts: q (BH, S, hd); k, v (BHkv, S, hd). Grid (BH, nq, nk), kv innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, block_q: int, block_k: int, n_kv: int, seq_len: int, window: int | None,
    causal: bool, scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block skip: run the block only if any (q, k) pair in it is unmasked
    if causal:
        live = k_start <= q_start + block_q - 1
        if window is not None:
            live = live & (k_start + block_k - 1 >= q_start - window + 1)
    else:
        live = jnp.bool_(True)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < seq_len                             # mask key padding
        if causal:
            ok &= k_pos <= q_pos
            if window is not None:
                ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "n_q_heads", "seq_len"),
)
def flash_attention_pallas(
    q: jnp.ndarray,   # (BH, Sq, hd) — padded to block multiples
    k: jnp.ndarray,   # (BHkv, Sk, hd)
    v: jnp.ndarray,
    *,
    n_q_heads: int,       # H (per batch) for the GQA index map
    seq_len: int,         # true (unpadded) kv length
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    BHkv = k.shape[0]
    Sk = k.shape[1]
    # q row bh = b * H + h  ->  kv row b * Hkv + h // (H // Hkv)
    H = n_q_heads
    Hkv = BHkv // (BH // H)
    rep = H // Hkv

    def kv_index(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // rep, ki, 0)

    grid = (BH, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, n_kv=grid[2], seq_len=seq_len,
        window=window, causal=causal, scale=hd ** -0.5,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
