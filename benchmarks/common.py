"""Shared benchmark plumbing: dataset/partition caching, CSV emission."""
from __future__ import annotations

import functools
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@functools.lru_cache(maxsize=32)
def fed_setup(dataset: str, scale: int, n_clients: int, alpha_key: str, seed: int = 0):
    """Cached (graph, federated partition). alpha_key: 'iid' or str(alpha)."""
    from repro.graph.data import make_dataset
    from repro.federated.partition import partition_graph

    alpha = None if alpha_key == "iid" else float(alpha_key)
    g = make_dataset(dataset, scale=scale, seed=seed)
    fed = partition_graph(g, n_clients, alpha=alpha, seed=seed)
    return g, fed


def save_rows(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def emit_csv(name: str, rows: list[dict]) -> None:
    """Print 'benchmark,key=value,...' lines — the harness contract."""
    for row in rows:
        parts = ",".join(f"{k}={_fmt(v)}" for k, v in row.items())
        print(f"{name},{parts}")


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timed(fn, *args, repeats: int = 3, **kw):
    """us_per_call for jit'd callables (post-warmup)."""
    import jax
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6
