"""AdamW + SGD in pure JAX, pytree-native.

``state_dtype`` lets large models (llama3-405b on 16 GB v5e chips) keep the
first/second moments in bf16 — see DESIGN.md §6 item 6.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray   # scalar int32
    mu: PyTree          # first moment
    nu: PyTree          # second moment


def adamw_init(params: PyTree, state_dtype=jnp.float32) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(
    grads: PyTree,
    state: AdamState,
    params: PyTree,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.001,
) -> tuple[PyTree, AdamState]:
    """One AdamW step. Returns (new_params, new_state).

    Math is done in fp32 regardless of the storage dtype of moments/params.
    """
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1.0 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1.0 - b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        p32 = p.astype(jnp.float32)
        newp = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, mu=new_mu, nu=new_nu)


def sgd_update(grads: PyTree, params: PyTree, lr) -> PyTree:
    """Plain SGD step (the paper's client-side update, Algorithm 1 line 18)."""
    return jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )
