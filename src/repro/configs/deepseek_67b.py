"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch. [arXiv:2401.02954]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        source="arXiv:2401.02954",
        block_pattern=("attn",),
        activation="silu",
        gated_mlp=True,
        rope_theta=10_000.0,
        max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("deepseek-67b", config, smoke)
