from repro.sharding.fed import (
    CLIENT_AXIS,
    build_sharded_chunk,
    client_axis_of,
    cohort_padding,
    make_client_mesh,
)
from repro.sharding.specs import (
    activation_rules,
    batch_spec,
    decode_state_spec,
    param_spec_tree,
)
from repro.sharding.tables import (
    POD_AXIS,
    build_pod_sharded_chunk,
    make_pod_mesh,
    pad_tables_to_pods,
    pairwise_sum,
    pod_axes_of,
    shard_tables_to_mesh,
)

__all__ = [
    "CLIENT_AXIS",
    "POD_AXIS",
    "activation_rules",
    "batch_spec",
    "build_pod_sharded_chunk",
    "build_sharded_chunk",
    "client_axis_of",
    "cohort_padding",
    "decode_state_spec",
    "make_client_mesh",
    "make_pod_mesh",
    "pad_tables_to_pods",
    "pairwise_sum",
    "param_spec_tree",
    "pod_axes_of",
    "shard_tables_to_mesh",
]
