"""Paper Fig. 7: sensitivity to non-iid degree (Dirichlet alpha) and to the
sample-selection ratio r."""
from __future__ import annotations

from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup


def run(quick: bool = True) -> list[dict]:
    rounds = 10 if quick else 30
    rows = []

    # ---- non-iid degree sweep ----
    alphas = ["0.1", "0.5", "10"] if quick else ["0.05", "0.1", "0.5", "1.0", "10", "100"]
    for a in alphas:
        g, fed = fed_setup("reddit", 96 if quick else 64, 16, a)
        res = FedEngine(g, fed, method_config("fedais", tau0=4),
                        rounds=rounds, clients_per_round=5, seed=0).run()
        rows.append({
            "sweep": "alpha", "value": a,
            "final_acc": round(res.final["acc"] * 100, 2),
            "comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
        })

    # ---- sample ratio sweep ----
    ratios = [0.1, 0.5, 0.9] if quick else [0.1, 0.3, 0.5, 0.7, 0.9]
    g, fed = fed_setup("reddit", 96 if quick else 64, 16, "iid")
    for r in ratios:
        res = FedEngine(g, fed, method_config("fedais", tau0=4, sample_ratio=r),
                        rounds=rounds, clients_per_round=5, seed=0).run()
        rows.append({
            "sweep": "sample_ratio", "value": r,
            "final_acc": round(res.final["acc"] * 100, 2),
            "comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
            "embed_comm_mb": round(res.final["comm_embed_bytes"] / 1e6, 2),
        })
    return rows
