"""Federated runtime: intra-graph partition, clients, server, baselines."""
