"""The paper's five comparison baselines + FedAIS ablations as MethodConfigs.

All methods share the same LocalUpdate machinery (core/fedais.py) with
feature toggles, so the cost/accuracy axes are directly comparable:

    FedAll     all local samples, random neighbor selection, sync every epoch
    FedRandom  random sample batches + random neighbors, sync every epoch
    FedSage+   all samples; ghost features *generated* locally (no embed sync,
               generator params ride the model up/down-link)  [lite variant,
               DESIGN.md §6.3]
    FedPNS     all samples, fixed periodic sync (tau = 2)
    FedGraph   all samples, bandit-learned neighbor fanout    [lite variant,
               DESIGN.md §6.2]
    FedLocal   within-client neighbors only (Fig. 1 reference)
    FedAIS1    importance sampling only (fixed tau)
    FedAIS2    all samples + adaptive sync only
    FedAIS     the full method
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedais import MethodConfig

FANOUT_ACTIONS = (2, 5, 10, 32)


def method_config(name: str, **overrides) -> MethodConfig:
    """Resolve a method name to its MethodConfig via the repro.api registry
    (the presets that used to live here are now registry entries)."""
    from repro.api.registry import method_config as registry_method_config

    return registry_method_config(name, **overrides)


ALL_BASELINES = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph")


# ---------------------------------------------------------------------------
# FedSage+ lite: local ghost-feature generator
# ---------------------------------------------------------------------------

def ghost_reverse_map(fed, max_rev: int = 8):
    """(K, g_max, R) own-rows adjacent to each ghost + mask — the structural
    context the generator conditions on."""
    K, n_max, D = fed.nbr_idx.shape
    g_max = fed.g_max
    rev = np.zeros((K, g_max, max_rev), np.int32)
    rev_mask = np.zeros((K, g_max, max_rev), np.float32)
    fill = np.zeros((K, g_max), np.int32)
    for k in range(K):
        rows, slots = np.where(fed.nbr_idx[k] >= n_max)
        for r, s_col in zip(rows, slots):
            if fed.nbr_mask[k, r, s_col] == 0:
                continue
            s = fed.nbr_idx[k, r, s_col] - n_max
            if fill[k, s] < max_rev:
                rev[k, s, fill[k, s]] = r
                rev_mask[k, s, fill[k, s]] = 1.0
                fill[k, s] += 1
    return rev, rev_mask


def generator_init(key, n_feat: int, hidden: int = 64):
    k1, k2 = jax.random.split(key)
    s1 = (2.0 / (n_feat + hidden)) ** 0.5
    s2 = (2.0 / (hidden + n_feat)) ** 0.5
    return {
        "w1": jax.random.normal(k1, (n_feat, hidden), jnp.float32) * s1,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, n_feat), jnp.float32) * s2,
        "b2": jnp.zeros((n_feat,), jnp.float32),
    }


def generator_apply(gp, ctx):
    """Refine a neighborhood-mean context vector into a feature estimate."""
    h = jax.nn.relu(ctx @ gp["w1"] + gp["b1"])
    return ctx + h @ gp["w2"] + gp["b2"]      # residual refinement


def generator_train_step(gp, feats, nbr_idx, nbr_mask, node_mask, lr=1e-2):
    """Self-supervised: reconstruct own features from own neighborhood mean
    (that is exactly the task the generator performs for ghosts)."""

    def loss_fn(gp):
        own = nbr_mask * (nbr_idx < feats.shape[0])
        gathered = feats[jnp.minimum(nbr_idx, feats.shape[0] - 1)] * own[..., None]
        deg = jnp.maximum(own.sum(-1, keepdims=True), 1.0)
        ctx = gathered.sum(1) / deg
        pred = generator_apply(gp, ctx)
        err = jnp.square(pred - feats).sum(-1) * node_mask
        return err.sum() / jnp.maximum(node_mask.sum(), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(gp)
    gp = jax.tree_util.tree_map(lambda p, g: p - lr * g, gp, grads)
    return gp, loss


def generator_impute(gp, feats, rev, rev_mask, ghost_mask):
    """Predict ghost features from reverse-neighborhood means (one client)."""
    gathered = feats[rev] * rev_mask[..., None]
    deg = jnp.maximum(rev_mask.sum(-1, keepdims=True), 1.0)
    ctx = gathered.sum(1) / deg
    return generator_apply(gp, ctx) * ghost_mask[:, None]


def generator_param_count(n_feat: int, hidden: int = 64) -> int:
    return n_feat * hidden + hidden + hidden * n_feat + n_feat


# ---------------------------------------------------------------------------
# FedGraph lite: epsilon-greedy fanout bandit
# ---------------------------------------------------------------------------

class FanoutBandit:
    """Per-client epsilon-greedy bandit over neighbor-fanout actions; reward
    is the per-round local-loss improvement (the DRL policy of FedGraph
    collapsed to its decision variable; DESIGN.md §6.2)."""

    def __init__(self, n_clients: int, seed: int = 0, eps: float = 0.2):
        self.q = np.zeros((n_clients, len(FANOUT_ACTIONS)), np.float64)
        self.n = np.zeros((n_clients, len(FANOUT_ACTIONS)), np.int64)
        self.rng = np.random.default_rng(seed)
        self.eps = eps
        self.last_action = np.zeros(n_clients, np.int64)

    def choose(self, k: int) -> int:
        if self.rng.random() < self.eps or self.n[k].sum() == 0:
            a = self.rng.integers(len(FANOUT_ACTIONS))
        else:
            a = int(np.argmax(self.q[k]))
        self.last_action[k] = a
        return FANOUT_ACTIONS[a]

    def update(self, k: int, reward: float) -> None:
        a = self.last_action[k]
        self.n[k, a] += 1
        self.q[k, a] += (reward - self.q[k, a]) / self.n[k, a]
