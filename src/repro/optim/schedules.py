"""Learning-rate schedules as plain callables step -> lr (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def schedule(step):
        return jnp.asarray(lr, jnp.float32)

    return schedule


def cosine_decay(lr: float, decay_steps: int, final_ratio: float = 0.1):
    def schedule(step):
        frac = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * (final_ratio + (1.0 - final_ratio) * cos)

    return schedule


def linear_warmup_cosine(lr: float, warmup_steps: int, decay_steps: int, final_ratio: float = 0.1):
    cos = cosine_decay(lr, max(1, decay_steps - warmup_steps), final_ratio)

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(1, warmup_steps)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return schedule
