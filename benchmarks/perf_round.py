"""Round-throughput benchmark: fused scanned executor vs stepwise loop.

The figure of merit is training-round throughput (rounds/s) of the
SyncScheduler hot path — the number every selector/method sweep pays per
grid point. The fused executor runs every round between eval boundaries as
one donated ``lax.scan`` XLA call; the stepwise loop pays per-round
dispatch, eager aggregation/write-back copies of the (K, n_tot, H1) tables,
and a host sync for cost accounting. The eval-side hot spot (full-graph
forward, O(N*K*F) per eval) is timed per aggregation backend alongside, and
so is the *training*-path backend swap: ``train_segment`` re-times the fused
executor with ``train_backend="segment"`` (gated: the in-trace bucketed-CSR
aggregation must not lose to the gather reference it replaces) and
``train_spmm`` records the Pallas-kernel path at a reduced round count
(interpret mode off-TPU — never gated).

Writes ``BENCH_round.json`` at the repo root (the perf trajectory seed) and
``benchmarks/results/perf_round.json``. Exits non-zero from the CLI if the
fused executor is not faster than stepwise — the CI perf-smoke gate.
``--sharded`` additionally times the client-sharded fused executor over all
visible devices and records ``sharded_rounds_per_s`` (no gate: CPU shard_map
collective overhead may not win at quick shapes; the column tracks it).
``--sharded-only`` measures just that and merges it into the existing
BENCH_round.json without touching the gated single-device rows — so a
forced-multi-device rerun never overwrites the gate's own trajectory.
``--quant-ablation`` trains the same configuration at every embedding-wire
dtype (repro.federated.quant: fp32/bf16/int8) x tau grid point and merges
accuracy-vs-bytes rows under the same discipline: plain and sharded-only
runs carry the ablation rows forward, ablation runs never touch the gated
rows or the sharded column.

    PYTHONPATH=src python -m benchmarks.perf_round --quick
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.perf_round --quick --sharded-only
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit_csv, fed_setup, save_rows
from repro.federated.quant import SYNC_DTYPES, wire_bytes

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# BENCH_round.json schema: the perf-smoke gate and the forward-merge logic
# (plain runs carry the sharded column + quant rows, sharded-only and
# quant-ablation runs keep the gated rows) all rewrite the file, so
# malformed payloads would otherwise propagate silently until a CI failure
# nobody can diagnose.
_TOP_KEYS = ("bench", "backend", "devices", "quick", "fused_speedup",
             "sharded_rounds_per_s", "sharded_devices", "rows")
_GATED_VARIANTS = ("stepwise", "fused")
# tau grid for the accuracy-vs-bytes ablation: a tight schedule that syncs
# often (the quantized wire works hardest) and the paper-default loose one
_QUANT_TAUS = (2, 8)


def validate_bench_round(payload, *, require_gated: bool = True) -> list[str]:
    """Schema-check a BENCH_round.json payload. Returns a list of problems
    (empty = valid): required keys present and typed, every row labelled
    with a variant, the gated single-device rows not silently nulled or
    dropped, and the sharded column's value/device-count consistent.
    ``require_gated=False`` permits a payload without the stepwise/fused
    rows — only legitimate for a fresh ``--sharded-only`` run with no
    previous BENCH_round.json to merge the gated rows from."""
    errs: list[str] = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected dict"]
    for k in _TOP_KEYS:
        if k not in payload:
            errs.append(f"missing key {k!r}")
    if errs:
        return errs
    if payload["bench"] != "round_throughput":
        errs.append(f"bench is {payload['bench']!r}, "
                    "expected 'round_throughput'")
    if not isinstance(payload["devices"], int) or payload["devices"] < 1:
        errs.append(f"devices must be a positive int, got {payload['devices']!r}")
    if not isinstance(payload["quick"], bool):
        errs.append(f"quick must be a bool, got {payload['quick']!r}")
    rows = payload["rows"]
    if not isinstance(rows, list) or not rows:
        return errs + ["rows must be a non-empty list"]
    by_variant: dict = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or not isinstance(row.get("variant"), str):
            errs.append(f"rows[{i}] has no string 'variant'")
            continue
        by_variant[row["variant"]] = row
    # the gated payload: stepwise + fused rows with real throughput numbers
    # and a non-null speedup — a merge that nulls any of these broke the gate
    for v in _GATED_VARIANTS:
        row = by_variant.get(v)
        if row is None:
            if require_gated:
                errs.append(f"gated row {v!r} missing")
        elif not isinstance(row.get("rounds_per_s"), (int, float)) \
                or not row["rounds_per_s"] > 0:
            errs.append(f"gated row {v!r} has no positive rounds_per_s "
                        f"(got {row.get('rounds_per_s')!r})")
    if all(v in by_variant for v in _GATED_VARIANTS):
        sp = payload["fused_speedup"]
        if not isinstance(sp, (int, float)) or not sp > 0:
            errs.append("fused_speedup nulled while gated rows exist "
                        f"(got {sp!r})")
    srps, sdev = payload["sharded_rounds_per_s"], payload["sharded_devices"]
    if srps is not None and (not isinstance(srps, (int, float)) or not srps > 0):
        errs.append(f"sharded_rounds_per_s must be None or positive, got {srps!r}")
    if (srps is None) != (sdev is None):
        errs.append("sharded_rounds_per_s and sharded_devices must be "
                    f"nulled together (got {srps!r} / {sdev!r})")
    if sdev is not None and (not isinstance(sdev, int) or sdev < 1):
        errs.append(f"sharded_devices must be None or a positive int, got {sdev!r}")
    # quant_ablation rows (accuracy vs wire bytes per sync dtype x tau):
    # each must carry a valid dtype/tau, an accuracy in [0, 1], and wire
    # bytes that never exceed the fp32 nominal (equal at fp32) — and every
    # tau that appears must include its fp32 baseline, or the reduction
    # column has nothing to be relative to
    q_taus: set = set()
    fp32_taus: set = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or row.get("variant") != "quant_ablation":
            continue
        d, tau = row.get("sync_dtype"), row.get("tau")
        if d not in SYNC_DTYPES:
            errs.append(f"rows[{i}]: quant_ablation sync_dtype must be one "
                        f"of {SYNC_DTYPES}, got {d!r}")
        if not isinstance(tau, int) or tau < 1:
            errs.append(f"rows[{i}]: quant_ablation tau must be a positive "
                        f"int, got {tau!r}")
        else:
            q_taus.add(tau)
            if d == "fp32":
                fp32_taus.add(tau)
        acc = row.get("test_acc")
        if not isinstance(acc, (int, float)) or not 0.0 <= acc <= 1.0:
            errs.append(f"rows[{i}]: quant_ablation test_acc must be in "
                        f"[0, 1], got {acc!r}")
        wb, fb = row.get("embed_wire_bytes"), row.get("embed_fp32_bytes")
        if not isinstance(wb, (int, float)) or not isinstance(fb, (int, float)) \
                or wb < 0 or fb < 0 or wb > fb:
            errs.append(f"rows[{i}]: quant_ablation needs "
                        f"0 <= embed_wire_bytes <= embed_fp32_bytes, got "
                        f"{wb!r} / {fb!r}")
        elif d == "fp32" and wb != fb:
            errs.append(f"rows[{i}]: fp32 quant_ablation wire bytes must "
                        f"equal the nominal ({wb!r} != {fb!r})")
    for tau in sorted(q_taus - fp32_taus):
        errs.append(f"quant_ablation rows at tau={tau} lack the fp32 "
                    "baseline row")
    # train-backend rows: train_segment carries the gated speedup-vs-gather
    # column, train_spmm is recorded only — both need real throughput
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        v = row.get("variant")
        if v in ("train_segment", "train_spmm") and (
                not isinstance(row.get("rounds_per_s"), (int, float))
                or not row["rounds_per_s"] > 0):
            errs.append(f"rows[{i}]: {v} has no positive rounds_per_s "
                        f"(got {row.get('rounds_per_s')!r})")
        if v == "train_segment":
            sp = row.get("speedup_vs_gather")
            if not isinstance(sp, (int, float)) or not sp > 0:
                errs.append(f"rows[{i}]: train_segment needs a positive "
                            f"speedup_vs_gather (got {sp!r})")
    return errs


def _load_prev(bench_path: str):
    try:
        with open(bench_path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _write_payload(bench_path: str, payload: dict, prev) -> None:
    """Validate-then-write BENCH_round.json; gated rows are demanded
    whenever this payload or the previous one carried them (a merge must
    never drop them)."""
    prev_gated = prev is not None and any(
        isinstance(r, dict) and r.get("variant") in _GATED_VARIANTS
        for r in prev.get("rows", []))
    has_gated = any(isinstance(r, dict) and r.get("variant") in _GATED_VARIANTS
                    for r in payload.get("rows", []))
    problems = validate_bench_round(payload,
                                    require_gated=has_gated or prev_gated)
    if problems:
        raise ValueError(
            "refusing to write a malformed BENCH_round.json:\n  "
            + "\n  ".join(problems))
    with open(bench_path, "w") as f:
        json.dump(payload, f, indent=1)


def run_quant_ablation(quick: bool = True) -> list[dict]:
    """The accuracy-vs-bytes ablation: train the same FedAIS configuration
    at every wire dtype x tau grid point and record the final accuracy next
    to the embedding-sync bytes the codec actually moves (per pulled ghost:
    one (F,) feature row + one (H1,) hist1 row, each with its own int8
    scale — ``repro.federated.quant.wire_bytes``). Rows merge into
    BENCH_round.json without touching the gated single-device rows or the
    carried sharded column (the ``--sharded-only`` discipline)."""
    from repro.api import FedEngine, method_config
    from repro.models.gcn import HIDDEN

    ds = "pubmed"
    scale = 16 if quick else 8
    n_clients = 256
    m = 4 if quick else 8
    rounds = 20 if quick else 40
    g, fed = fed_setup(ds, scale, n_clients, "0.5")
    F, H1 = g.n_features, HIDDEN[0]
    nominal_row = (F + H1) * 4          # client_embed_bytes' fp32 pricing
    rows = []
    for tau in _QUANT_TAUS:
        for d in SYNC_DTYPES:
            res = FedEngine(g, fed, method_config("fedais", tau0=tau),
                            rounds=rounds, clients_per_round=m, seed=0,
                            eval_every=rounds, sync_dtype=d).run()
            embed_fp32 = float(res.history["comm_embed"][-1])
            wire_row = wire_bytes((1, F), d) + wire_bytes((1, H1), d)
            wire = embed_fp32 / nominal_row * wire_row
            row = {
                "variant": "quant_ablation",
                "sync_dtype": d,
                "tau": tau,
                "rounds": rounds,
                "clients": n_clients,
                "cohort": m,
                "test_acc": float(res.history["test_acc"][-1]),
                "embed_fp32_bytes": embed_fp32,
                "embed_wire_bytes": wire,
                "wire_reduction": round(nominal_row / wire_row, 2),
            }
            rows.append(row)
            print(f"# quant_ablation tau={tau} {d}: "
                  f"acc={row['test_acc']:.4f} "
                  f"wire={wire:,.0f}B ({row['wire_reduction']}x)")

    bench_path = os.path.join(REPO_ROOT, "BENCH_round.json")
    prev = _load_prev(bench_path)
    if prev is not None:
        # keep the gated stepwise/fused rows, the eval rows, and the
        # sharded column untouched; replace only the ablation rows
        payload = dict(prev)
        payload["rows"] = [r for r in prev.get("rows", [])
                           if not (isinstance(r, dict)
                                   and r.get("variant") == "quant_ablation")]
        payload["rows"] += rows
    else:
        payload = {
            "bench": "round_throughput",
            "backend": jax.default_backend(),
            "devices": jax.device_count(),
            "quick": quick,
            "fused_speedup": None,
            "sharded_rounds_per_s": None,
            "sharded_devices": None,
            "rows": rows,
        }
    _write_payload(bench_path, payload, prev)
    return rows


def _time_run(make_engine, repeats: int = 3) -> float:
    """Median wall-clock of a full engine.run() after compile warmups."""
    eng = make_engine()
    eng.run()                                   # warmup 1: compiles
    eng.run()                                   # warmup 2: allocator settles
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.run()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def run(quick: bool = True, sharded: bool = False,
        sharded_only: bool = False) -> list[dict]:
    from repro.api import FedEngine, SyncScheduler, method_config
    from repro.federated.server import build_eval_graph, evaluate_global
    from repro.models.gcn import AGG_BACKENDS, gcn_init

    # Cross-device regime: many clients, small sampled cohort. The stepwise
    # loop's per-round cost is dominated by the eager full-table copies
    # (hist1/age/ghost_feat scale with K, not with the cohort), which is
    # exactly what the donated scanned executor eliminates.
    ds = "pubmed"
    scale = 16 if quick else 8
    n_clients = 256
    m = 4 if quick else 8
    rounds = 20 if quick else 40
    g, fed = fed_setup(ds, scale, n_clients, "0.5")
    mcfg = method_config("fedais", tau0=4)

    # eval only at the scan boundaries (round 0 + last): both variants pay
    # the same two server evals, so the delta is pure round-loop overhead
    def make(fused):
        return FedEngine(g, fed, mcfg, rounds=rounds, clients_per_round=m,
                         seed=0, eval_every=rounds,
                         scheduler=SyncScheduler(fused=fused))

    # sharded-only mode (the CI multi-device step) measures just the sharded
    # variant plus an in-env fused reference, and merges the sharded column
    # into BENCH_round.json without touching the gated single-device
    # stepwise/fused rows — a forced-8-device rerun must not overwrite the
    # perf trajectory the gate actually ran in.
    sharded = sharded or sharded_only
    rows = []
    secs = {}
    variants = [("fused", True)] if sharded_only else \
        [("stepwise", False), ("fused", True)]
    for name, fused in variants:
        dt = _time_run(lambda: make(fused))
        secs[name] = dt
        rows.append({
            "variant": name,
            "rounds": rounds,
            "clients": n_clients,
            "cohort": m,
            "rounds_per_s": rounds / dt,
            "ms_per_round": dt / rounds * 1e3,
        })
    if sharded_only:
        speedup = None          # no stepwise baseline measured: nothing to gate
    else:
        speedup = secs["stepwise"] / secs["fused"]
        rows[1]["speedup_vs_stepwise"] = speedup

    # ---- client-sharded fused executor (the multi-device scale-out path) ----
    # Recorded, never gated: CPU shard_map pays per-round collective overhead
    # that quick shapes don't amortize — the column tracks the trend.
    sharded_rps = None
    if sharded:
        n_dev = jax.device_count()
        if n_dev < 2:
            print("# sharded: skipped (one device; force more with "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        else:
            from repro.sharding.fed import make_client_mesh

            mesh = make_client_mesh()

            def make_sharded():
                return FedEngine(g, fed, mcfg, rounds=rounds,
                                 clients_per_round=m, seed=0,
                                 eval_every=rounds, mesh=mesh,
                                 scheduler=SyncScheduler(fused=True))

            probe = make_sharded()
            probe.run()
            assert probe.last_executor == "sharded_fused", probe.last_executor
            dt = _time_run(make_sharded)
            sharded_rps = rounds / dt
            rows.append({
                "variant": "sharded_fused",
                "devices": n_dev,
                "rounds": rounds,
                "clients": n_clients,
                "cohort": m,
                "rounds_per_s": sharded_rps,
                "ms_per_round": dt / rounds * 1e3,
                "speedup_vs_fused": secs["fused"] / dt,
            })

    # ---- training-path aggregation backends (the LocalUpdate hot loop) ----
    # train_segment re-times the fused executor with the in-trace
    # bucketed-CSR segment backend; its speedup_vs_gather column is the CI
    # perf-smoke gate (the backend replaced gather as the recommended
    # training path, so losing to it is a regression). train_spmm rides the
    # Pallas kernel in interpret mode off-TPU — recorded at a reduced round
    # count, never gated (the number is only meaningful compiled on-device).
    if not sharded_only:
        def make_backend(be, r):
            return FedEngine(g, fed, mcfg, rounds=r, clients_per_round=m,
                             seed=0, eval_every=r,
                             scheduler=SyncScheduler(fused=True),
                             train_backend=be)

        dt = _time_run(lambda: make_backend("segment", rounds))
        rows.append({
            "variant": "train_segment",
            "rounds": rounds,
            "clients": n_clients,
            "cohort": m,
            "rounds_per_s": rounds / dt,
            "ms_per_round": dt / rounds * 1e3,
            "speedup_vs_gather": secs["fused"] / dt,
        })
        spmm_rounds = 2
        eng = make_backend("spmm", spmm_rounds)
        eng.run()                               # warmup: compiles
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        rows.append({
            "variant": "train_spmm",
            "rounds": spmm_rounds,
            "clients": n_clients,
            "cohort": m,
            "rounds_per_s": spmm_rounds / dt,
            "ms_per_round": dt / spmm_rounds * 1e3,
        })

    # ---- eval aggregation backends (the per-round server-side hot spot) ----
    params = gcn_init(jax.random.PRNGKey(0), g.n_features, g.n_classes)
    for be in AGG_BACKENDS if not sharded_only else ():
        eg = build_eval_graph(g, backend=be)
        evaluate_global(params, eg, "test")     # warmup/compile
        t0 = time.perf_counter()
        n_reps = 5
        for _ in range(n_reps):
            evaluate_global(params, eg, "test")
        rows.append({
            "variant": f"eval_{be}",
            "ms_per_eval": (time.perf_counter() - t0) / n_reps * 1e3,
        })

    bench_path = os.path.join(REPO_ROOT, "BENCH_round.json")
    sharded_devices = jax.device_count() if sharded_rps is not None else None
    prev = _load_prev(bench_path)
    if sharded_rps is None and prev is not None:
        # a non-sharded run must not erase the recorded sharded column —
        # carry the previous measurement forward (scalar, device count, AND
        # its sharded_fused row, so the ms_per_round/device provenance
        # travels with the number) instead of nulling it
        sharded_rps = prev.get("sharded_rounds_per_s")
        sharded_devices = prev.get("sharded_devices")
        rows += [r for r in prev.get("rows", [])
                 if isinstance(r, dict) and r.get("variant") == "sharded_fused"]
    if not sharded_only and prev is not None:
        # likewise the quant_ablation rows: the gate and sharded reruns
        # never measure them, so they travel forward untouched
        rows += [r for r in prev.get("rows", [])
                 if isinstance(r, dict) and r.get("variant") == "quant_ablation"]
    if sharded_only and prev is not None:
        # merge: update only the sharded column + row, keep the gated
        # single-device payload (fused_speedup, stepwise/fused/eval/quant
        # rows)
        payload = dict(prev,
                       sharded_rounds_per_s=sharded_rps,
                       sharded_devices=sharded_devices)
        payload["rows"] = (
            [r for r in prev.get("rows", []) if r.get("variant") != "sharded_fused"]
            + [r for r in rows if r["variant"] == "sharded_fused"])
    else:
        payload = {
            "bench": "round_throughput",
            "backend": jax.default_backend(),
            "devices": jax.device_count(),
            "quick": quick,
            "fused_speedup": speedup,
            "sharded_rounds_per_s": sharded_rps,
            "sharded_devices": sharded_devices,
            "rows": rows,
        }
    _write_payload(bench_path, payload, prev)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    ap.add_argument("--sharded", action="store_true",
                    help="also time the client-sharded fused executor over "
                         "all devices (recorded in BENCH_round.json, no gate)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="time ONLY the sharded executor (+ an in-env fused "
                         "reference) and merge the sharded column into "
                         "BENCH_round.json, leaving the gated single-device "
                         "rows untouched — the CI multi-device step")
    ap.add_argument("--no-gate", action="store_true",
                    help="record only, never fail on fused < stepwise (for "
                         "runs in environments the gate was not calibrated "
                         "for, e.g. forced multi-device CPU)")
    ap.add_argument("--quant-ablation", action="store_true",
                    help="run ONLY the accuracy-vs-bytes wire-format "
                         "ablation (sync dtype x tau) and merge its rows "
                         "into BENCH_round.json, leaving the gated rows and "
                         "the sharded column untouched")
    args = ap.parse_args()
    if args.quant_ablation:
        rows = run_quant_ablation(quick=args.quick)
        emit_csv("perf_round_quant", rows)
        save_rows("perf_round_quant", rows)
        return 0
    rows = run(quick=args.quick, sharded=args.sharded,
               sharded_only=args.sharded_only)
    emit_csv("perf_round", rows)
    save_rows("perf_round", rows)
    speedup = next((r["speedup_vs_stepwise"] for r in rows
                    if r.get("speedup_vs_stepwise") is not None), None)
    if speedup is None:
        return 0                # sharded-only: nothing measured to gate
    print(f"# fused speedup vs stepwise: {speedup:.2f}x")
    if speedup < 1.0 and not args.no_gate:
        print("# FAIL: fused executor slower than the step-by-step loop")
        return 1
    seg = next((r for r in rows if r.get("variant") == "train_segment"), None)
    if seg is not None:
        print("# segment training backend speedup vs gather: "
              f"{seg['speedup_vs_gather']:.2f}x")
        # the two variants differ only in the batch aggregation — a small
        # slice of the fused round — so the honest win is a few percent and
        # the gate needs tolerance for timer jitter; a real regression
        # (e.g. losing the in-trace CSR derivation to a host re-bucketing)
        # costs far more than 5%
        if seg["speedup_vs_gather"] < 0.95 and not args.no_gate:
            print("# FAIL: segment training backend measurably slower "
                  "than gather")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
