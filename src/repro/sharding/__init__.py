from repro.sharding.fed import (
    CLIENT_AXIS,
    build_sharded_chunk,
    client_axis_of,
    cohort_padding,
    make_client_mesh,
)
from repro.sharding.specs import (
    activation_rules,
    batch_spec,
    decode_state_spec,
    param_spec_tree,
)

__all__ = [
    "CLIENT_AXIS",
    "activation_rules",
    "batch_spec",
    "build_sharded_chunk",
    "client_axis_of",
    "cohort_padding",
    "decode_state_spec",
    "make_client_mesh",
    "param_spec_tree",
]
