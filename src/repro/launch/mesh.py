"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def production_mesh_shape(*, multi_pod: bool = False) -> tuple:
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    return (2, 16, 16) if multi_pod else (16, 16)


def production_chip_count(*, multi_pod: bool = False) -> int:
    n = 1
    for v in production_mesh_shape(multi_pod=multi_pod):
        n *= v
    return n


def make_production_mesh(*, multi_pod: bool = False):
    shape = production_mesh_shape(multi_pod=multi_pod)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over however many real devices exist (CPU tests)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def mesh_label(mesh) -> str:
    return "x".join(str(v) for v in mesh.shape.values())
