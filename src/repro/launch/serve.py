"""Deprecated shim — the LM serving driver moved to
``repro.launch.serve_lm_cli`` so that ``python -m repro.launch.serve_fed``
(the federated GCN server, repro/serve) vs the LM stack is unambiguous.

    PYTHONPATH=src python -m repro.launch.serve_lm_cli ...   # LM prefill/decode
    PYTHONPATH=src python -m repro.launch.serve_fed ...      # federated GCN
"""
from __future__ import annotations

import warnings

from repro.launch.serve_lm_cli import main, serve  # noqa: F401

warnings.warn(
    "repro.launch.serve is deprecated: the LM driver is now "
    "repro.launch.serve_lm_cli (the federated GCN server is "
    "repro.launch.serve_fed)",
    DeprecationWarning,
    stacklevel=2,
)

if __name__ == "__main__":
    main()
