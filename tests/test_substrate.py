"""Substrate tests: optimizer, checkpointing, data pipeline, utils, sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import st  # hypothesis strategies, or a skip-stub when absent

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data.pipeline import TokenPipeline, make_lm_batch
from repro.optim import adamw_init, adamw_update, sgd_update
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.utils.hlo import collective_stats
from repro.utils.roofline import RooflineReport
from repro.utils.tree import (
    global_norm_clip,
    tree_bytes,
    tree_count_params,
    tree_isfinite,
    tree_l2_norm,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["x"]))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, 0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments():
    params = {"x": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params, jnp.bfloat16)
    assert opt.mu["x"].dtype == jnp.bfloat16
    g = {"x": jnp.ones((4,), jnp.bfloat16)}
    p2, o2 = adamw_update(g, opt, params, 0.1)
    assert p2["x"].dtype == jnp.bfloat16
    assert bool(tree_isfinite(p2))


def test_sgd_direction():
    p = {"x": jnp.asarray([1.0])}
    g = {"x": jnp.asarray([2.0])}
    out = sgd_update(g, p, 0.5)
    np.testing.assert_allclose(np.asarray(out["x"]), [0.0])


def test_schedules():
    assert float(constant(0.1)(jnp.asarray(5))) == pytest.approx(0.1)
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)
    wu = linear_warmup_cosine(1.0, 10, 100)
    assert float(wu(jnp.asarray(5))) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    restored = load_checkpoint(d, 7, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_shape_mismatch_raises(tmp_path, key):
    tree = {"a": jnp.zeros((2, 2))}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, tree)
    with pytest.raises(ValueError):
        load_checkpoint(d, 1, {"a": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    p = TokenPipeline(1024, 32, 4, seed=1)
    a = p.batch(10)["tokens"]
    b = p.batch(10)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = p.batch(11)["tokens"]
    assert not np.array_equal(a, c)


def test_pipeline_learnable_structure():
    """The Markov stream must be predictable: transition entropy << uniform."""
    p = TokenPipeline(256, 64, 8, seed=0, noise_prob=0.0, markov_states=16)
    toks = p.batch(0)["tokens"] % 16
    trans = np.zeros((16, 16))
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            trans[a, b] += 1
    trans = trans / np.maximum(trans.sum(-1, keepdims=True), 1)
    ent = -(trans * np.log(np.maximum(trans, 1e-12))).sum(-1).mean()
    assert ent < 0.9 * np.log(16)


def test_make_lm_batch_shift():
    p = TokenPipeline(128, 16, 2, seed=0)
    b = make_lm_batch(p, 0)
    raw = p.batch(0)["tokens"]
    np.testing.assert_array_equal(np.asarray(b["tokens"]), raw[:, :-1])
    np.testing.assert_array_equal(np.asarray(b["labels"]), raw[:, 1:])


# ---------------------------------------------------------------------------
# utils
# ---------------------------------------------------------------------------

def test_tree_helpers(key):
    tree = {"a": jnp.ones((3, 4)), "b": jnp.ones((2,))}
    assert tree_count_params(tree) == 14
    assert tree_bytes(tree) == 14 * 4
    assert float(tree_l2_norm(tree)) == pytest.approx(np.sqrt(14))
    clipped, norm = global_norm_clip(tree, 1.0)
    assert float(tree_l2_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_collective_stats_parser():
    hlo = """
  %ag = bf16[16,4096,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %ars = f32[8,128]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[32,64]{1,0} all-to-all(%w), dimensions={0}
  %cp = u32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot1 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}
"""
    s = collective_stats(hlo)
    assert s.count_by_kind["all-gather"] == 1
    assert s.bytes_by_kind["all-gather"] == 16 * 4096 * 512 * 2
    assert s.bytes_by_kind["all-reduce"] == 1024 * 4
    assert s.total_count == 5


def test_collective_stats_start_done_not_double_counted():
    hlo = """
  %ag0 = bf16[128]{0} all-gather-start(%x)
  %ag1 = bf16[128]{0} all-gather-done(%ag0)
"""
    s = collective_stats(hlo)
    assert s.count_by_kind["all-gather"] == 1


def test_roofline_report_terms():
    r = RooflineReport(arch="x", shape="train_4k", mesh="pod1", chips=256,
                       hlo_flops=256 * 197e12,        # exactly 1s compute
                       hlo_bytes=256 * 819e9 * 0.5,   # 0.5s memory
                       collective_bytes=256 * 50e9 * 0.25,
                       model_flops=256 * 197e12 * 0.8)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.dominant == "compute"
    assert r.mfu_upper_bound == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# sharding specs (pure logic; no devices needed)
# ---------------------------------------------------------------------------

def test_param_specs_shard_big_dims():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import param_spec_tree
    if len(jax.devices()) != 1:
        pytest.skip("expects single-device CPU")
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    params = {
        "embed": Leaf((1024, 64)),
        "units": {"b0": {"attn": {"wq": Leaf((8, 64, 64)), "ln": {"scale": Leaf((64,))}}}},
    }
    specs = param_spec_tree(params, mesh, fsdp=False)
    assert specs["embed"] == P("model", None)
    assert specs["units"]["b0"]["attn"]["wq"] == P(None, None, "model")
    assert specs["units"]["b0"]["attn"]["ln"]["scale"] == P(None)
