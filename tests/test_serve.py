"""repro.serve: checkpoint-backed online inference.

Pins the subsystem's contracts:

* **checkpoint round-trip parity** — a trained federation saved with
  ``save_federation`` and restored into a ``ServedModel`` serves logits
  bit-identical to the training-side eval path (``build_eval_graph`` ->
  ``_eval_logits``) under ``cache_policy="historical"``;
* **no recompiles after warmup** — any query mix after ``warmup()`` reuses
  the pre-jitted bucket shapes (``trace_count`` probe);
* **exact 1-hop invalidation** — streaming updates dirty precisely the
  mutated rows' layer-1 cache entries, and a background refresh restores
  historical/fresh agreement bit-for-bit;
* the checkpoint-layer satellites (atomic tmp cleanup, writable loaded
  arrays, ``load_latest``).
"""
import os

import numpy as np
import pytest

from repro.checkpoint import (
    latest_step,
    load_checkpoint,
    load_latest,
    save_checkpoint,
)
from repro.serve import (
    CapacityError,
    GraphStore,
    QueryEngine,
    ServedModel,
    save_federation,
)

TRAIN_ROUNDS = 2


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """A small trained + checkpointed federation: (graph, fed, state, dir)."""
    from repro.api import FedEngine, method_config
    from repro.federated.partition import partition_graph
    from repro.graph.data import make_dataset

    g = make_dataset("pubmed", scale=32, seed=0)
    fed = partition_graph(g, 4, alpha=0.5, seed=0)
    engine = FedEngine(g, fed, method_config("fedais", tau0=2),
                       rounds=TRAIN_ROUNDS, clients_per_round=2, seed=0,
                       eval_every=TRAIN_ROUNDS)
    state = engine.init_state()
    engine.run(state)
    ckpt_dir = str(tmp_path_factory.mktemp("fed_ckpt"))
    save_federation(ckpt_dir, TRAIN_ROUNDS, state)
    return g, fed, state, ckpt_dir


def restore_engine(trained, backend="segment", warm="refresh", **kw):
    g, fed, _, ckpt_dir = trained
    model = ServedModel.restore(ckpt_dir, g, fed, backend=backend, warm=warm,
                                seed=0)
    return model, QueryEngine(model, **kw)


def eval_logits_reference(trained, backend):
    """The training-side eval path the served logits must match bitwise."""
    from repro.federated.server import _eval_logits, build_eval_graph

    g, fed, state, _ = trained
    eg = build_eval_graph(g, max_deg=fed.max_deg, seed=0, backend=backend)
    return np.asarray(_eval_logits(
        state.params, eg["features"], eg["nbr_idx"], eg["nbr_mask"],
        csr=eg.get("csr"), adj=eg.get("adj"), backend=backend))


# ---------------------------------------------------------------------------
# the acceptance invariant: checkpoint round-trip bit-parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["segment", "gather"])
def test_roundtrip_served_logits_bit_identical(trained, backend):
    g = trained[0]
    model, engine = restore_engine(trained, backend=backend)
    engine.warmup()
    want = eval_logits_reference(trained, backend)
    n = g.features.shape[0]
    for policy in ("historical", "fresh"):
        got = np.concatenate([
            engine.query(np.arange(i, min(i + 100, n)), policy=policy)
            for i in range(0, n, 100)])
        assert got.shape == want.shape
        assert np.array_equal(got, want), \
            f"{backend}/{policy}: served logits differ from eval path"
    assert model.restored_step == TRAIN_ROUNDS


def test_restore_autopicks_latest_step(trained):
    g, fed, state, ckpt_dir = trained
    model = ServedModel.restore(ckpt_dir, g, fed, seed=0)
    assert model.restored_step == latest_step(ckpt_dir) == TRAIN_ROUNDS
    # the training-time staleness diagnostics ride along, in global order
    assert model.table_age is not None
    assert model.table_age.shape == (g.features.shape[0],)
    s = model.summary()
    assert s["valid_frac"] == 1.0 and s["restored_step"] == TRAIN_ROUNDS


def test_warm_tables_uses_checkpointed_rows(trained):
    from repro.serve.model import _scatter_tables

    g, fed, state, ckpt_dir = trained
    model = ServedModel.restore(ckpt_dir, g, fed, warm="tables", seed=0)
    want = _scatter_tables(fed, state.hist.hist1)
    n = g.features.shape[0]
    assert np.array_equal(np.asarray(model.h1)[:n], want)
    assert model.valid[:n].all()


# ---------------------------------------------------------------------------
# no recompiles after warmup (the jit-stable micro-batching contract)
# ---------------------------------------------------------------------------

def test_no_recompile_after_warmup(trained):
    model, engine = restore_engine(trained)
    baseline = engine.warmup()
    assert baseline == engine.trace_count_after_warmup > 0
    rng = np.random.default_rng(0)
    n = model.n_active
    for size in (1, 3, 8, 9, 32, 77, 128, 129, 300):
        for policy in ("historical", "fresh"):
            engine.query(rng.integers(0, n, size=size), policy=policy)
    # multi-request packing + updates + refresh ride the same shapes
    engine.serve_batch([rng.integers(0, n, size=s) for s in (2, 5, 40)])
    engine.add_edges([(0, 1)])
    engine.refresh()
    assert engine.trace_count == baseline, \
        f"{engine.trace_count - baseline} recompiles after warmup"


def test_batch_packing_returns_per_request_logits(trained):
    model, engine = restore_engine(trained)
    reqs = [[5], [1, 2, 3], np.arange(20)]
    outs, info = engine.serve_batch(reqs)
    assert [len(o) for o in outs] == [1, 3, 20]
    singles = [engine.query(r) for r in reqs]
    for got, want in zip(outs, singles):
        assert np.array_equal(got, want)
    assert 0 < info["occupancy"] <= 1
    assert info["hit_rate"] == 1.0


# ---------------------------------------------------------------------------
# streaming updates: exact 1-hop invalidation + refresh exactness
# ---------------------------------------------------------------------------

def pick_nonadjacent(store, lo=0):
    """Two live nodes with free slots that are not already neighbors."""
    deg = store.degrees()
    for u in range(lo, store.n_active):
        for v in range(u + 1, store.n_active):
            if deg[u] < store.max_deg and deg[v] < store.max_deg \
                    and v not in store.nbr_idx[u][store.nbr_mask[u] > 0]:
                return u, v
    pytest.skip("graph too dense for a free edge slot")


def test_add_edges_invalidates_exactly_endpoints(trained):
    model, engine = restore_engine(trained)
    u, v = pick_nonadjacent(model.store)
    affected = engine.add_edges([(u, v)])
    assert sorted(affected) == sorted({u, v})
    assert set(model.invalid_rows()) == {u, v}
    # every other cached row is untouched
    mask = np.ones(model.n_active, bool)
    mask[[u, v]] = False
    assert model.valid[: model.n_active][mask].all()


def test_add_nodes_invalidates_one_hop(trained):
    model, engine = restore_engine(trained)
    anchors = [0, 3]
    feats = model.store.features[anchors] * 0.5
    new_id = model.n_active
    ids, affected = engine.add_nodes(feats[:1], [(new_id, a) for a in anchors])
    assert list(ids) == [new_id]
    assert sorted(affected) == sorted({new_id, *anchors})
    assert set(model.invalid_rows()) == {new_id, *anchors}
    # the new node is servable immediately (stale rows serve as-is)
    logits = engine.query([new_id], policy="fresh")
    assert np.isfinite(logits).all()


def test_add_nodes_grows_capacity_and_rewarms(trained):
    """An insert past the store's allocation grows the device mirrors in
    place (old cache rows bit-preserved), lands the feature write AFTER the
    growth, and re-warms the bucket shapes so no later query traces."""
    model, engine = restore_engine(trained)
    baseline = engine.warmup()
    cap0 = model.store.capacity
    h1_before = np.asarray(model.h1)[: model.n_active].copy()
    n_new = cap0 - model.n_active + 3
    rng = np.random.default_rng(1)
    feats = rng.standard_normal(
        (n_new, model.store.n_features)).astype(np.float32)
    ids, _ = engine.add_nodes(feats)
    assert model.store.n_grows == 1 and model.store.capacity > cap0
    # every device/host mirror tracks the new capacity
    cap = model.store.capacity
    assert model.feat.shape[0] == model.h1.shape[0] == cap
    assert len(model.valid) == len(model.row_version) == cap
    # the post-growth feature scatter landed (old capacity would drop it)
    assert np.array_equal(np.asarray(model.feat)[ids], feats)
    # the warm cache survived the reallocation bit-for-bit
    assert np.array_equal(np.asarray(model.h1)[: len(h1_before)], h1_before)
    # re-warm happened, and the post-growth shapes are now compile-stable
    assert engine.trace_count_after_warmup > baseline
    rewarmed = engine.trace_count
    engine.query(ids[:2], policy="historical")
    engine.query(ids, policy="fresh")
    engine.refresh()
    assert engine.trace_count == rewarmed, "post-growth query traced"


def test_refresh_restores_fresh_historical_agreement(trained):
    model, engine = restore_engine(trained)
    u, v = pick_nonadjacent(model.store)
    engine.add_edges([(u, v)])
    q = np.array([u, v])
    stale = engine.query(q, policy="historical")
    fresh = engine.query(q, policy="fresh")
    # the mutated rows' historical cache is stale until refreshed
    assert not np.array_equal(stale, fresh)
    n = engine.refresh()
    assert n == 2 and len(model.invalid_rows()) == 0
    assert np.array_equal(engine.query(q, policy="historical"), fresh)
    # hit-rate ledger saw the staleness window
    assert model.n_invalidated == 2 and model.n_refreshed >= 2


def test_fresh_policy_ignores_staleness_of_neighbors(trained):
    """'fresh' re-embeds the whole 1-hop neighborhood, so it is exact even
    when the cache rows it overlays are stale."""
    model, engine = restore_engine(trained)
    u, v = pick_nonadjacent(model.store)
    engine.add_edges([(u, v)])
    before = engine.query([u], policy="fresh")
    engine.refresh()
    assert np.array_equal(engine.query([u], policy="fresh"), before)


def test_query_validation(trained):
    model, engine = restore_engine(trained)
    with pytest.raises(ValueError, match="outside"):
        engine.query([model.n_active + 10])
    with pytest.raises(ValueError, match="cache_policy"):
        engine.query([0], policy="psychic")
    with pytest.raises(ValueError, match="cache_policy"):
        QueryEngine(model, cache_policy="nope")
    with pytest.raises(ValueError, match="backend"):
        ServedModel({}, model.store, backend="cuda")


# ---------------------------------------------------------------------------
# GraphStore (host-side mutable adjacency)
# ---------------------------------------------------------------------------

def make_store(n=6, d=3, f=4, **kw):
    idx = np.zeros((n, d), np.int32)
    mask = np.zeros((n, d), np.float32)
    feats = np.arange(n * f, dtype=np.float32).reshape(n, f)
    return GraphStore(feats, idx, mask, **kw)


def test_store_capacity_and_headroom():
    s = make_store(n=6, capacity=8, max_capacity=8)
    assert s.capacity == 8
    s.add_nodes(np.zeros((2, 4)))
    with pytest.raises(CapacityError, match="hard cap"):
        s.add_nodes(np.zeros((1, 4)))
    with pytest.raises(ValueError, match="capacity"):
        make_store(n=6, capacity=3)
    with pytest.raises(ValueError, match="max_capacity"):
        make_store(n=6, capacity=8, max_capacity=7)
    with pytest.raises(ValueError, match="growth"):
        make_store(n=6, growth=1.0)
    assert make_store(n=100).capacity >= 164      # default headroom floor


def test_store_geometric_growth():
    s = make_store(n=6, capacity=8)
    assert s.max_capacity is None
    # past headroom: grows geometrically instead of raising
    s.add_nodes(np.zeros((4, 4)))
    assert s.n_active == 10
    assert s.capacity == 12                       # ceil(8 * 1.5)
    assert s.n_grows == 1
    # a burst larger than one growth step lands in a single reallocation
    ids, _ = s.add_nodes(np.arange(25 * 4, dtype=np.float32).reshape(25, 4))
    assert s.n_active == 35 and s.capacity == 35 and s.n_grows == 2
    np.testing.assert_array_equal(
        s.features[ids],
        np.arange(25 * 4, dtype=np.float32).reshape(25, 4))
    # growth preserves existing adjacency and zeroes the new headroom
    assert s.nbr_idx.shape == (35, 3) and not s.nbr_mask[10:].any()


def test_store_growth_respects_hard_cap():
    s = make_store(n=6, capacity=8, max_capacity=10)
    s.add_nodes(np.zeros((3, 4)))                 # grows, clamped to the cap
    assert s.capacity == 10 and s.n_grows == 1
    with pytest.raises(CapacityError, match="hard cap"):
        s.add_nodes(np.zeros((2, 4)))
    assert s.n_active == 9                        # failed insert left no rows


def test_store_edge_semantics():
    s = make_store(n=4, d=2)
    assert list(s.add_edges([(0, 1)])) == [0, 1]
    assert list(s.add_edges([(0, 1), (1, 0)])) == []      # dup: no-op
    assert list(s.add_edges([(2, 2)])) == []              # self-loop ignored
    assert s.n_edges_added == 1
    s.add_edges([(0, 2), (0, 3)])                         # row 0 now full
    assert s.n_edges_evicted == 1                         # random slot replaced
    assert s.degrees([0])[0] == 2                         # degree stays capped
    with pytest.raises(ValueError, match="outside"):
        s.add_edges([(0, 99)])


def test_store_add_nodes_with_attachment_edges():
    s = make_store(n=3, d=2, capacity=6)
    ids, affected = s.add_nodes(np.ones((2, 4)), edges=[(3, 0), (4, 3)])
    assert list(ids) == [3, 4]
    assert sorted(affected) == [0, 3, 4]
    assert s.n_active == 5
    assert s.degrees([3])[0] == 2                         # edges to 0 and 4


# ---------------------------------------------------------------------------
# checkpoint-layer satellites
# ---------------------------------------------------------------------------

def test_failed_save_leaves_no_tmp(tmp_path, monkeypatch):
    import msgpack

    def boom(*a, **kw):
        raise RuntimeError("disk on fire")

    monkeypatch.setattr(msgpack, "packb", boom)
    with pytest.raises(RuntimeError, match="disk on fire"):
        save_checkpoint(str(tmp_path), 1, {"x": np.zeros(3)})
    assert os.listdir(tmp_path) == []         # no stray .tmp, no partial ckpt


def test_load_latest_picks_newest(tmp_path):
    like = {"x": np.zeros(3, np.float32)}
    with pytest.raises(FileNotFoundError):
        load_latest(str(tmp_path), like)
    save_checkpoint(str(tmp_path), 2, {"x": np.full(3, 2.0, np.float32)})
    save_checkpoint(str(tmp_path), 10, {"x": np.full(3, 10.0, np.float32)})
    step, tree = load_latest(str(tmp_path), like)
    assert step == 10
    assert np.array_equal(np.asarray(tree["x"]), np.full(3, 10.0))


def test_loaded_arrays_are_writable(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": np.arange(4, dtype=np.float32)})
    tree = load_checkpoint(str(tmp_path), 0, {"x": np.zeros(4, np.float32)})
    host = np.asarray(tree["x"]).copy()
    host[0] = -1.0                                        # plain numpy path
    buf = np.frombuffer(b"\x00" * 16, np.float32)
    assert not buf.flags.writeable                        # the hazard guarded


# ---------------------------------------------------------------------------
# load generator + latency ledger
# ---------------------------------------------------------------------------

def test_loadgen_emits_schema_valid_payload(trained):
    from repro.serve import LoadGenerator, validate_bench_serve

    model, engine = restore_engine(trained)
    gen = LoadGenerator(engine, seed=0, n_queries=16, n_updates=2,
                        mode="closed", concurrency=4, refresh_every=2)
    ledger = gen.run()                         # warms up the engine itself
    payload = ledger.summary(backend=model.backend, devices=1, quick=True,
                             mode="closed", policy_mix=gen.policy_mix,
                             model_summary=model.summary())
    assert validate_bench_serve(payload) == []
    assert payload["n_queries"] == 16 and payload["n_updates"] == 2
    assert sum(b["n"] for b in payload["buckets"]) == 16

    # open-loop discipline over the already-warm engine: queueing delay
    # makes latency >= service time, and the ledger still validates
    gen2 = LoadGenerator(engine, seed=1, n_queries=12, n_updates=3,
                        mode="open", rate=2000.0)
    payload2 = gen2.run().summary(backend=model.backend, devices=1,
                                  quick=True, mode="open",
                                  policy_mix=gen2.policy_mix)
    assert validate_bench_serve(payload2) == []
    # traffic ran entirely through the warmed bucket shapes
    assert engine.trace_count == engine.trace_count_after_warmup


def test_loadgen_validations(trained):
    from repro.serve import LoadGenerator

    model, engine = restore_engine(trained)
    with pytest.raises(ValueError, match="mode"):
        LoadGenerator(engine, mode="diagonal")
    with pytest.raises(ValueError, match="policy_mix"):
        LoadGenerator(engine, policy_mix={"psychic": 1.0})


# ---------------------------------------------------------------------------
# graceful degradation (fault-tolerance layer)
# ---------------------------------------------------------------------------

def test_fresh_falls_back_to_warm_cache_on_poison(trained):
    """Poisoned streaming features make the fresh path produce non-finite
    logits; with ``fallback`` on, the chunk is re-served from the warm
    historical cache — finite, bit-equal to a historical query — and the
    degradation is observable (counter + per-chunk flags), never silent."""
    import jax.numpy as jnp

    model, engine = restore_engine(trained)
    engine.warmup()
    q = np.arange(12)
    warm = engine.query(q, policy="historical")
    clean = model.feat
    model.feat = model.feat.at[:].set(jnp.nan)
    try:
        [got], info = engine.serve_batch([q], policy="fresh")
        assert np.isfinite(got).all()
        assert np.array_equal(got, warm)
        assert info["fell_back"] and engine.n_fallbacks == 1
        # the requested policy is reported at the top level; the chunks
        # record what actually ran
        assert info["policy"] == "fresh"
        assert all(c["policy"] == "historical" for c in info["chunks"])

        # fallback off: the legacy contract — raw (possibly non-finite)
        # fresh logits come back untouched, nothing raises, no counters
        _, strict_engine = restore_engine(trained, fallback=False)
        strict_engine.warmup()
        strict_engine.model.feat = strict_engine.model.feat.at[:].set(jnp.nan)
        raw = strict_engine.query(q, policy="fresh")
        assert not np.isfinite(raw).all()
        assert strict_engine.n_fallbacks == 0
    finally:
        model.feat = clean
    # recovered features serve fresh exactly again
    assert np.isfinite(engine.query(q, policy="fresh")).all()


def test_deadline_downgrades_fresh_to_historical(trained):
    model, engine = restore_engine(trained, deadline_ms=5.0)
    engine.warmup()
    q = [np.arange(8)]
    # under deadline (or unreported queueing): fresh runs as requested
    _, info = engine.serve_batch(q, policy="fresh", queue_ms=1.0)
    assert info["policy"] == "fresh" and engine.n_degraded == 0
    _, info = engine.serve_batch(q, policy="fresh")
    assert info["policy"] == "fresh" and engine.n_degraded == 0
    # past deadline: the batch downgrades to the cheap warm-cache policy
    [got], info = engine.serve_batch(q, policy="fresh", queue_ms=9.0)
    assert info["policy"] == "historical" and engine.n_degraded == 1
    assert np.array_equal(got, engine.query(q[0], policy="historical"))
    # historical batches have nothing to downgrade
    _, info = engine.serve_batch(q, policy="historical", queue_ms=9.0)
    assert info["policy"] == "historical" and engine.n_degraded == 1


def test_admission_control_sheds_past_max_queue(trained):
    model, engine = restore_engine(trained, max_queue=2)
    assert engine.admit(0) and engine.admit(1)
    assert not engine.admit(2) and not engine.admit(5)
    assert engine.n_rejected == 2
    assert engine.degraded_snapshot() == {
        "n_rejected": 2, "n_degraded": 0, "n_fallbacks": 0}
    # unset: everything admits
    _, open_engine = restore_engine(trained)
    assert open_engine.admit(10 ** 6)
    assert open_engine.n_rejected == 0
    with pytest.raises(ValueError, match="deadline_ms"):
        QueryEngine(model, deadline_ms=0.0)
    with pytest.raises(ValueError, match="max_queue"):
        QueryEngine(model, max_queue=0)


def test_nonfinite_rows_probe(trained):
    model, engine = restore_engine(trained)
    assert len(model.nonfinite_rows()) == 0
    assert model.summary()["h1_finite_frac"] == 1.0
    clean = model.h1
    model.h1 = model.h1.at[3, 0].set(np.nan)
    try:
        assert list(model.nonfinite_rows()) == [3]
        frac = model.summary()["h1_finite_frac"]
        assert frac == 1.0 - 1.0 / model.n_active
    finally:
        model.h1 = clean


def test_serve_keeps_answering_at_hard_cap(trained):
    """Satellite: a store at its ``max_capacity`` ceiling refuses growth
    with ``CapacityError`` but the engine keeps serving queries — ingestion
    degrades, availability doesn't."""
    model, engine = restore_engine(trained)
    engine.warmup()
    store = model.store
    store.max_capacity = store.capacity          # operator memory budget hit
    n0, cap0, grows0 = store.n_active, store.capacity, store.n_grows
    headroom = cap0 - n0
    feats = np.zeros((headroom + 1, store.n_features), np.float32)
    with pytest.raises(CapacityError, match="hard cap"):
        engine.add_nodes(feats)
    # the failed insert left no partial state: no rows, no growth
    assert store.n_active == n0 and store.capacity == cap0
    assert store.n_grows == grows0
    # and the engine still answers, recompile-free, with exact logits
    before = engine.trace_count
    got = engine.query(np.arange(16), policy="historical")
    assert np.isfinite(got).all() and engine.trace_count == before
    # inserts within the remaining headroom still land
    if headroom:
        ids, _ = engine.add_nodes(np.zeros((headroom, store.n_features),
                                           np.float32))
        assert store.n_active == cap0 and len(ids) == headroom


def test_loadgen_shed_counters_ride_the_payload(trained):
    """An open-loop burst against a tiny admission queue sheds load; the
    ledger's summary reports the shed count + engine degradation counters
    and still validates against the serve-bench schema."""
    from repro.serve import LoadGenerator, validate_bench_serve

    model, engine = restore_engine(trained, max_queue=1)
    gen = LoadGenerator(engine, seed=0, n_queries=24, n_updates=0,
                        mode="open", rate=200_000.0)
    ledger = gen.run()
    assert ledger.rejects > 0 and engine.n_rejected == ledger.rejects
    payload = ledger.summary(backend=model.backend, devices=1, quick=True,
                             mode="open", policy_mix=gen.policy_mix,
                             degraded=engine.degraded_snapshot())
    assert validate_bench_serve(payload) == []
    assert payload["degraded"]["n_shed"] == ledger.rejects
    assert payload["degraded"]["n_rejected"] == engine.n_rejected
    # served + shed accounts for every generated query
    assert sum(b["n"] for b in payload["buckets"]) + ledger.rejects == 24


# ---------------------------------------------------------------------------
# fused single-call bucket path vs the decomposed two-call reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["segment", "gather"])
def test_twocall_reference_matches_fused_bitwise(trained, backend):
    """``fused=False`` keeps the decomposed aggregate-call/host-hop/head-call
    pipeline; both modes decode the same cache bits and sum segments in the
    same slot order, so the served logits agree bit for bit under either
    policy — the invariant that makes launch.serve_fed's fused A/B a pure
    latency comparison. The fused warmup compiles 3 programs per bucket
    (hist, fresh, refresh); the two-call reference pays 5."""
    model, fused = restore_engine(trained, backend=backend)
    fused.warmup()
    two = QueryEngine(model, fused=False)
    two.warmup()
    assert fused.trace_count_after_warmup == 3 * len(fused.buckets)
    assert two.trace_count_after_warmup == 5 * len(two.buckets)
    rng = np.random.default_rng(7)
    n = model.n_active
    for size in (1, 8, 33, 128):
        ids = rng.integers(0, n, size=size)
        for policy in ("historical", "fresh"):
            assert np.array_equal(fused.query(ids, policy=policy),
                                  two.query(ids, policy=policy)), \
                f"{backend}/{policy}/size={size}"
    # both modes served every mix recompile-free
    assert fused.trace_count == fused.trace_count_after_warmup
    assert two.trace_count == two.trace_count_after_warmup


def test_twocall_refresh_matches_fused_bitwise(trained):
    """The background refresh writes the same rows either way: invalidate a
    few rows, refresh through each mode from the same snapshot, compare the
    resulting caches bitwise."""
    import jax.numpy as jnp

    model, fused = restore_engine(trained)
    fused.warmup()
    two = QueryEngine(model, fused=False)
    two.warmup()
    snap_h1 = jnp.array(model.h1)
    model.invalidate(np.arange(5))
    fused.refresh()
    want = np.asarray(model.h1)
    model.h1 = snap_h1
    model.invalidate(np.arange(5))
    two.refresh()
    assert np.array_equal(np.asarray(model.h1), want)


def test_loadgen_hot_set_diverges_across_seeds(trained):
    """The Zipf popularity permutation derives from the generator's own
    seed: differently-seeded generators hammer different hot sets (the old
    code hard-coded rng(12345), so every generator shared one), equal seeds
    reproduce the same hot set, and deriving the permutation does not
    consume from the arrival/policy stream."""
    from repro.serve import LoadGenerator

    model, engine = restore_engine(trained)
    engine.warmup()
    g0 = LoadGenerator(engine, seed=0)
    g0b = LoadGenerator(engine, seed=0)
    g1 = LoadGenerator(engine, seed=1)
    ids0 = g0._node_ids(4096)
    assert np.array_equal(ids0, g0b._node_ids(4096))
    g1._node_ids(1)
    assert not np.array_equal(g0._perm, g1._perm)
    # the permutation comes from a salted fork, not from self.rng: two
    # same-seeded generators stay in rng lockstep even when only one of
    # them re-derives its permutation an extra time
    g0._perm_n = None
    g0._node_ids(1)
    g0b._node_ids(1)
    assert np.array_equal(g0._perm, g0b._perm)
    assert int(g0.rng.integers(1 << 30)) == int(g0b.rng.integers(1 << 30))


def test_bench_serve_fused_column_validates(trained):
    from repro.serve import LoadGenerator, validate_bench_serve

    model, engine = restore_engine(trained)
    gen = LoadGenerator(engine, seed=0, n_queries=8, n_updates=0,
                        mode="closed", concurrency=2)
    ledger = gen.run()
    col = {"bucket": 8, "p50_ms": 0.5, "twocall_p50_ms": 0.7,
           "speedup": 1.4, "recompiles_after_warmup": 0}
    payload = ledger.summary(backend=model.backend, devices=1, quick=True,
                             mode="closed", policy_mix=gen.policy_mix,
                             fused=col)
    assert validate_bench_serve(payload) == []
    assert payload["fused"] == col
    # and the validator rejects malformed fused columns
    for broken in ({"bucket": 8},
                   {**col, "p50_ms": -1.0},
                   {**col, "bucket": 0},
                   {**col, "recompiles_after_warmup": -1}):
        bad = dict(payload)
        bad["fused"] = broken
        assert validate_bench_serve(bad), broken
