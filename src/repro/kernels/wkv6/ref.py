"""Pure-jnp oracle for the WKV6 recurrence (same math as models/rwkv.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state0=None):
    """r,k,v,w: (B, T, H, N); u: (H, N). Returns (y (B,T,H,N), S (B,H,N,N))."""
    B, T, H, N = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = [a.astype(jnp.float32) for a in inp]
        coef = jnp.sum(rt * u * kt, axis=-1, keepdims=True)
        y = coef * vt + jnp.einsum("bhn,bhnm->bhm", rt, S)
        S = wt[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    S, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), S
