"""Model configuration schema + registry for the assigned architectures.

Every architecture in the assignment pool is expressed as a ``ModelConfig``.
``block_pattern`` is the repeating unit of block kinds; ``n_layers`` need not
be divisible by the unit length (the remainder is applied as a trailing
partial unit — layer counts stay exact, see DESIGN.md §6.4).

Block kinds:
    "attn"    full (causal) self-attention + FFN
    "local"   sliding-window self-attention + FFN
    "rec"     RG-LRU recurrent block (Griffin) + FFN
    "rwkv"    RWKV6 time-mix + channel-mix
    "enc"     bidirectional encoder attention + FFN (whisper encoder)
    "dec"     causal self-attn + cross-attn + FFN (whisper decoder)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    source: str = ""                  # citation from the assignment pool

    # -- attention / block layout --
    block_pattern: tuple = ("attn",)
    window_size: int = 4096           # sliding window for "local" blocks
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"       # rope | learned | none
    max_seq_len: int = 131072

    # -- MLP --
    activation: str = "silu"          # silu | gelu | sqrelu | relu
    gated_mlp: bool = True

    # -- MoE --
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_ff_dim: int = 0             # hidden dim of the parallel dense FFN
    # "sort": global argsort dispatch (paper-faithful gather/scatter port —
    # the baseline). "einsum": group-wise one-hot dispatch that SPMD
    # partitions cleanly (the §Perf hillclimb winner; see EXPERIMENTS.md).
    moe_impl: str = "sort"
    # routing-group length for the einsum dispatch. Dispatch-einsum FLOPs
    # scale with group² (C ∝ group), so smaller groups cut the one-hot
    # matmul cost quadratically at slightly higher drop variance (§Perf H1.2).
    moe_group_size: int = 0           # 0 -> one group per sequence

    # -- SSM / hybrid --
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 0               # >0: chunked WKV w/ boundary remat (§Perf H2.2)
    rglru_width: int = 0              # 0 -> d_model
    conv1d_width: int = 4
    rglru_c: float = 8.0

    # -- encoder-decoder (whisper) --
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500       # stub mel/conv frame embeddings

    # -- VLM --
    n_image_tokens: int = 0           # stub projected patch embeddings

    # -- numerics / impl --
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_impl: str = "einsum"         # einsum | chunked  (chunked = blockwise, lower HBM)
    attn_chunk_size: int = 1024
    remat: bool = False               # activation checkpointing over blocks
    # scan over stacked layer units (compact HLO) vs python-unrolled layers.
    # The dry-run unrolls so cost_analysis / collective parsing sees every
    # layer (XLA counts a while-loop body once, not x trip count).
    scan_layers: bool = True
    # long_500k support: when True, "attn" blocks degrade to sliding window in
    # the long-context decode path (documented beyond-paper variant).
    long_context_local: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_pattern(self) -> tuple:
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rwkv",) for k in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True iff no block requires *full* attention over the sequence."""
        kinds = set(self.block_pattern) | set(self.remainder_pattern)
        if kinds <= {"rwkv", "rec", "local"}:
            return True
        if kinds <= {"rwkv", "rec", "local", "attn"} and self.long_context_local:
            return True
        return False

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step (none assigned here)."""
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        qd, kvd = self.q_dim, self.kv_dim
        per_kind: dict[str, int] = {}
        attn_p = d * qd + 2 * d * kvd + qd * d + d  # q,k,v,o + norm
        ffn_dense = d * ff * (3 if self.gated_mlp else 2) + d
        if self.n_experts:
            ffn_moe = d * self.n_experts + self.n_experts * d * ff * (3 if self.gated_mlp else 2) + d
            if self.moe_dense_residual:
                dff = self.dense_ff_dim or ff
                ffn_moe += d * dff * (3 if self.gated_mlp else 2)
            ffn = ffn_moe
        else:
            ffn = ffn_dense
        per_kind["attn"] = attn_p + ffn
        per_kind["local"] = attn_p + ffn
        per_kind["enc"] = attn_p + ffn
        per_kind["dec"] = attn_p + (d * qd + 2 * d * kvd + qd * d + d) + ffn
        w = self.rglru_width or d
        per_kind["rec"] = (
            2 * d * w                      # rec/gate branch in-projections
            + w * self.conv1d_width + w    # depthwise conv + bias
            + 2 * w * w + 2 * w + w        # RG-LRU gates (w_a, w_i, biases, Lambda)
            + w * d                        # out projection
            + d * ff * 3 + 2 * d           # gated MLP + norms
        )
        # time-mix (r,k,v,g,o projections + ddlerp/decay loras + bonus) +
        # channel-mix (wck, wcv, wcr) + norms/mix vectors
        per_kind["rwkv"] = (
            5 * d * d                      # wr, wk, wv, wg, wo
            + 5 * (d * 32 + 32 * d)        # ddlerp lora (mix_w1/mix_w2)
            + d * 64 + 64 * d              # decay lora
            + d * ff + ff * d + d * d      # channel mix
            + 10 * d                       # mu vectors, w0, u, ln scales
        )
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        pattern = list(self.block_pattern) * self.n_units + list(self.remainder_pattern)
        for kind in pattern:
            total += per_kind[kind]
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn_p + ffn_dense)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_ffn_mats = 3 if self.gated_mlp else 2
        inactive = (self.n_experts - self.top_k) * d * ff * n_ffn_mats
        n_moe_layers = sum(
            1 for k in (list(self.block_pattern) * self.n_units + list(self.remainder_pattern))
            if k in ("attn", "local")
        )
        return int(self.param_count() - n_moe_layers * inactive)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, config_fn: Callable[[], ModelConfig], smoke_fn: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = config_fn
    _SMOKE_REGISTRY[arch_id] = smoke_fn


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def get_smoke_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE_REGISTRY[arch_id]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import the per-arch modules for their registration side effects
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        gemma3_12b, dbrx_132b, deepseek_67b, nemotron_4_15b, llama3_405b,
        arctic_480b, whisper_large_v3, rwkv6_1_6b, recurrentgemma_2b,
        internvl2_2b,
    )


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Beyond-paper long-context decode variant: full-attention blocks degrade
    to sliding-window so the 500k cache stays sub-quadratic (used only for the
    ``long_500k`` shape when ``cfg.long_context_local``; DESIGN.md §5)."""
    if not cfg.long_context_local:
        return cfg
    pattern = tuple("local" if k == "attn" else k for k in cfg.block_pattern)
    return replace(cfg, block_pattern=pattern)


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family: 2 layers, d_model<=512, <=4 experts."""
    pattern = cfg.block_pattern
    d = min(cfg.d_model, 256)
    hd = 32
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0
    kw = dict(
        n_layers=max(2, len(pattern[:2])) if len(pattern) > 1 else 2,
        d_model=d,
        n_heads=n_heads if cfg.n_heads else 0,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # no-drop capacity so tiny-batch decode routes identically to prefill
        capacity_factor=8.0 if cfg.n_experts else cfg.capacity_factor,
        dense_ff_dim=min(cfg.dense_ff_dim, 256) if cfg.dense_ff_dim else 0,
        rwkv_head_dim=32,
        rglru_width=min(cfg.rglru_width, 256) if cfg.rglru_width else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        encoder_seq_len=16 if cfg.n_encoder_layers else cfg.encoder_seq_len,
        n_image_tokens=8 if cfg.n_image_tokens else 0,
        window_size=min(cfg.window_size, 8),
        max_seq_len=128,
        attn_chunk_size=16,
        dtype="float32",
    )
    # keep the *family pattern*: 2 layers drawn from the same repeating unit
    kw["block_pattern"] = tuple(pattern[:2]) if len(pattern) >= 2 else pattern
    kw["n_layers"] = 2
    kw.update(overrides)
    return replace(cfg, **kw)
