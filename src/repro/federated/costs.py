"""Communication / computation cost meters + the paper's delay model.

Everything is counted analytically (bytes of what crosses the network,
FLOPs of what runs on clients) so iid/non-iid/scale sweeps are exact and
deterministic — matching how the paper reports Fig. 3/4 cost axes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

BYTES_F32 = 4


def seq_sum(x) -> float:
    """Left-to-right float64 sum, bit-identical to a Python accumulation loop
    starting from 0.0 (np.sum's pairwise blocking rounds differently). Lets
    the cost model vectorize per-client accounting without perturbing meters
    that tests and benchmarks pin exactly."""
    arr = np.asarray(x, np.float64).ravel()
    return float(arr.cumsum()[-1]) if arr.size else 0.0


@dataclass
class CostMeter:
    comm_model_bytes: float = 0.0      # model up/down-link
    comm_embed_bytes: float = 0.0      # cross-client embedding sync
    compute_flops: float = 0.0
    wall_clock_s: float = 0.0
    sync_events: int = 0

    @property
    def comm_total_bytes(self) -> float:
        return self.comm_model_bytes + self.comm_embed_bytes

    def add(self, other: "CostMeter") -> None:
        self.comm_model_bytes += other.comm_model_bytes
        self.comm_embed_bytes += other.comm_embed_bytes
        self.compute_flops += other.compute_flops
        self.wall_clock_s += other.wall_clock_s
        self.sync_events += other.sync_events

    def snapshot(self) -> dict:
        return {
            "comm_model_bytes": self.comm_model_bytes,
            "comm_embed_bytes": self.comm_embed_bytes,
            "comm_total_bytes": self.comm_total_bytes,
            "compute_flops": self.compute_flops,
            "wall_clock_s": self.wall_clock_s,
            "sync_events": self.sync_events,
        }


@dataclass(frozen=True)
class DelayModel:
    """Client compute speed + network bandwidth for the wall-clock estimate
    (paper's c and o). Defaults roughly a commodity edge client."""

    client_flops_per_s: float = 50e9     # 50 GFLOP/s effective
    bandwidth_bytes_per_s: float = 12.5e6  # 100 Mbit/s
    latency_s: float = 0.05

    # Both delays accept a scalar OR a per-client np.ndarray and return the
    # same shape — the cost model prices whole cohorts in one call, and the
    # AsyncScheduler's per-client heterogeneity knobs (speed_factors for
    # compute, comm_factors for links) multiply these baselines elementwise.

    def compute_time(self, flops):
        return flops / self.client_flops_per_s

    def comm_time(self, bytes_):
        return self.latency_s + bytes_ / self.bandwidth_bytes_per_s


@dataclass
class VirtualClock:
    """Server-side virtual clock for overlapped (asynchronous) rounds.

    Synchronous accounting bills ``max(client compute) + sync overhead`` per
    round: every client blocks until the slowest finishes. Under overlap the
    server keeps clients in flight across merges, so a merge bills only the
    wait from the previous merge completion (``now``) until the
    quorum-completing update arrived, plus the server-side overhead.

    ``merge_elapsed`` works from the arriving update's *relative* client time
    rather than subtracting absolute timestamps: when the update was
    dispatched exactly at ``now`` (no overlap — the synchronous regime) the
    billed time is bit-identical to the synchronous meter's
    ``max(compute) + overhead``, which is what pins the async/sync parity
    test.
    """

    now: float = 0.0

    def merge_elapsed(self, dispatch_time: float, client_time: float,
                      overhead: float) -> float:
        """Advance past a merge; returns the wall-clock billed to it."""
        wait = (dispatch_time - self.now) + client_time
        elapsed = max(wait, 0.0) + overhead
        self.now += elapsed
        return elapsed


def model_bytes(n_params: int) -> float:
    return n_params * BYTES_F32


def embed_sync_bytes(n_ghosts: float, dims: tuple[int, ...]) -> float:
    """One synchronization event: per ghost, one embedding per layer."""
    return float(n_ghosts) * sum(dims) * BYTES_F32
