"""Unit + property tests for the FedAIS core modules (the paper's math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # hypothesis, or a skip-stub when absent

from repro.core.importance import (
    importance_probs,
    loss_delta_scores,
    sample_batch,
    sampling_variance,
    uniform_probs,
)
from repro.core.sync import adaptive_tau, delay_model, error_bound, tau_theoretical
from repro.core.variance import minibatch_variance, theorem1_bound
from repro.core.historical import push_embeddings, staleness_metrics


# ---------------------------------------------------------------------------
# importance sampling (Eq. 7-8)
# ---------------------------------------------------------------------------

def test_importance_probs_normalised(rng):
    scores = jnp.asarray(rng.random(100), jnp.float32)
    mask = jnp.asarray(rng.random(100) < 0.7, jnp.float32)
    p = importance_probs(scores, mask)
    assert abs(float(p.sum()) - 1.0) < 1e-5
    assert float(p.min()) >= 0.0
    # masked entries have zero probability
    assert float((p * (1 - mask)).sum()) == 0.0


@given(n=st.integers(4, 200))
@settings(max_examples=20, deadline=None)
def test_importance_probs_property(n):
    rng = np.random.default_rng(n)
    scores = jnp.asarray(rng.random(n), jnp.float32)
    mask = jnp.ones(n, jnp.float32)
    p = importance_probs(scores, mask)
    assert abs(float(p.sum()) - 1.0) < 1e-4
    # monotone: higher score -> higher probability
    i, j = int(jnp.argmax(scores)), int(jnp.argmin(scores))
    assert float(p[i]) >= float(p[j])


def test_loss_delta_cold_start():
    """Never-seen nodes (prev=-1) score by their current loss."""
    curr = jnp.asarray([1.0, 2.0, 3.0])
    prev = jnp.asarray([-1.0, 1.5, -1.0])
    mask = jnp.ones(3)
    s = loss_delta_scores(curr, prev, mask)
    np.testing.assert_allclose(np.asarray(s), [1.0, 0.5, 3.0])


def test_sample_batch_distinct_and_masked(key):
    probs = jnp.asarray([0.5, 0.3, 0.2, 0.0, 0.0])
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    idx, valid = sample_batch(key, probs, 3, mask)
    idx_np = np.asarray(idx)
    assert len(set(idx_np.tolist())) == 3          # distinct
    assert set(idx_np[np.asarray(valid)]) <= {0, 1, 2}  # masked never valid


def test_sample_batch_respects_probabilities(key):
    """High-probability nodes are drawn far more often (statistical)."""
    probs = jnp.asarray([0.9, 0.05, 0.05] + [0.0] * 7)
    probs = probs / probs.sum()
    mask = (probs > 0).astype(jnp.float32)
    counts = np.zeros(10)
    for i in range(200):
        idx, valid = sample_batch(jax.random.fold_in(key, i), probs, 1, mask)
        counts[int(idx[0])] += 1
    assert counts[0] > 100   # node 0 dominates


def test_importance_sampling_reduces_eq7_objective(rng):
    """The Eq. 7 variance objective is lower under p ∝ ||grad|| than uniform
    for skewed gradient norms — the paper's core sampling claim."""
    g = jnp.asarray(rng.pareto(1.5, 200) + 0.01, jnp.float32)   # heavy tail
    mask = jnp.ones(200, jnp.float32)
    p_imp = importance_probs(g, mask)
    p_uni = uniform_probs(mask)
    v_imp = float(sampling_variance(p_imp, g, mask))
    v_uni = float(sampling_variance(p_uni, g, mask))
    assert v_imp < v_uni


# ---------------------------------------------------------------------------
# adaptive sync (Eq. 9-11)
# ---------------------------------------------------------------------------

def test_adaptive_tau_decreases_with_loss():
    """Eq. 11: tau decays as sqrt(F_t/F_0) — more sync as model converges."""
    taus = [adaptive_tau(f, 4.0, tau0=8) for f in (4.0, 2.0, 1.0, 0.25, 0.01)]
    assert taus[0] == 8
    assert all(a >= b for a, b in zip(taus, taus[1:]))
    assert taus[-1] == 1


def test_adaptive_tau_robust():
    assert adaptive_tau(float("nan"), 1.0, 4) == 4
    assert adaptive_tau(1.0, 0.0, 4) == 4
    assert adaptive_tau(100.0, 1.0, 4, tau_max=16) == 16


@given(f0=st.floats(0.5, 10), o=st.floats(0.1, 100), zeta2=st.floats(0.01, 10),
       eta=st.floats(1e-4, 0.1))
@settings(max_examples=30, deadline=None)
def test_eq10_minimises_error_bound(f0, o, zeta2, eta):
    """The Eq. 10 tau* should (approximately) minimise the Eq. 9 bound over
    integer tau — verified by brute force."""
    lam, c_total, c = 1.0, 1000.0, 1.0
    tau_star = tau_theoretical(f0, 0.0, o, eta, c_total, lam, zeta2)
    taus = np.arange(1, 200)
    vals = [error_bound(f0, 0.0, eta, lam, zeta2, c, o, t, c_total) for t in taus]
    best = taus[int(np.argmin(vals))]
    if 1 <= tau_star <= 199:
        # continuous optimum within 1 of the integer argmin (convexity)
        assert abs(best - tau_star) <= max(2.0, 0.35 * tau_star)


def test_delay_model_speedup():
    d = delay_model([1.0, 1.2, 0.9], o=5.0, tau=5)
    assert d["c_syn"] == pytest.approx(6.2)
    assert d["c_avg"] == pytest.approx(2.2)
    assert d["speedup"] > 2.0


# ---------------------------------------------------------------------------
# variance bounds (Thm. 1) + historical store
# ---------------------------------------------------------------------------

def test_theorem1_bound_grows_with_depth():
    b2 = theorem1_bound(0.9, 0.9, 5.0, 2)
    b3 = theorem1_bound(0.9, 0.9, 5.0, 3)
    assert b3 > b2 > 0


def test_minibatch_variance_matches_eq7(rng):
    g = jnp.asarray(rng.random(50) + 0.1, jnp.float32)
    mask = jnp.ones(50, jnp.float32)
    p = importance_probs(g, mask)
    v = float(minibatch_variance(g, p, mask))
    assert np.isfinite(v) and v > 0


def test_push_embeddings_and_staleness():
    hist = jnp.zeros((10, 4))
    age = jnp.asarray([5] * 10, jnp.int32)
    batch = jnp.asarray([1, 3, 5])
    vals = jnp.ones((3, 4))
    valid = jnp.asarray([True, True, False])
    h2, age2 = push_embeddings(hist, age, batch, vals, valid)
    np.testing.assert_allclose(np.asarray(h2[1]), 1.0)
    np.testing.assert_allclose(np.asarray(h2[3]), 1.0)
    np.testing.assert_allclose(np.asarray(h2[5]), 0.0)   # invalid: unchanged
    assert int(age2[1]) == 0 and int(age2[3]) == 0
    assert int(age2[0]) == 6                             # others aged
    m = staleness_metrics(age2, jnp.ones(10))
    assert float(m["mean_age"]) > 0
