"""recurrentgemma-2b [hybrid] — Griffin: 26L d_model=2560 10H (MQA kv=1)
d_ff=7680, vocab=256000, RG-LRU + local attention at 1:2 (one attention
per two recurrent blocks). 26 = 8x(rec,rec,local) + 2x rec remainder
(layer count exact; see DESIGN.md §6.4). [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,            # MQA
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,            # griffin uses 256
        source="arXiv:2402.19427",
        block_pattern=("rec", "rec", "local"),
        window_size=2048,
        rglru_width=2560,
        conv1d_width=4,
        activation="gelu",
        gated_mlp=True,
        tie_embeddings=True,
        pos_embedding="rope",
        max_seq_len=1 << 20,     # local attn + O(1) recurrent state
    )


def smoke() -> ModelConfig:
    return smoke_variant(config(), block_pattern=("rec", "local"), n_kv_heads=1)


register("recurrentgemma-2b", config, smoke)
