"""Synthetic heavy-traffic load generator + latency ledger for the server.

``LoadGenerator`` drives a :class:`QueryEngine` with seeded mixed traffic —
node-classification queries (Zipf-popular node ids, variable request sizes)
interleaved with streaming graph updates (edge inserts / node arrivals) and
periodic background cache refreshes. Two arrival disciplines:

* ``mode="open"``  — open-loop Poisson arrivals at ``rate`` req/s: requests
  queue while the engine is busy, so latency includes queueing delay (the
  heavy-traffic regime; the simulation clock advances by *measured*
  wall-clock service times);
* ``mode="closed"`` — ``concurrency`` clients each issue their next request
  the moment the previous one completes (latency == service time).

``LatencyLedger`` collects per-query records and summarises them into the
schema-guarded ``BENCH_serve.json`` payload (p50/p99 per bucket, queries/s,
batch occupancy, cache hit/invalidation rates); ``validate_bench_serve`` is
the write gate, in the style of ``benchmarks.perf_round.validate_bench_round``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.federated.quant import SYNC_DTYPES
from repro.serve.engine import CACHE_POLICIES, QueryEngine

LOAD_MODES = ("open", "closed")

# BENCH_serve.json required top-level keys (see validate_bench_serve)
_TOP_KEYS = ("bench", "backend", "devices", "quick", "mode", "policy_mix",
             "n_queries", "n_updates", "queries_per_s", "p50_ms", "p99_ms",
             "batch_occupancy", "cache_hit_rate", "invalidation_rate",
             "rows_invalidated", "rows_refreshed", "buckets")
_BUCKET_KEYS = ("bucket", "n", "p50_ms", "p99_ms")
# the accuracy-vs-latency cache column (launch.serve_fed --cache-dtype):
# optional in ad-hoc ledgers, but the committed BENCH_serve.json carries it
# (tests/test_bench_schema.py pins that)
_CACHE_KEYS = ("cache_dtype", "resident_bytes", "serve_accuracy")
# the fused-vs-two-call hot-path column (launch.serve_fed measures both
# engine modes on the same warm model): optional in ad-hoc ledgers, the
# committed BENCH_serve.json carries it, and the pipeline gates
# p50_ms <= twocall_p50_ms with zero post-warmup recompiles
_FUSED_KEYS = ("bucket", "p50_ms", "twocall_p50_ms", "speedup",
               "recompiles_after_warmup")


def _pctl(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) else 0.0


def validate_bench_serve(payload) -> list[str]:
    """Schema-check a BENCH_serve.json payload. Returns a list of problems
    (empty = valid): required keys present and typed, percentiles ordered,
    rates in range, and the per-bucket rows accounting for every query."""
    errs: list[str] = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected dict"]
    for k in _TOP_KEYS:
        if k not in payload:
            errs.append(f"missing key {k!r}")
    if errs:
        return errs
    if payload["bench"] != "serve_latency":
        errs.append(f"bench is {payload['bench']!r}, expected 'serve_latency'")
    if not isinstance(payload["devices"], int) or payload["devices"] < 1:
        errs.append(f"devices must be a positive int, got {payload['devices']!r}")
    if not isinstance(payload["quick"], bool):
        errs.append(f"quick must be a bool, got {payload['quick']!r}")
    if payload["mode"] not in LOAD_MODES:
        errs.append(f"mode must be one of {LOAD_MODES}, got {payload['mode']!r}")
    if not isinstance(payload["policy_mix"], dict) or not all(
            p in CACHE_POLICIES for p in payload["policy_mix"]):
        errs.append(f"policy_mix must map {CACHE_POLICIES} to weights, "
                    f"got {payload['policy_mix']!r}")
    nq, nu = payload["n_queries"], payload["n_updates"]
    if not isinstance(nq, int) or nq < 1:
        errs.append(f"n_queries must be a positive int, got {nq!r}")
    if not isinstance(nu, int) or nu < 0:
        errs.append(f"n_updates must be a non-negative int, got {nu!r}")
    for k in ("queries_per_s", "p50_ms", "p99_ms"):
        v = payload[k]
        if not isinstance(v, (int, float)) or not v > 0:
            errs.append(f"{k} must be positive, got {v!r}")
    if isinstance(payload["p50_ms"], (int, float)) \
            and isinstance(payload["p99_ms"], (int, float)) \
            and payload["p99_ms"] < payload["p50_ms"]:
        errs.append(f"p99_ms {payload['p99_ms']!r} < p50_ms {payload['p50_ms']!r}")
    occ = payload["batch_occupancy"]
    if not isinstance(occ, (int, float)) or not 0 < occ <= 1:
        errs.append(f"batch_occupancy must be in (0, 1], got {occ!r}")
    for k in ("cache_hit_rate", "invalidation_rate"):
        v = payload[k]
        if not isinstance(v, (int, float)) or not 0 <= v <= 1:
            errs.append(f"{k} must be in [0, 1], got {v!r}")
    for k in ("rows_invalidated", "rows_refreshed"):
        v = payload[k]
        if not isinstance(v, int) or v < 0:
            errs.append(f"{k} must be a non-negative int, got {v!r}")
    buckets = payload["buckets"]
    if not isinstance(buckets, list) or not buckets:
        return errs + ["buckets must be a non-empty list"]
    n_acc = 0
    for i, row in enumerate(buckets):
        if not isinstance(row, dict) or any(k not in row for k in _BUCKET_KEYS):
            errs.append(f"buckets[{i}] missing keys (need {_BUCKET_KEYS})")
            continue
        if not isinstance(row["bucket"], int) or row["bucket"] < 1:
            errs.append(f"buckets[{i}].bucket must be a positive int")
        if not isinstance(row["n"], int) or row["n"] < 0:
            errs.append(f"buckets[{i}].n must be a non-negative int")
        else:
            n_acc += row["n"]
        if isinstance(row.get("p50_ms"), (int, float)) \
                and isinstance(row.get("p99_ms"), (int, float)) \
                and row["p99_ms"] < row["p50_ms"]:
            errs.append(f"buckets[{i}]: p99_ms < p50_ms")
    if isinstance(nq, int) and n_acc != nq and not errs:
        errs.append(f"bucket rows account for {n_acc} queries, "
                    f"n_queries says {nq}")
    cache = payload.get("cache")
    if cache is not None:
        if not isinstance(cache, dict) or any(k not in cache
                                              for k in _CACHE_KEYS):
            errs.append(f"cache column missing keys (need {_CACHE_KEYS})")
        else:
            if cache["cache_dtype"] not in SYNC_DTYPES:
                errs.append(f"cache.cache_dtype must be one of {SYNC_DTYPES}, "
                            f"got {cache['cache_dtype']!r}")
            rb = cache["resident_bytes"]
            if not isinstance(rb, int) or rb < 1:
                errs.append(f"cache.resident_bytes must be a positive int, "
                            f"got {rb!r}")
            acc = cache["serve_accuracy"]
            if not isinstance(acc, (int, float)) or not 0.0 <= acc <= 1.0:
                errs.append(f"cache.serve_accuracy must be in [0, 1], "
                            f"got {acc!r}")
    fused = payload.get("fused")
    if fused is not None:
        if not isinstance(fused, dict) or any(k not in fused
                                              for k in _FUSED_KEYS):
            errs.append(f"fused column missing keys (need {_FUSED_KEYS})")
        else:
            if not isinstance(fused["bucket"], int) or fused["bucket"] < 1:
                errs.append(f"fused.bucket must be a positive int, "
                            f"got {fused['bucket']!r}")
            for k in ("p50_ms", "twocall_p50_ms", "speedup"):
                v = fused[k]
                if not isinstance(v, (int, float)) or not v > 0:
                    errs.append(f"fused.{k} must be positive, got {v!r}")
            rc = fused["recompiles_after_warmup"]
            if not isinstance(rc, int) or rc < 0:
                errs.append(f"fused.recompiles_after_warmup must be a "
                            f"non-negative int, got {rc!r}")
    return errs


@dataclass
class QueryRecord:
    arrival: float          # sim-clock seconds
    done: float
    n_nodes: int
    bucket: int
    policy: str
    hit_rate: float

    @property
    def latency_ms(self) -> float:
        return (self.done - self.arrival) * 1e3


@dataclass
class LatencyLedger:
    """Accumulates per-query/update records and emits the BENCH payload."""

    queries: list = field(default_factory=list)
    updates: list = field(default_factory=list)
    occupancies: list = field(default_factory=list)
    refresh_rows: int = 0
    horizon_s: float = 0.0
    rejects: int = 0

    def record_query(self, **kw) -> None:
        self.queries.append(QueryRecord(**kw))

    def record_reject(self) -> None:
        self.rejects += 1

    def record_update(self, kind: str, n_invalidated: int, dt_s: float) -> None:
        self.updates.append({"kind": kind, "n_invalidated": n_invalidated,
                             "dt_s": dt_s})

    def record_batch(self, occupancy: float) -> None:
        self.occupancies.append(occupancy)

    def record_refresh(self, n_rows: int) -> None:
        self.refresh_rows += n_rows

    def summary(self, *, backend: str, devices: int, quick: bool, mode: str,
                policy_mix: dict, model_summary: dict | None = None,
                degraded: dict | None = None,
                cache: dict | None = None,
                fused: dict | None = None) -> dict:
        lat = [q.latency_ms for q in self.queries]
        by_bucket: dict[int, list] = {}
        by_policy: dict[str, list] = {}
        for q in self.queries:
            by_bucket.setdefault(q.bucket, []).append(q.latency_ms)
            by_policy.setdefault(q.policy, []).append(q.latency_ms)
        n_inval = sum(u["n_invalidated"] for u in self.updates)
        n_touched = sum(q.n_nodes for q in self.queries)
        payload = {
            "bench": "serve_latency",
            "backend": backend,
            "devices": devices,
            "quick": quick,
            "mode": mode,
            "policy_mix": dict(policy_mix),
            "n_queries": len(self.queries),
            "n_updates": len(self.updates),
            "queries_per_s": len(self.queries) / max(self.horizon_s, 1e-9),
            "nodes_per_s": n_touched / max(self.horizon_s, 1e-9),
            "p50_ms": _pctl(lat, 50),
            "p99_ms": _pctl(lat, 99),
            "batch_occupancy": (float(np.mean(self.occupancies))
                                if self.occupancies else 0.0),
            "cache_hit_rate": (float(np.mean([q.hit_rate for q in self.queries]))
                               if self.queries else 1.0),
            "invalidation_rate": n_inval / max(n_inval + n_touched, 1),
            "rows_invalidated": n_inval,
            "rows_refreshed": self.refresh_rows,
            "buckets": [
                {"bucket": b, "n": len(xs), "p50_ms": _pctl(xs, 50),
                 "p99_ms": _pctl(xs, 99)}
                for b, xs in sorted(by_bucket.items())
            ],
            "policies": {
                p: {"n": len(xs), "p50_ms": _pctl(xs, 50), "p99_ms": _pctl(xs, 99)}
                for p, xs in sorted(by_policy.items())
            },
        }
        if model_summary:
            payload["model"] = model_summary
        if cache is not None:
            # the accuracy-vs-latency column: which wire format the h1
            # cache is resident in, what it costs, what accuracy it serves
            payload["cache"] = dict(cache)
        if fused is not None:
            # the fused-vs-two-call hot-path A/B (launch.serve_fed measures
            # both engine modes on the same warm model + bucket)
            payload["fused"] = dict(fused)
        if degraded is not None or self.rejects:
            # engine degradation counters + the requests this ledger shed
            payload["degraded"] = {"n_shed": self.rejects, **(degraded or {})}
        return payload


class LoadGenerator:
    """Seeded synthetic traffic against a warmed :class:`QueryEngine`."""

    def __init__(self, engine: QueryEngine, *, seed: int = 0,
                 n_queries: int = 200, n_updates: int = 20,
                 mode: str = "open", rate: float = 500.0,
                 concurrency: int = 8, query_size: tuple[int, int] = (1, 4),
                 policy_mix: dict | None = None,
                 update_mix: dict | None = None,
                 zipf_a: float = 1.3, refresh_every: int = 4,
                 refresh_rows: int | None = None):
        if mode not in LOAD_MODES:
            raise ValueError(f"unknown mode {mode!r}; known: {LOAD_MODES}")
        self.engine = engine
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.n_queries = int(n_queries)
        self.n_updates = int(n_updates)
        self.mode = mode
        self.rate = float(rate)
        self.concurrency = int(concurrency)
        self.query_size = query_size
        self.policy_mix = dict(policy_mix or {"historical": 0.9, "fresh": 0.1})
        if not all(p in CACHE_POLICIES for p in self.policy_mix):
            raise ValueError(f"policy_mix keys must be in {CACHE_POLICIES}")
        self.update_mix = dict(update_mix or {"edges": 0.75, "nodes": 0.25})
        self.zipf_a = zipf_a
        self.refresh_every = int(refresh_every)
        self.refresh_rows = refresh_rows

    # -- traffic synthesis ----------------------------------------------

    def _node_ids(self, n: int) -> np.ndarray:
        """Zipf-popular node ids over the live rows (heavy-traffic skew)."""
        n_active = self.engine.model.n_active
        ranks = np.minimum(self.rng.zipf(self.zipf_a, size=n), n_active) - 1
        # a fixed permutation decouples popularity rank from node id; it is
        # derived from this generator's own seed (salted so it does not
        # mirror any other seed-keyed stream) rather than a hard-coded
        # constant, so differently-seeded generators hammer different hot
        # sets — and it deliberately does NOT consume from self.rng, which
        # would shift every later arrival/policy draw whenever n_active
        # grows past a re-derivation
        if getattr(self, "_perm_n", None) != n_active:
            self._perm = np.random.default_rng(
                (self.seed, 12345)).permutation(n_active)
            self._perm_n = n_active
        return self._perm[ranks]

    def _make_query(self, arrival: float) -> dict:
        lo, hi = self.query_size
        size = int(self.rng.integers(lo, hi + 1))
        names, probs = zip(*self.policy_mix.items())
        policy = str(self.rng.choice(names, p=np.asarray(probs) / sum(probs)))
        return {"t": arrival, "ids": self._node_ids(size), "policy": policy}

    def _apply_update(self, ledger: LatencyLedger) -> float:
        """One streaming update; returns its measured wall-clock seconds."""
        eng = self.engine
        names, probs = zip(*self.update_mix.items())
        kind = str(self.rng.choice(names, p=np.asarray(probs) / sum(probs)))
        t0 = time.perf_counter()
        if kind == "nodes":
            # a new node arrives with features near an existing node's and
            # attaches to 1-3 popular anchors
            anchor = int(self._node_ids(1)[0])
            feat = (eng.model.store.features[anchor]
                    + 0.1 * self.rng.standard_normal(eng.model.store.n_features))
            new_id = eng.model.n_active
            anchors = self._node_ids(int(self.rng.integers(1, 4)))
            edges = [(new_id, int(a)) for a in anchors]
            _, affected = eng.add_nodes(feat[None, :], edges)
        else:
            u, v = self._node_ids(2)
            affected = eng.add_edges([(int(u), int(v))])
        dt = time.perf_counter() - t0
        ledger.record_update(kind, len(affected), dt)
        return dt

    # -- the drive loop --------------------------------------------------

    def run(self) -> LatencyLedger:
        if self.engine.trace_count_after_warmup is None:
            self.engine.warmup()
        ledger = LatencyLedger()
        if self.mode == "open":
            self._run_open(ledger)
        else:
            self._run_closed(ledger)
        return ledger

    def _serve(self, batch: list[dict], now: float,
               ledger: LatencyLedger) -> float:
        """Serve one packed micro-batch; returns the completion time."""
        # queueing delay so far drives the engine's deadline downgrade
        queue_ms = max(0.0, (now - min(q["t"] for q in batch)) * 1e3)
        t0 = time.perf_counter()
        _, info = self.engine.serve_batch([q["ids"] for q in batch],
                                          policy=batch[0]["policy"],
                                          queue_ms=queue_ms)
        dt = time.perf_counter() - t0
        done = now + dt
        ledger.record_batch(info["occupancy"])
        for q, chunk in zip(batch, _spread(info["chunks"], batch)):
            # record the policy that actually ran (deadline downgrades and
            # fresh-path fallbacks land in the "historical" bucket)
            ledger.record_query(arrival=q["t"], done=done, n_nodes=len(q["ids"]),
                                bucket=chunk["bucket"], policy=chunk["policy"],
                                hit_rate=info["hit_rate"])
        return done

    def _run_open(self, ledger: LatencyLedger) -> None:
        """Poisson arrivals; the engine drains the queue batch by batch."""
        n_ev = self.n_queries + self.n_updates
        gaps = self.rng.exponential(1.0 / self.rate, size=n_ev)
        times = np.cumsum(gaps)
        kinds = np.array(["q"] * self.n_queries + ["u"] * self.n_updates)
        self.rng.shuffle(kinds)
        events = [(float(t), k) for t, k in zip(times, kinds)]
        bmax = self.engine.buckets[-1]
        now, i, n_batches = 0.0, 0, 0
        pending: list[dict] = []
        while i < len(events) or pending:
            if not pending and i < len(events):
                now = max(now, events[i][0])
            while i < len(events) and events[i][0] <= now:
                t, kind = events[i]
                i += 1
                if kind == "q":
                    if self.engine.admit(len(pending)):
                        pending.append(self._make_query(t))
                    else:
                        ledger.record_reject()
                else:
                    now += self._apply_update(ledger)
            if not pending:
                continue
            # pack queued same-policy requests into one micro-batch
            policy = pending[0]["policy"]
            batch, rows = [], 0
            while pending and pending[0]["policy"] == policy \
                    and rows + len(pending[0]["ids"]) <= bmax:
                q = pending.pop(0)
                batch.append(q)
                rows += len(q["ids"])
            if not batch:                       # single oversized request
                batch = [pending.pop(0)]
            now = self._serve(batch, now, ledger)
            n_batches += 1
            if self.refresh_every and n_batches % self.refresh_every == 0:
                t0 = time.perf_counter()
                n = self.engine.refresh(self.refresh_rows)
                if n:
                    now += time.perf_counter() - t0
                    ledger.record_refresh(n)
        ledger.horizon_s = now

    def _run_closed(self, ledger: LatencyLedger) -> None:
        """``concurrency`` clients in lockstep: every completion immediately
        issues the next request, so each batch carries one request per
        client and latency equals service time."""
        now, served, n_batches = 0.0, 0, 0
        upd_interval = (max(1, self.n_queries // self.n_updates)
                        if self.n_updates else 0)
        updates_done = 0
        while served < self.n_queries:
            c = min(self.concurrency, self.n_queries - served)
            batch = [self._make_query(now) for _ in range(c)]
            # all requests in a closed-loop batch share one policy draw
            policy = batch[0]["policy"]
            for q in batch:
                q["policy"] = policy
            now = self._serve(batch, now, ledger)
            served += c
            n_batches += 1
            if upd_interval and updates_done < self.n_updates \
                    and served // upd_interval > updates_done:
                now += self._apply_update(ledger)
                updates_done += 1
            if self.refresh_every and n_batches % self.refresh_every == 0:
                t0 = time.perf_counter()
                n = self.engine.refresh(self.refresh_rows)
                if n:
                    now += time.perf_counter() - t0
                    ledger.record_refresh(n)
        # drain any never-applied updates so n_updates is honest
        while updates_done < self.n_updates:
            now += self._apply_update(ledger)
            updates_done += 1
        ledger.horizon_s = now


def _spread(chunks: list[dict], batch: list[dict]) -> list[dict]:
    """Assign each request the chunk it landed in (requests are packed in
    order; a request spanning chunks reports its first one)."""
    out = []
    ci, used = 0, 0
    for q in batch:
        if ci < len(chunks) - 1 and used >= chunks[ci]["real"]:
            ci += 1
            used = 0
        out.append(chunks[ci])
        used += len(q["ids"])
    return out
