"""Mixture-of-Experts FFN with top-k routing and sort-based capacity dispatch.

TPU-native design (DESIGN.md §4): instead of PyG/torch-style ragged
gather-scatter, tokens are sorted by expert id and packed into a dense
(E, C, d) buffer so the expert matmuls are batched dense MXU ops; the
dispatch/combine are single scatters. Experts shard over the `model` mesh
axis (expert parallelism: dbrx 16e/16-way, arctic 128e -> 8 per chip).

Load-balance aux loss follows the standard switch-transformer form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation_fn, dense_init, mlp_apply, mlp_init, shard_activation


def moe_init(key, cfg) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.jnp_dtype
    keys = jax.random.split(key, 5)
    n_mats = 3 if cfg.gated_mlp else 2

    def expert_stack(k, d_in, d_out):
        ks = jax.random.split(k, E)
        return jax.vmap(lambda kk: dense_init(kk, d_in, d_out, dt))(ks)

    p = {
        "router": dense_init(keys[0], d, E, jnp.float32),
        "w_in": expert_stack(keys[1], d, ff),
        "w_out": expert_stack(keys[2], ff, d),
    }
    if cfg.gated_mlp:
        p["w_gate"] = expert_stack(keys[3], d, ff)
    if cfg.moe_dense_residual:  # arctic: parallel dense FFN
        p["dense"] = mlp_init(keys[4], d, cfg.dense_ff_dim or ff, cfg.gated_mlp, dt)
    return p


def moe_apply(params: dict, cfg, x: jnp.ndarray):
    """Dispatch on cfg.moe_impl: 'sort' (baseline) or 'einsum' (partition-friendly)."""
    if getattr(cfg, "moe_impl", "sort") == "einsum":
        return moe_apply_einsum(params, cfg, x)
    return moe_apply_sort(params, cfg, x)


def moe_apply_einsum(params: dict, cfg, x: jnp.ndarray):
    """Group-wise one-hot dispatch (MaxText-style), x: (B, S, d).

    Each batch row is its own routing group, so every tensor keeps a leading
    B dim that stays sharded on the data axes — no global gather/scatter, and
    the expert reduction partitions as einsums (§Perf hillclimb H1: the sort
    dispatch's token gather forced SPMD full rematerialisation + ~350s of
    all-gather on dbrx train_4k).
    """
    B0, S0, d = x.shape
    g = getattr(cfg, "moe_group_size", 0) or S0
    g = min(g, S0)
    if S0 % g:
        g = S0
    # regroup: (B0, S0) -> (B0*S0/g, g); groups are the routing unit, so the
    # dispatch one-hot einsum costs O(g·C) = O(g²·k·cf/E) per token group
    x = x.reshape(B0 * S0 // g, g, d)
    B, S, _ = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = int(max(1, round(S * K / E * cfg.capacity_factor)))

    router_logits = x.astype(jnp.float32) @ params["router"]            # (B, S, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                              # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (B * S * K)
    aux_loss = E * jnp.sum(me * ce)

    # position of each (token, k) within its expert, per group
    expert_onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)         # (B, S, K, E)
    pos = jnp.cumsum(expert_onehot.reshape(B, S * K, E), axis=1).reshape(B, S, K, E)
    pos = pos * expert_onehot - 1.0                                     # slot index, -1 if unrouted
    keep = (pos >= 0) & (pos < C)
    slot_onehot = jax.nn.one_hot(jnp.where(keep, pos, -1).astype(jnp.int32).max(-1),
                                 C, dtype=x.dtype)                      # (B, S, K, C)
    # combine (B,S,K,E) x (B,S,K,C) -> dispatch mask (B, S, E, C)
    dispatch = jnp.einsum("bske,bskc->bsec",
                          (expert_onehot * keep).astype(x.dtype), slot_onehot)
    weights = jnp.einsum("bske,bsk->bse", (expert_onehot * keep).astype(jnp.float32),
                         top_w)                                         # (B, S, E)

    buf = jnp.einsum("bsec,bsd->ebcd", dispatch, x)                     # (E, B, C, d)
    buf = shard_activation(buf, "experts", "batch", None, None)

    act = activation_fn(cfg.activation)
    h = jnp.einsum("ebcd,edf->ebcf", buf, params["w_in"])
    if cfg.gated_mlp:
        g = jnp.einsum("ebcd,edf->ebcf", buf, params["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    y = jnp.einsum("ebcf,efd->ebcd", h, params["w_out"])                # (E, B, C, d)

    out = jnp.einsum("ebcd,bsec->bsd", y, dispatch * weights[..., None].astype(x.dtype))
    if "dense" in params:
        out = out + mlp_apply(params["dense"], x, cfg.activation)
    return out.reshape(B0, S0, d), aux_loss


def moe_apply_sort(params: dict, cfg, x: jnp.ndarray):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    router_logits = xf.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                             # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)    # renormalise

    # ---- load-balance auxiliary loss (switch-style) ----
    me = probs.mean(axis=0)                                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * K)
    aux_loss = E * jnp.sum(me * ce)

    # ---- sort-based dispatch into (E, C, d) ----
    C = int(max(1, round(T * K / E * cfg.capacity_factor)))
    flat_e = top_i.reshape(-1)                                         # (T*K,)
    flat_t = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K)).reshape(-1)
    flat_w = top_w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert segment = index - segment start
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")       # (E,)
    rank = jnp.arange(T * K) - seg_start[se]
    keep = rank < C

    # scatter token features into the expert buffer; dropped -> bucket E
    idx_e = jnp.where(keep, se, E)
    idx_c = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E + 1, C, d), x.dtype)
    buf = buf.at[idx_e, idx_c].set(xf[st] * keep[:, None].astype(x.dtype))
    buf = buf[:E]                                                      # (E, C, d)
    buf = shard_activation(buf, "experts", None, None)

    # ---- expert MLPs: batched dense matmuls over the expert axis ----
    act = activation_fn(cfg.activation)
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    h = shard_activation(h, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, params["w_out"])                 # (E, C, d)

    # ---- combine back, weighted ----
    y_pad = jnp.concatenate([y, jnp.zeros((1, C, d), y.dtype)], axis=0)
    vals = y_pad[idx_e, idx_c] * (sw * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((T, d), y.dtype).at[st].add(vals)
    out = out.reshape(B, S, d)

    if "dense" in params:  # arctic dense residual path
        out = out + mlp_apply(params["dense"], x, cfg.activation)
    return out, aux_loss
