"""Centralized graph-sampling strategies (related-work section of the paper).

These are the three classical families the paper contrasts with — node-wise
(GraphSAGE), layer-wise (FastGCN) and subgraph (ClusterGCN-style) — provided
for the centralized-vs-federated comparison benchmark. They operate on the
padded neighbor-list form.
"""
from __future__ import annotations

import numpy as np


def node_wise_sample(nbr_idx, nbr_mask, fanout: int, rng: np.random.Generator):
    """GraphSAGE-style: keep <= fanout random neighbors per node."""
    n, K = nbr_idx.shape
    if fanout >= K:
        return nbr_idx, nbr_mask
    scores = rng.random((n, K)) * nbr_mask - (1.0 - nbr_mask)
    keep = np.argsort(-scores, axis=1)[:, :fanout]
    new_idx = np.take_along_axis(nbr_idx, keep, axis=1)
    new_mask = np.take_along_axis(nbr_mask, keep, axis=1)
    return new_idx.astype(np.int32), new_mask.astype(np.float32)


def layer_wise_sample(nbr_idx, nbr_mask, n_nodes: int, budget: int, rng: np.random.Generator):
    """FastGCN-style: sample a per-layer node budget by (approx) importance
    q(v) ∝ deg(v); neighbors outside the layer sample are masked."""
    deg = nbr_mask.sum(-1) + 1e-6
    q = deg / deg.sum()
    chosen = rng.choice(n_nodes, size=min(budget, n_nodes), replace=False, p=q)
    in_layer = np.zeros(n_nodes, bool)
    in_layer[chosen] = True
    new_mask = nbr_mask * in_layer[nbr_idx]
    return nbr_idx, new_mask.astype(np.float32)


def subgraph_sample(edges: np.ndarray, n_nodes: int, n_parts: int, rng: np.random.Generator):
    """ClusterGCN-style: random-hash partition into n_parts; returns the node
    partition id per node (true METIS is out of scope; the paper itself notes
    partitioning cost/sensitivity as the weakness of this family)."""
    return rng.integers(0, n_parts, size=n_nodes).astype(np.int32)
