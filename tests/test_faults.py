"""Deterministic fault injection + graceful degradation (repro.faults).

The contracts this file pins:

* **empty plans are inert** — ``faults=None``, ``FaultPlan()`` and
  ``guard=False`` all produce bit-identical histories through the
  stepwise AND fused executors (the no-fault paths did not move);
* **executor parity under faults** — the fault-aware fused chunk agrees
  with the stepwise path on every fault counter exactly and on the float
  history to fp32 reassociation tolerance (dropped rows are summed as
  interleaved zeros rather than compacted away, which reassociates the
  merge reduction — see repro.faults.fused);
* **nothing is silently averaged in** — non-finite (and, with a norm
  ceiling, finite-but-exploded) updates are quarantined and counted, a
  fully-dropped cohort is a server no-op round, and switching the guard
  off demonstrably lets the poison through;
* **async fault handling is bounded** — dropped uploads without a
  timeout are counted lost; with a timeout they retry with exponential
  backoff up to ``max_retries`` then abort + backfill; stale arrivals
  evict; every run still terminates with finite params;
* the checkpoint layer skips torn/corrupt files (newest valid wins).
"""
import os

import jax
import numpy as np
import pytest

from repro.api import AsyncScheduler, FedEngine, SyncScheduler
from repro.faults import (
    CORRUPT_MODES,
    FaultCounters,
    FaultPlan,
    UpdateGuard,
    corrupt_params_stack,
    guard_mask,
    tear_file,
)
from repro.federated.partition import partition_graph
from repro.graph.data import make_dataset

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

ROUNDS, COHORT = 4, 2


@pytest.fixture(scope="module")
def small():
    g = make_dataset("pubmed", scale=32, seed=0)
    fed = partition_graph(g, 4, alpha=0.5, seed=0)
    return g, fed


def run(small, *, rounds=ROUNDS, m=COHORT, scheduler=None, **kw):
    g, fed = small
    engine = FedEngine(g, fed, "fedais", rounds=rounds, clients_per_round=m,
                       seed=0, eval_every=2,
                       scheduler=scheduler or SyncScheduler(fused=False), **kw)
    state = engine.init_state()
    result = engine.run(state)
    return engine, state, result


def assert_history_equal(a, b, keys=None):
    keys = keys if keys is not None else set(a.history) | set(b.history)
    for k in keys:
        np.testing.assert_array_equal(
            np.asarray(a.history[k]), np.asarray(b.history[k]), err_msg=k)


def params_leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state.params)]


def all_finite(state) -> bool:
    return all(np.isfinite(x).all() for x in params_leaves(state))


# ---------------------------------------------------------------------------
# FaultPlan: validation + deterministic draws
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="dropout"):
        FaultPlan(dropout=1.5)
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultPlan(corrupt_mode="martian")
    with pytest.raises(ValueError, match="straggler_mult"):
        FaultPlan(straggler_mult=0.5)
    assert FaultPlan().empty
    assert not FaultPlan(dropout=0.1).empty
    assert FaultPlan().describe() == "none"
    slug = FaultPlan(dropout=0.4, corrupt=0.2, corrupt_mode="inf").describe()
    assert slug == "drop0.4+corrupt0.2:inf"
    snap = FaultPlan(dropout=0.4).snapshot()
    assert snap["dropout"] == 0.4 and snap["corrupt_mode"] in CORRUPT_MODES


def test_plan_draws_are_deterministic_and_independent():
    sel = np.arange(6)
    a = FaultPlan(seed=3, dropout=0.4, corrupt=0.5)
    b = FaultPlan(seed=3, dropout=0.4, corrupt=0.5)
    np.testing.assert_array_equal(a.drops(2, sel), b.drops(2, sel))
    np.testing.assert_array_equal(a.corruptions(2, sel), b.corruptions(2, sel))
    # per-kind salts: changing the dropout rate must not reshuffle who is
    # corrupted, and vice versa
    c = FaultPlan(seed=3, dropout=0.9, corrupt=0.5)
    np.testing.assert_array_equal(a.corruptions(2, sel), c.corruptions(2, sel))
    # a different seed is a different scenario
    d = FaultPlan(seed=4, dropout=0.4, corrupt=0.5)
    assert not (np.array_equal(a.drops(0, sel), d.drops(0, sel))
                and np.array_equal(a.drops(1, sel), d.drops(1, sel))
                and np.array_equal(a.drops(2, sel), d.drops(2, sel)))
    # rate-0 families never fire; rate-1 always fire
    assert not FaultPlan(seed=3).drops(0, sel).any()
    assert FaultPlan(seed=3, dropout=1.0).drops(0, sel).all()
    # stragglers are static per client (round-independent)
    s = FaultPlan(seed=3, straggler_frac=0.5)
    np.testing.assert_array_equal(s.stragglers(sel), s.stragglers(sel))
    f = s.delay_factors(sel)
    assert set(np.unique(f)) <= {1.0, s.straggler_mult}


def test_corrupt_value_modes():
    assert np.isnan(FaultPlan(corrupt_mode="nan").corrupt_value())
    assert np.isinf(FaultPlan(corrupt_mode="inf").corrupt_value())
    assert FaultPlan(corrupt_mode="scale",
                     corrupt_scale=42.0).corrupt_value() == 42.0


def test_guard_mask_and_corrupt_stack():
    stack = {"w": np.ones((4, 3), np.float32),
             "b": np.zeros((4, 2), np.float32)}
    ref = {"w": np.ones(3, np.float32), "b": np.zeros(2, np.float32)}
    poisoned = corrupt_params_stack(stack, np.array([0, 1, 0, 0], bool),
                                    float("nan"))
    ok = guard_mask(poisoned, ref, None)
    np.testing.assert_array_equal(ok, [True, False, True, True])
    # mult-by-1.0 rows are bit-identical (corruption never perturbs the rest)
    np.testing.assert_array_equal(np.asarray(poisoned["w"])[0], stack["w"][0])
    # a finite blow-up passes the finite check but not the norm ceiling
    blown = corrupt_params_stack(stack, np.array([0, 0, 1, 0], bool), 1e6)
    assert guard_mask(blown, ref, None).all()
    np.testing.assert_array_equal(guard_mask(blown, ref, 1e3),
                                  [True, True, False, True])


# ---------------------------------------------------------------------------
# the inertness contract: empty plans change nothing, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, None], ids=["stepwise", "fused"])
def test_empty_plan_bit_identical(small, fused):
    _, _, r_none = run(small, scheduler=SyncScheduler(fused=fused))
    _, _, r_empty = run(small, scheduler=SyncScheduler(fused=fused),
                        faults=FaultPlan())
    _, _, r_noguard = run(small, scheduler=SyncScheduler(fused=fused),
                          guard=False)
    assert_history_equal(r_none, r_empty)
    assert_history_equal(r_none, r_noguard)


def test_async_empty_plan_bit_identical(small):
    # generous knobs that never fire + an empty plan keep the event
    # trajectory identical (comm_factors stays None: setting it — even to
    # 1.0 — adds communication pricing the legacy path never billed)
    sched = AsyncScheduler(timeout_s=1e9, max_retries=3, max_staleness=100)
    _, st, r_plain = run(small, scheduler=AsyncScheduler())
    _, st2, r_knobs = run(small, scheduler=sched, faults=FaultPlan())
    assert_history_equal(r_plain, r_knobs)
    assert not st2.fault_events.any()


# ---------------------------------------------------------------------------
# dropout: zero-weight merges, no-op rounds, executor parity
# ---------------------------------------------------------------------------

def test_all_dropped_rounds_are_noops(small):
    plan = FaultPlan(seed=1, dropout=1.0)
    engine, state, _ = run(small, faults=plan)
    _, fresh, _ = run(small, rounds=0)
    for got, want in zip(params_leaves(state), params_leaves(fresh)):
        np.testing.assert_array_equal(got, want)
    ev = state.fault_events
    assert ev.n_dropped == ROUNDS * COHORT
    assert ev.n_empty_merges == ROUNDS
    assert all_finite(state)


def test_fused_matches_stepwise_under_faults(small):
    plan = FaultPlan(seed=7, dropout=0.35, corrupt=0.3)
    e1, s1, r1 = run(small, faults=plan, scheduler=SyncScheduler(fused=False))
    e2, s2, r2 = run(small, faults=plan, scheduler=SyncScheduler())
    assert e2.last_executor == "fused_faulty"
    assert s1.fault_events.snapshot() == s2.fault_events.snapshot()
    assert s1.fault_events.any()
    # interleaved-zero summation reassociates the merge reduction: float
    # history is allclose, everything discrete and cost-metered is exact
    for k in r1.history:
        a, b = np.asarray(r1.history[k]), np.asarray(r2.history[k])
        if k in ("test_loss", "test_acc", "f1", "auc"):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6, err_msg=k)
        else:
            np.testing.assert_array_equal(a, b, err_msg=k)
    assert all_finite(s1) and all_finite(s2)


# ---------------------------------------------------------------------------
# corruption: quarantine, the norm ceiling, and what "no guard" costs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["nan", "inf"])
def test_nonfinite_corruption_quarantined(small, mode):
    plan = FaultPlan(seed=2, corrupt=1.0, corrupt_mode=mode)
    engine, state, _ = run(small, faults=plan)
    _, fresh, _ = run(small, rounds=0)
    # every update poisoned -> every merge empty -> params never moved
    for got, want in zip(params_leaves(state), params_leaves(fresh)):
        np.testing.assert_array_equal(got, want)
    ev = state.fault_events
    assert ev.n_quarantined == ROUNDS * COHORT
    assert ev.n_empty_merges == ROUNDS
    assert all_finite(state)


def test_scale_corruption_needs_norm_ceiling(small):
    plan = FaultPlan(seed=2, corrupt=1.0, corrupt_mode="scale",
                     corrupt_scale=1e6)
    # the default (finite-only) guard admits the blow-up: params explode
    # (later rounds may overflow to non-finite updates the guard then
    # quarantines organically, so only the magnitude is asserted)
    _, loose, _ = run(small, faults=plan)
    assert any(np.abs(x).max() > 1e3 for x in params_leaves(loose))
    assert all_finite(loose)
    # ...the norm ceiling quarantines it
    _, tight, _ = run(small, faults=plan, guard=UpdateGuard(max_norm=1e3))
    assert tight.fault_events.n_quarantined == ROUNDS * COHORT
    _, fresh, _ = run(small, rounds=0)
    for got, want in zip(params_leaves(tight), params_leaves(fresh)):
        np.testing.assert_array_equal(got, want)


def test_guard_off_lets_poison_through(small):
    plan = FaultPlan(seed=2, corrupt=1.0, corrupt_mode="nan")
    _, state, _ = run(small, faults=plan, guard=False)
    assert state.fault_events.n_quarantined == 0
    assert not all_finite(state)


def test_counters_snapshot():
    c = FaultCounters()
    assert not c.any()
    c.n_dropped = 3
    assert c.any() and c.snapshot()["n_dropped"] == 3


# ---------------------------------------------------------------------------
# async: lost slots, bounded retry, staleness eviction, comm heterogeneity
# ---------------------------------------------------------------------------

def test_async_drop_without_timeout_loses_slots(small):
    plan = FaultPlan(seed=5, dropout=0.5)
    _, state, _ = run(small, faults=plan, scheduler=AsyncScheduler())
    ev = state.fault_events
    assert ev.n_lost > 0 and ev.n_timeouts == 0
    assert all_finite(state)


def test_async_timeout_retry_then_abort(small):
    plan = FaultPlan(seed=5, dropout=0.5)
    _, state, _ = run(
        small, faults=plan,
        scheduler=AsyncScheduler(timeout_s=5.0, max_retries=1, backoff=2.0))
    ev = state.fault_events
    assert ev.n_timeouts > 0 and ev.n_lost == 0
    assert ev.n_retries > 0
    # a client whose retries all drop is abandoned, never spun on
    assert ev.n_aborted > 0
    assert ev.n_timeouts == ev.n_retries + ev.n_aborted
    assert state.round + 1 == ROUNDS      # the run still completed
    assert all_finite(state)


def test_async_total_dropout_truncates_gracefully(small):
    plan = FaultPlan(seed=5, dropout=1.0)
    _, state, _ = run(
        small, faults=plan,
        scheduler=AsyncScheduler(timeout_s=5.0, max_retries=2))
    # every upload lost forever: the circuit breaker ends the run instead
    # of spinning, and params never moved
    _, fresh, _ = run(small, rounds=0)
    for got, want in zip(params_leaves(state), params_leaves(fresh)):
        np.testing.assert_array_equal(got, want)
    assert state.fault_events.n_timeouts > 0


def test_async_max_staleness_evicts(small):
    # mild skew: slow v0 stragglers still pop inside the horizon, where a
    # quorum-1 loop has already advanced the version past them
    sched = AsyncScheduler(quorum=1, concurrency=4,
                           speed_factors=[1.0, 2.0, 4.0, 8.0],
                           max_staleness=0)
    _, state, _ = run(small, rounds=8, scheduler=sched)
    assert state.fault_events.n_evicted > 0
    assert all_finite(state)


def test_async_comm_factors(small):
    _, _, r_base = run(small, scheduler=AsyncScheduler())
    _, _, r_ones = run(small, scheduler=AsyncScheduler(comm_factors=np.ones(4)))
    _, _, r_slow = run(small,
                       scheduler=AsyncScheduler(comm_factors=np.full(4, 50.0)))
    # setting comm_factors prices link time into every arrival (None bills
    # none), and slower links bill strictly more virtual wall-clock
    wall = lambda r: float(np.asarray(r.history["wall_clock"])[-1])  # noqa: E731
    assert wall(r_ones) > wall(r_base)
    assert wall(r_slow) > wall(r_ones)
    # heterogeneous timing never changes how much work merges
    np.testing.assert_array_equal(np.asarray(r_base.history["merged"]),
                                  np.asarray(r_slow.history["merged"]))
    with pytest.raises(ValueError, match="comm_factors"):
        run(small, scheduler=AsyncScheduler(comm_factors=np.ones(3)))


def test_async_knob_validation(small):
    with pytest.raises(ValueError, match="max_retries"):
        run(small, scheduler=AsyncScheduler(timeout_s=1.0, max_retries=-1))
    with pytest.raises(ValueError, match="backoff"):
        run(small, scheduler=AsyncScheduler(timeout_s=1.0, backoff=0.5))


# ---------------------------------------------------------------------------
# engine gating: what each executor supports under faults
# ---------------------------------------------------------------------------

def test_corrupt_plan_disables_sharded_executors(small):
    g, fed = small
    plan = FaultPlan(seed=1, corrupt=0.5)
    engine = FedEngine(g, fed, "fedais", rounds=2, clients_per_round=2,
                       seed=0, faults=plan)
    why = engine._sharded_faults_unsafe_reason()
    assert why and "corrupt" in why.lower()
    ok, _ = engine.sharded_eligibility()
    assert not ok
    # dropout/straggler-only plans do not trip the fault gate
    engine2 = FedEngine(g, fed, "fedais", rounds=2, clients_per_round=2,
                        seed=0, faults=FaultPlan(seed=1, dropout=0.5))
    assert not engine2._sharded_faults_unsafe_reason()


def test_engine_guard_validation(small):
    g, fed = small
    with pytest.raises(ValueError, match="guard"):
        FedEngine(g, fed, "fedais", rounds=1, guard="yes please")


# ---------------------------------------------------------------------------
# checkpoint: torn writes are skipped, newest valid wins
# ---------------------------------------------------------------------------

def test_torn_checkpoint_recovery(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import (checkpoint_steps, latest_step, load_latest,
                                  save_checkpoint)

    like = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.zeros(2)}}
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.ones((3, 3)), "b": {"c": jnp.ones(2)}})
    p2 = save_checkpoint(d, 2, {"a": 2 * jnp.ones((3, 3)),
                                "b": {"c": 2 * jnp.ones(2)}})
    assert tear_file(p2) < os.path.getsize(
        os.path.join(d, "step_00000001.msgpack"))
    step, tree = load_latest(d, like)
    assert step == 1
    assert float(np.asarray(tree["a"])[0, 0]) == 1.0
    with pytest.raises(Exception):
        load_latest(d, like, strict=True)
    assert checkpoint_steps(d) == [1, 2] and latest_step(d) == 2
    tear_file(os.path.join(d, "step_00000001.msgpack"))
    with pytest.raises(ValueError, match="candidate"):
        load_latest(d, like)


def test_load_latest_missing_dir(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import load_latest

    with pytest.raises(FileNotFoundError):
        load_latest(str(tmp_path / "nope"), {"a": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# sharded executors: dropout as zero-weight dead slots
# ---------------------------------------------------------------------------

@pytest.mark.sharded
@needs_devices
def test_sharded_dropout_matches_stepwise(small):
    from repro.sharding.fed import make_client_mesh

    g, fed = small
    m = 4
    n = max(d for d in range(1, N_DEV + 1) if m % d == 0)
    plan = FaultPlan(seed=7, dropout=0.35, straggler_frac=0.25)
    e1, s1, r1 = run(small, m=m, faults=plan,
                     scheduler=SyncScheduler(fused=False))
    e2, s2, r2 = run(small, m=m, faults=plan, scheduler=SyncScheduler(),
                     mesh=make_client_mesh(n))
    assert e2.last_executor == "sharded_fused"
    assert s1.fault_events.snapshot() == s2.fault_events.snapshot()
    for k in r1.history:
        a, b = np.asarray(r1.history[k]), np.asarray(r2.history[k])
        if k in ("test_loss", "test_acc", "f1", "auc"):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6, err_msg=k)
        else:
            np.testing.assert_array_equal(a, b, err_msg=k)


@pytest.mark.sharded
@needs_devices
def test_sharded_all_dropped_is_safe(small):
    from repro.sharding.fed import make_client_mesh

    g, fed = small
    m = 4
    n = max(d for d in range(1, N_DEV + 1) if m % d == 0)
    plan = FaultPlan(seed=1, dropout=1.0)
    engine, state, _ = run(small, m=m, faults=plan,
                           scheduler=SyncScheduler(),
                           mesh=make_client_mesh(n))
    assert engine.last_executor in ("sharded_fused", "pod_sharded")
    # an all-zero weight vector must fall back to the old params, not 0/0
    _, fresh, _ = run(small, rounds=0)
    for got, want in zip(params_leaves(state), params_leaves(fresh)):
        np.testing.assert_array_equal(got, want)
    assert all_finite(state)
