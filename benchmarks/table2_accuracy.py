"""Paper Table 2: accuracy / F1 / AUC of six methods, iid and non-iid.

Synthetic stand-in datasets (DESIGN.md §6.1): the claim validated is the
*relative* one — FedAIS reaches accuracy comparable to or better than the
baselines — not the absolute public-dataset numbers.
"""
from __future__ import annotations

from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup

METHODS = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph", "fedais")


def run(quick: bool = True) -> list[dict]:
    datasets = ["coauthor", "pubmed"] if quick else ["coauthor", "pubmed", "yelp", "reddit", "amazon2m"]
    scale = 32 if quick else 64
    rounds = 12 if quick else 40
    rows = []
    for ds in datasets:
        for setting in ("iid", "0.5"):
            g, fed = fed_setup(ds, scale, 16, setting)
            for m in METHODS:
                mcfg = method_config(m, tau0=4 if m == "fedais" else
                                     (2 if m == "fedpns" else 1))
                res = FedEngine(g, fed, mcfg, rounds=rounds,
                                clients_per_round=5, seed=0).run()
                rows.append({
                    "dataset": ds,
                    "setting": "iid" if setting == "iid" else "non-iid",
                    "method": m,
                    "test_acc": round(res.final["acc"] * 100, 2),
                    "f1": round(res.final["f1"] * 100, 2),
                    "auc": round(res.final["auc"] * 100, 2),
                })
    return rows
