"""Deterministic fault injection for the federated engine.

A ``FaultPlan`` is a *seeded description* of everything that can go wrong
in a deployment — clients dropping out mid-round, stragglers holding the
cohort hostage, poisoned/overflowed update uploads, torn checkpoint
writes — evaluated lazily per ``(kind, round, client)`` coordinate so
every executor (stepwise, fused, client-sharded, pod-sharded, async)
sees the *same* faults for the same plan, regardless of dispatch order
or how many rounds a chunk scans. Decisions come from
``np.random.default_rng((seed, kind, round, client))`` — a SeedSequence
spawn, stable across processes and platforms — so a chaos run is exactly
reproducible from its seed alone.

The plan only *describes* faults. Enforcement lives in three places:

* ``FedEngine`` (repro.api.engine) consumes ``drops`` / ``corruptions``
  / ``delay_factors`` between its dispatch and merge halves, and its
  merge path runs the ``UpdateGuard`` below so non-finite or
  norm-exploded updates are quarantined (counted in
  ``EngineState.fault_events``), never silently averaged in;
* ``AsyncScheduler`` (repro.api.protocols) prices straggler delays into
  the virtual clock and loses dropped uploads (timing out / retrying
  them when configured);
* ``checkpoint.ckpt`` / ``launch.fed_chaos`` use ``tear_file`` to
  simulate torn writes.

An empty plan (all rates zero) is inert by contract: every consumer
gates its behavior change on the fault actually firing, so runs with
``FaultPlan()`` — or no plan at all — stay bit-identical to the
pre-fault code paths (pinned by tests/test_faults.py).
"""
from __future__ import annotations

import os
from dataclasses import asdict, dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FaultPlan", "FaultCounters", "UpdateGuard", "guard_mask",
           "corrupt_params_stack", "tear_file", "CORRUPT_MODES"]

CORRUPT_MODES = ("nan", "inf", "scale")

# Event-kind salts: each fault family draws from its own independent
# stream, so e.g. raising `dropout` never reshuffles who gets corrupted.
_DROP, _CORRUPT, _STRAGGLE, _TORN = 11, 13, 17, 19


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, rate-parameterized fault scenario (see module docstring).

    dropout          P(a dispatched client's upload never reaches the
                     server) per (round, client).
    straggler_frac   fraction of the *client population* that is a
                     permanent straggler (static per client, like
                     AsyncScheduler.speed_factors).
    straggler_mult   compute/comm time multiplier for stragglers.
    corrupt          P(a client's uploaded params are corrupted) per
                     (round, client).
    corrupt_mode     "nan" | "inf" (non-finite poison; caught by the
                     finite guard) | "scale" (finite blow-up by
                     corrupt_scale; needs UpdateGuard.max_norm to catch).
    torn_write       P(a checkpoint save is torn mid-write) per step.
    """

    seed: int = 0
    dropout: float = 0.0
    straggler_frac: float = 0.0
    straggler_mult: float = 4.0
    corrupt: float = 0.0
    corrupt_mode: str = "nan"
    corrupt_scale: float = 1e6
    torn_write: float = 0.0

    def __post_init__(self):
        for name in ("dropout", "straggler_frac", "corrupt", "torn_write"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultPlan.{name} must be in [0, 1], got {v}")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}; "
                             f"known: {' | '.join(CORRUPT_MODES)}")
        if self.straggler_mult < 1.0:
            raise ValueError("straggler_mult must be >= 1 (a straggler is "
                             f"slower, not faster), got {self.straggler_mult}")

    @property
    def empty(self) -> bool:
        """True when the plan injects nothing (consumers treat it as None)."""
        return not (self.dropout or self.straggler_frac
                    or self.corrupt or self.torn_write)

    # -- deterministic per-coordinate draws --------------------------------

    def _fires(self, rate: float, *coords: int) -> bool:
        return np.random.default_rng(
            (self.seed,) + tuple(int(c) for c in coords)).random() < rate

    def drops(self, t: int, sel: Sequence[int]) -> np.ndarray:
        """Bool mask over the cohort: whose round-``t`` upload is lost."""
        sel = np.asarray(sel)
        if self.dropout <= 0.0:
            return np.zeros(len(sel), bool)
        return np.array([self._fires(self.dropout, _DROP, t, c) for c in sel])

    def corruptions(self, t: int, sel: Sequence[int]) -> np.ndarray:
        """Bool mask over the cohort: whose round-``t`` upload is corrupted."""
        sel = np.asarray(sel)
        if self.corrupt <= 0.0:
            return np.zeros(len(sel), bool)
        return np.array([self._fires(self.corrupt, _CORRUPT, t, c) for c in sel])

    def corrupt_value(self) -> float:
        """The per-element multiplier a corrupted upload is scaled by."""
        return {"nan": float("nan"), "inf": float("inf"),
                "scale": float(self.corrupt_scale)}[self.corrupt_mode]

    def stragglers(self, clients: Sequence[int]) -> np.ndarray:
        """Bool mask: which of ``clients`` are (static) stragglers."""
        clients = np.asarray(clients)
        if self.straggler_frac <= 0.0:
            return np.zeros(len(clients), bool)
        return np.array([self._fires(self.straggler_frac, _STRAGGLE, c)
                         for c in clients])

    def delay_factors(self, clients: Sequence[int]) -> np.ndarray:
        """Per-client wall-time multipliers (straggler_mult or 1.0)."""
        f = np.ones(len(np.asarray(clients)), np.float64)
        f[self.stragglers(clients)] = self.straggler_mult
        return f

    def tears_write(self, step: int) -> bool:
        """Does the checkpoint save at ``step`` tear mid-write?"""
        return self.torn_write > 0.0 and self._fires(self.torn_write, _TORN, step)

    def describe(self) -> str:
        """Compact scenario slug for bench rows / logs."""
        parts = []
        if self.dropout:
            parts.append(f"drop{self.dropout:g}")
        if self.straggler_frac:
            parts.append(f"strag{self.straggler_frac:g}x{self.straggler_mult:g}")
        if self.corrupt:
            parts.append(f"corrupt{self.corrupt:g}:{self.corrupt_mode}")
        if self.torn_write:
            parts.append(f"torn{self.torn_write:g}")
        return "+".join(parts) or "none"

    def snapshot(self) -> dict:
        return asdict(self)


@dataclass
class FaultCounters:
    """What the engine/scheduler actually did about faults, accumulated on
    ``EngineState.fault_events`` — the observable half of every injected
    (or organic) fault, so chaos runs can assert nothing was silently
    averaged in or silently lost."""

    n_dropped: int = 0        # cohort uploads that never reached a merge
    n_quarantined: int = 0    # non-finite / norm-exploded updates rejected
    n_empty_merges: int = 0   # merges with no survivor (server no-op round)
    n_timeouts: int = 0       # async waits that expired before arrival
    n_retries: int = 0        # async re-dispatches after a timeout
    n_aborted: int = 0        # async clients abandoned after max_retries
    n_evicted: int = 0        # async updates evicted past max_staleness
    n_lost: int = 0           # async slots lost with no timeout configured

    def any(self) -> bool:
        return any(v for v in vars(self).values())

    def snapshot(self) -> dict:
        return dict(vars(self))


@dataclass(frozen=True)
class UpdateGuard:
    """Merge-side admission rule for client updates: every leaf must be
    finite, and (when ``max_norm`` is set) the update's global L2 distance
    from the current server params must not exceed it. The finite check
    alone catches "nan"/"inf" corruption; "scale" corruption is finite and
    needs the norm ceiling. A guard that admits everything changes nothing
    — bit-parity with unguarded history is pinned by tests/test_faults.py."""

    max_norm: Optional[float] = None


@jax.jit
def _guard_stats(stacked, ref):
    """Per-client (all_finite, sum-of-squared-deltas-vs-ref) across leaves."""
    leaves = jax.tree_util.tree_leaves(stacked)
    refs = jax.tree_util.tree_leaves(ref)
    m = leaves[0].shape[0]
    ok = jnp.ones((m,), bool)
    sumsq = jnp.zeros((m,), jnp.float32)
    for x, r in zip(leaves, refs):
        flat = x.reshape(m, -1)
        ok &= jnp.all(jnp.isfinite(flat), axis=1)
        d = flat - r.reshape(1, -1)
        # non-finite deltas would poison sumsq; zero them (ok already False)
        d = jnp.where(jnp.isfinite(d), d, 0.0)
        sumsq += jnp.sum(d * d, axis=1)
    return ok, sumsq


def guard_mask(stacked, ref, max_norm: Optional[float]) -> np.ndarray:
    """Host-side admission mask for a stacked (m, ...) update pytree:
    True where the client's update passes the UpdateGuard."""
    ok, sumsq = jax.device_get(_guard_stats(stacked, ref))
    ok = np.array(ok, bool)        # copy: device_get views can be read-only
    if max_norm is not None:
        ok &= np.sqrt(np.asarray(sumsq, np.float64)) <= float(max_norm)
    return ok


def corrupt_params_stack(params_stack, mask: np.ndarray, value: float):
    """Multiply the masked members' rows of a stacked (m, ...) params
    pytree by ``value`` (NaN/inf poison or a finite blow-up) — the host
    half of corruption injection, shared by the stepwise engine path and
    the AsyncScheduler. Unmasked rows are multiplied by 1.0 (exact)."""
    m = len(mask)
    mult = np.ones(m, np.float32)
    mult[np.asarray(mask, bool)] = value
    mj = jnp.asarray(mult)
    return jax.tree_util.tree_map(
        lambda x: x * mj.reshape((m,) + (1,) * (x.ndim - 1)).astype(x.dtype),
        params_stack)


def tear_file(path: str, keep_frac: float = 0.5) -> int:
    """Simulate a torn write: truncate ``path`` to ``keep_frac`` of its
    bytes (at least 1 byte removed). Returns the new size."""
    size = os.path.getsize(path)
    keep = min(int(size * keep_frac), size - 1)
    keep = max(keep, 0)
    with open(path, "rb+") as f:
        f.truncate(keep)
    return keep
