"""Synthetic graph datasets mirroring the paper's Table 1 statistics.

The five public datasets (Coauthor/Pubmed/Yelp/Reddit/Amazon2M) are not
available offline, so we generate class-structured stochastic block model
graphs matched to each dataset's *published statistics* — node count (scaled
by ``scale``), average degree, feature dim (capped), class count and split
fractions — with Gaussian-mixture features so GCNs are actually learnable.
DESIGN.md §6.1 records this deviation; every benchmark prints the scale used.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.utils.tree import stable_hash


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_nodes: int          # Table 1 |V|
    n_edges: int          # Table 1 |E|
    n_features: int
    n_classes: int
    train_frac: float
    val_frac: float
    test_frac: float


# Table 1 of the paper, verbatim.
DATASET_SPECS: dict[str, DatasetSpec] = {
    "coauthor": DatasetSpec("coauthor", 18_333, 163_788, 6_805, 15, 0.8, 0.1, 0.1),
    "pubmed": DatasetSpec("pubmed", 19_717, 88_648, 500, 3, 0.8, 0.1, 0.1),
    "yelp": DatasetSpec("yelp", 716_847, 13_954_819, 300, 100, 0.75, 0.10, 0.15),
    "reddit": DatasetSpec("reddit", 232_965, 114_615_892, 602, 41, 0.66, 0.10, 0.24),
    "amazon2m": DatasetSpec("amazon2m", 2_449_029, 61_859_140, 100, 47, 0.8, 0.1, 0.1),
}


@dataclass
class GraphData:
    name: str
    features: np.ndarray       # (N, F) float32
    labels: np.ndarray         # (N,) int32
    edges: np.ndarray          # (E, 2) int32, undirected (each edge once)
    n_classes: int
    train_mask: np.ndarray     # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    spec: DatasetSpec

    @property
    def n_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def n_features(self) -> int:
        return self.features.shape[1]

    def adjacency_lists(self) -> list[list[int]]:
        adj: list[list[int]] = [[] for _ in range(self.n_nodes)]
        for u, v in self.edges:
            adj[u].append(int(v))
            adj[v].append(int(u))
        return adj


def make_dataset(
    name: str,
    *,
    scale: int = 64,
    max_features: int = 128,
    homophily: float = 0.75,
    feature_noise: float = 3.0,
    seed: int = 0,
) -> GraphData:
    """Generate a synthetic stand-in for dataset ``name`` at 1/scale size."""
    spec = DATASET_SPECS[name]
    # stable_hash, NOT hash(): str hashes are salted per-process, so hash(name)
    # regenerated a *different* dataset in every fresh interpreter — the
    # "cross-process nondeterminism" of seeded runs traced back to here.
    rng = np.random.default_rng(seed * 977 + stable_hash(name) % 10_000)

    n = max(256, spec.n_nodes // scale)
    f = min(spec.n_features, max_features)
    c = spec.n_classes
    avg_deg = min(2.0 * spec.n_edges / spec.n_nodes, 64.0)  # cap for memory

    # labels: mildly imbalanced class proportions
    class_p = rng.dirichlet(np.ones(c) * 5.0)
    labels = rng.choice(c, size=n, p=class_p).astype(np.int32)

    # features: Gaussian mixture around per-class means
    means = rng.standard_normal((c, f)).astype(np.float32) * 1.5
    features = means[labels] + rng.standard_normal((n, f)).astype(np.float32) * feature_noise

    # edges: degree-corrected SBM-ish sampling. Draw endpoints with a
    # power-lawish degree propensity; accept same-class pairs w.p. homophily.
    target_edges = int(n * avg_deg / 2)
    prop = rng.pareto(2.5, size=n) + 1.0
    prop /= prop.sum()
    src = rng.choice(n, size=target_edges * 3, p=prop)
    dst = rng.choice(n, size=target_edges * 3, p=prop)
    same = labels[src] == labels[dst]
    accept = np.where(same, homophily, 1.0 - homophily) > rng.random(len(src))
    ok = accept & (src != dst)
    edges = np.stack([src[ok], dst[ok]], axis=1)
    # dedupe (undirected)
    lo = edges.min(1)
    hi = edges.max(1)
    uniq = np.unique(lo.astype(np.int64) * n + hi)
    edges = np.stack([uniq // n, uniq % n], axis=1).astype(np.int32)
    if len(edges) > target_edges:
        edges = edges[rng.permutation(len(edges))[:target_edges]]

    # splits
    order = rng.permutation(n)
    n_train = int(spec.train_frac * n)
    n_val = int(spec.val_frac * n)
    train_mask = np.zeros(n, bool)
    val_mask = np.zeros(n, bool)
    test_mask = np.zeros(n, bool)
    train_mask[order[:n_train]] = True
    val_mask[order[n_train : n_train + n_val]] = True
    test_mask[order[n_train + n_val :]] = True

    return GraphData(
        name=name, features=features, labels=labels, edges=edges, n_classes=c,
        train_mask=train_mask, val_mask=val_mask, test_mask=test_mask, spec=spec,
    )


def downsample_edges(graph: GraphData, keep: float = 0.5, seed: int = 0) -> GraphData:
    """Paper: 'we downsample the edges in local subgraphs by 50%'."""
    rng = np.random.default_rng(seed)
    m = rng.random(len(graph.edges)) < keep
    return GraphData(
        name=graph.name, features=graph.features, labels=graph.labels,
        edges=graph.edges[m], n_classes=graph.n_classes,
        train_mask=graph.train_mask, val_mask=graph.val_mask,
        test_mask=graph.test_mask, spec=graph.spec,
    )
