"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions and compiles, and extract the roofline
terms from the compiled artifact.

Run as a script this forces 512 placeholder host devices (jax locks the
device count on first backend init, and the production meshes need 512
chips) — see ``--force-devices``. Importing the module never touches
``XLA_FLAGS``: smoke tests and benchmarks run on the single real CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun
"""
import argparse
import dataclasses
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    INPUT_SHAPES,
    get_config,
    input_specs,
    list_archs,
    long_context_variant,
    shape_applicable,
)
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_label
from repro.models import lm
from repro.models.layers import activation_sharding_ctx
from repro.optim import adamw_init
from repro.optim.schedules import constant
from repro.sharding.specs import (
    activation_rules,
    batch_spec,
    decode_state_spec,
    param_spec_tree,
)
from repro.utils.hlo import collective_stats, duplicate_fusion_ratio
from repro.utils.roofline import RooflineReport

# archs whose optimizer moments drop to bf16 to fit 16 GB/chip (DESIGN.md §6.6)
BF16_MOMENT_ARCHS = {"llama3-405b", "arctic-480b", "dbrx-132b"}


def _sharded(mesh, spec_tree, shape_tree):
    return jax.tree_util.tree_map(
        lambda spec, sds: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=NamedSharding(mesh, spec)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_case(arch: str, shape_name: str, mesh, *, attn_impl: str | None = None,
               fsdp: bool = True, extra: dict | None = None, profile: str = "tp"):
    """Returns (step_fn, example_args (ShapeDtypeStructs w/ shardings), meta)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    overrides = dict(extra or {})
    if attn_impl:
        overrides["attn_impl"] = attn_impl
    n_params = cfg.param_count()
    if shape.kind == "train":
        # production default: activation checkpointing over layer units
        overrides.setdefault("remat", True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    rules = activation_rules(mesh, train=shape.kind == "train", profile=profile)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda: lm.init_lm(key, cfg))
    pspec = param_spec_tree(params_shapes, mesh, fsdp=fsdp and shape.kind == "train",
                            profile=profile)
    params_sds = _sharded(mesh, pspec, params_shapes)

    data = input_specs(cfg, shape)
    data_sds = {
        k: jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=NamedSharding(mesh, batch_spec(mesh, shape.global_batch,
                                                    len(v.shape), profile)
                                   if v.shape else P()))
        for k, v in data.items()
    }

    meta = {
        "arch": arch, "shape": shape_name, "mesh": mesh_label(mesh),
        "kind": shape.kind, "params": n_params,
        "active_params": cfg.active_param_count(),
        "remat": cfg.remat, "attn_impl": cfg.attn_impl, "profile": profile,
    }

    if shape.kind == "train":
        moment_dtype = jnp.bfloat16 if arch in BF16_MOMENT_ARCHS else jnp.float32
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, moment_dtype), params_shapes)
        from repro.sharding.specs import param_spec as _ps

        total_mesh = 1
        for v in mesh.shape.values():
            total_mesh *= v

        def _opt_spec(path, leaf):
            if leaf.ndim == 0:
                return P()
            if profile == "dp":
                # ZeRO-1-style: weights replicate, moments shard over the
                # whole mesh on the first divisible dim
                axes = [None] * leaf.ndim
                all_axes = tuple(mesh.shape.keys())
                for i, dim in enumerate(leaf.shape):
                    if dim % total_mesh == 0:
                        axes[i] = all_axes
                        break
                    if dim % mesh.shape["model"] == 0 and dim >= mesh.shape["model"]:
                        axes[i] = "model"
                        break
                return P(*axes)
            # mu/nu mirror the param specs; drop the leading AdamState index
            return _ps(path[1:], leaf, mesh, fsdp=fsdp)

        ospec = jax.tree_util.tree_map_with_path(_opt_spec, opt_shapes)
        opt_sds = _sharded(mesh, ospec, opt_shapes)
        base_step = lm.make_train_step(cfg, constant(3e-4))

        def step(params, opt_state, batch):
            with activation_sharding_ctx(rules):
                return base_step(params, opt_state, batch)

        tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = 6.0 * cfg.active_param_count() * tokens
        return step, (params_sds, opt_sds, data_sds), meta

    if shape.kind == "prefill":
        def step(params, batch):
            with activation_sharding_ctx(rules):
                logits, aux = lm.lm_forward(
                    params, cfg, batch["tokens"],
                    image_embeds=batch.get("image_embeds"),
                    enc_frames=batch.get("enc_frames"))
                return logits[:, -1]

        tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = 2.0 * cfg.active_param_count() * tokens
        return step, (params_sds, data_sds), meta

    # decode
    B, S = shape.global_batch, shape.seq_len
    enc_out_sds = None
    if cfg.n_encoder_layers:
        enc_out_sds = jax.ShapeDtypeStruct((B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype)
    state_shapes = jax.eval_shape(
        lambda p: lm.init_decode_state(p, cfg, B, S, enc_out=enc_out_sds)
        if enc_out_sds is None else lm.init_decode_state(p, cfg, B, S, enc_out=jnp.zeros(enc_out_sds.shape, enc_out_sds.dtype)),
        params_shapes,
    )
    sspec = jax.tree_util.tree_map_with_path(
        lambda path, leaf: decode_state_spec(path, leaf, mesh, B), state_shapes)
    state_sds = _sharded(mesh, sspec, state_shapes)

    def step(params, state, batch):
        with activation_sharding_ctx(rules):
            return lm.decode_step(params, cfg, state, batch["tokens"], batch["pos"])

    meta["model_flops"] = 2.0 * cfg.active_param_count() * B  # one token per seq
    return step, (params_sds, state_sds, data_sds), meta


def _compile_once(arch, shape_name, mesh, *, attn_impl, fsdp, extra, profile="tp"):
    """Lower + compile one configuration; extract per-device cost numbers."""
    t0 = time.time()
    step, args, meta = build_case(arch, shape_name, mesh,
                                  attn_impl=attn_impl, fsdp=fsdp, extra=extra,
                                  profile=profile)
    with mesh:
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    coll = collective_stats(hlo)
    return {
        "meta": meta,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "flops_dev": float(cost.get("flops", 0.0)),
        "bytes_dev": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes_dev": float(coll.total_bytes),
        "coll_by_kind": dict(coll.bytes_by_kind),
        "coll_counts": dict(coll.count_by_kind),
        "dot_dup": duplicate_fusion_ratio(hlo),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }


def run_case(arch: str, shape_name: str, mesh_name: str, *, attn_impl=None,
             fsdp=True, extra=None, profile="tp", verbose=True) -> dict:
    """Three compiles per case:
      (1) the FULL model with scan-over-layers — proves the (arch x shape x
          mesh) combination lowers/partitions/compiles and gives the real
          per-device memory analysis;
      (2)+(3) unrolled 1-unit and 2-unit variants — XLA's cost analysis
          counts a while-loop body once, so per-layer FLOPs/bytes/collective
          traffic are measured from the unrolled variants and extrapolated:
          total = A + (U-1)(B-A) + rem_frac (B-A). Exact for the linear layer
          stack; the remainder partial unit is prorated (DESIGN.md §6.4).
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=mesh_name == "pod2")
    chips = mesh_chips(mesh)
    base_extra = dict(extra or {})
    plen = len(cfg.block_pattern)
    U = cfg.n_units
    rem_frac = len(cfg.remainder_pattern) / plen
    enc1 = 1 if cfg.n_encoder_layers else 0

    try:
        full = _compile_once(arch, shape_name, mesh, attn_impl=attn_impl,
                             fsdp=fsdp, profile=profile,
                             extra={**base_extra, "scan_layers": True})
        va = _compile_once(arch, shape_name, mesh, attn_impl=attn_impl, fsdp=fsdp,
                           profile=profile,
                           extra={**base_extra, "scan_layers": False,
                                  "n_layers": plen, "n_encoder_layers": enc1})
        vb = _compile_once(arch, shape_name, mesh, attn_impl=attn_impl, fsdp=fsdp,
                           profile=profile,
                           extra={**base_extra, "scan_layers": False,
                                  "n_layers": 2 * plen, "n_encoder_layers": 2 * enc1})
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}

    mult = (U - 1) + rem_frac

    def extrap(key):
        a, b = va[key], vb[key]
        return a + mult * (b - a)

    flops_dev = extrap("flops_dev")
    bytes_dev = extrap("bytes_dev")
    coll_bytes_dev = extrap("coll_bytes_dev")
    coll_by_kind = {
        k: va["coll_by_kind"].get(k, 0) + mult * (vb["coll_by_kind"].get(k, 0) - va["coll_by_kind"].get(k, 0))
        for k in set(va["coll_by_kind"]) | set(vb["coll_by_kind"])
    }

    meta = full["meta"]
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops_dev * chips, hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_bytes_dev * chips,
        model_flops=meta["model_flops"],
    )

    result = {
        "status": "ok",
        **meta,
        "mesh": mesh_name,
        "chips": chips,
        "lower_s": round(full["lower_s"], 2),
        "compile_s": round(full["compile_s"], 2),
        "variant_compile_s": round(va["compile_s"] + vb["compile_s"], 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "collectives": {k: float(v) for k, v in coll_by_kind.items()},
        "dot_duplication": vb["dot_dup"],
        "roofline": rep.row(),
        "memory": full["memory"],
    }
    if verbose:
        print(rep.pretty())
        print(f"    full compile={full['compile_s']:.1f}s variants={result['variant_compile_s']:.1f}s "
              f"temp/device={result['memory']['temp_bytes']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--attn-impl", default=None, choices=[None, "einsum", "chunked"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--force-devices", type=int, default=512,
                    help="force N fake XLA host devices before the backend "
                         "initializes (0 disables; the production meshes "
                         "need 512)")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()
    if args.force_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.force_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch}|{shape}|{mesh_name}"
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    path = os.path.join(args.out, f"{arch}_{shape}_{mesh_name}.json")
                    if os.path.exists(path):
                        print(f"[cached] {tag}")
                        continue
                print(f"=== {tag} ===", flush=True)
                r = run_case(arch, shape, mesh_name,
                             attn_impl=args.attn_impl, fsdp=not args.no_fsdp)
                results.append(r)
                if r["status"] == "error":
                    print(f"    ERROR: {r['error']}")
                elif r["status"] == "skipped":
                    print(f"    SKIPPED: {r['reason']}")
                if args.out:
                    with open(path, "w") as f:
                        json.dump(r, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
