from repro.checkpoint.ckpt import (
    checkpoint_steps,
    latest_step,
    load_checkpoint,
    load_latest,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "load_latest", "latest_step",
           "checkpoint_steps"]
