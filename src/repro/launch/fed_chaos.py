"""Chaos harness: seeded fault scenarios end-to-end, degradation proven.

Runs the full fault-tolerance story against one small federation:

1. a fault-free baseline per scheduler (stepwise / fused / async);
2. a seeded scenario matrix (dropout x straggler x corruption) through
   every scheduler, asserting each run completes all rounds crash-free
   with finite merged params and bounded accuracy degradation
   (``--acc-bound`` vs the scheduler's own baseline);
3. when >= 2 devices exist, a dropout scenario through the
   client-sharded executor (zero-weight dead cohort slots);
4. serve-side chaos: a torn newest checkpoint (``load_latest`` must fall
   back to the previous step), poisoned streaming features (the fresh
   path must fall back to the warm historical cache), and an
   over-capacity open loop (admission control must shed, not stall);

then writes the schema-guarded ``BENCH_faults.json`` at the repo root.

    PYTHONPATH=src python -m repro.launch.fed_chaos --quick
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.fed_chaos --quick

Exit status is non-zero on any crash, non-finite merged params, or an
accuracy delta beyond the bound — the CI ``chaos-smoke`` gate.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import tempfile

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

# BENCH_faults.json schema (see validate_bench_faults)
_TOP_KEYS = ("bench", "devices", "quick", "seed", "dataset", "scale",
             "clients", "rounds", "cohort", "method", "acc_bound",
             "max_acc_delta", "crashes", "all_finite", "rows", "serve", "ckpt")
_ROW_KEYS = ("scenario", "scheduler", "executor", "dropout", "straggler_frac",
             "corrupt", "corrupt_mode", "baseline_acc", "final_acc",
             "acc_delta", "rounds_completed", "params_finite", "crashed",
             "faults")
_SERVE_KEYS = ("n_fallbacks", "n_degraded", "n_rejected", "n_shed",
               "fresh_fell_back", "fallback_finite", "fallback_matches_warm",
               "h1_finite_frac")
_CKPT_KEYS = ("torn_step", "recovered_step", "recovered")

# (dropout, straggler_frac, corrupt) per scenario; the quick matrix is the
# CI smoke, the full matrix adds harsher rates and finite ("scale") poison
_QUICK_SCENARIOS = [(0.3, 0.0, 0.0), (0.0, 0.25, 0.0), (0.0, 0.0, 0.2),
                    (0.3, 0.25, 0.2)]
_FULL_EXTRA = [(0.5, 0.0, 0.0), (0.5, 0.5, 0.3)]


def validate_bench_faults(payload) -> list[str]:
    """Schema-check a BENCH_faults.json payload. Returns a list of problems
    (empty = valid)."""
    errs: list[str] = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected dict"]
    for k in _TOP_KEYS:
        if k not in payload:
            errs.append(f"missing key {k!r}")
    if errs:
        return errs
    if payload["bench"] != "fault_tolerance":
        errs.append(f"bench is {payload['bench']!r}, expected 'fault_tolerance'")
    if not isinstance(payload["devices"], int) or payload["devices"] < 1:
        errs.append(f"devices must be a positive int, got {payload['devices']!r}")
    if not isinstance(payload["quick"], bool):
        errs.append(f"quick must be a bool, got {payload['quick']!r}")
    for k in ("seed", "scale", "clients", "rounds", "cohort"):
        if not isinstance(payload[k], int):
            errs.append(f"{k} must be an int, got {payload[k]!r}")
    if not isinstance(payload["acc_bound"], (int, float)) \
            or not payload["acc_bound"] > 0:
        errs.append(f"acc_bound must be positive, got {payload['acc_bound']!r}")
    if not isinstance(payload["max_acc_delta"], (int, float)):
        errs.append("max_acc_delta must be a number, "
                    f"got {payload['max_acc_delta']!r}")
    if not isinstance(payload["crashes"], int) or payload["crashes"] < 0:
        errs.append(f"crashes must be a non-negative int, "
                    f"got {payload['crashes']!r}")
    if not isinstance(payload["all_finite"], bool):
        errs.append(f"all_finite must be a bool, got {payload['all_finite']!r}")
    rows = payload["rows"]
    if not isinstance(rows, list) or not rows:
        return errs + ["rows must be a non-empty list"]
    n_crashed = 0
    for i, row in enumerate(rows):
        missing = [k for k in _ROW_KEYS
                   if not isinstance(row, dict) or k not in row]
        if missing:
            errs.append(f"rows[{i}] missing keys {missing}")
            continue
        for k in ("dropout", "straggler_frac", "corrupt"):
            v = row[k]
            if not isinstance(v, (int, float)) or not 0.0 <= v <= 1.0:
                errs.append(f"rows[{i}].{k} must be in [0, 1], got {v!r}")
        for k in ("params_finite", "crashed"):
            if not isinstance(row[k], bool):
                errs.append(f"rows[{i}].{k} must be a bool, got {row[k]!r}")
        n_crashed += bool(row["crashed"])
        if not isinstance(row["rounds_completed"], int) \
                or row["rounds_completed"] < 0:
            errs.append(f"rows[{i}].rounds_completed must be a "
                        f"non-negative int, got {row['rounds_completed']!r}")
        if not isinstance(row["faults"], dict):
            errs.append(f"rows[{i}].faults must be a dict (FaultCounters "
                        f"snapshot), got {row['faults']!r}")
        for k in ("baseline_acc", "final_acc", "acc_delta"):
            if not isinstance(row[k], (int, float)):
                errs.append(f"rows[{i}].{k} must be a number, got {row[k]!r}")
    if not errs and n_crashed != payload["crashes"]:
        errs.append(f"{n_crashed} crashed rows but crashes says "
                    f"{payload['crashes']}")
    deltas = [r["acc_delta"] for r in rows
              if isinstance(r, dict) and isinstance(r.get("acc_delta"),
                                                    (int, float))
              and math.isfinite(r["acc_delta"])]
    if not errs and deltas \
            and not math.isclose(max(deltas), payload["max_acc_delta"],
                                 rel_tol=1e-9, abs_tol=1e-12):
        errs.append(f"max_acc_delta {payload['max_acc_delta']!r} != max of "
                    f"row deltas {max(deltas)!r}")
    serve = payload["serve"]
    if not isinstance(serve, dict):
        errs.append("serve must be a dict")
    else:
        for k in _SERVE_KEYS:
            if k not in serve:
                errs.append(f"serve missing key {k!r}")
        hf = serve.get("h1_finite_frac")
        if hf is not None and (not isinstance(hf, (int, float))
                               or not 0.0 <= hf <= 1.0):
            errs.append(f"serve.h1_finite_frac must be in [0, 1], got {hf!r}")
    ckpt = payload["ckpt"]
    if not isinstance(ckpt, dict):
        errs.append("ckpt must be a dict")
    else:
        for k in _CKPT_KEYS:
            if k not in ckpt:
                errs.append(f"ckpt missing key {k!r}")
        if "recovered" in ckpt and not isinstance(ckpt["recovered"], bool):
            errs.append(f"ckpt.recovered must be a bool, "
                        f"got {ckpt['recovered']!r}")
    return errs


def build_args(argv=None) -> argparse.Namespace:
    from repro.faults import CORRUPT_MODES

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny federation + the 4-scenario CI matrix")
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--scale", type=int, default=None,
                    help="synthetic dataset scale (default: 32 quick, 8 full)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None,
                    help="training rounds (default: 6 quick, 20 full)")
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--method", default="fedais")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--corrupt-mode", default="nan", choices=CORRUPT_MODES,
                    help="poison flavor for the corruption scenarios")
    ap.add_argument("--acc-bound", type=float, default=0.30,
                    help="max tolerated final-accuracy drop vs the "
                         "scheduler's own fault-free baseline")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT,
                                                  "BENCH_faults.json"))
    args = ap.parse_args(argv)
    args.scale = args.scale if args.scale is not None else (32 if args.quick else 8)
    args.rounds = args.rounds if args.rounds is not None else (6 if args.quick else 20)
    return args


def _schedulers(args) -> dict:
    """Name -> scheduler factory. Async gets the bounded-retry knobs so
    dropped uploads time out and re-dispatch instead of leaking slots."""
    from repro.api import AsyncScheduler, SyncScheduler

    return {
        "sync_stepwise": lambda: SyncScheduler(fused=False),
        "sync_fused": lambda: SyncScheduler(),
        "async": lambda: AsyncScheduler(timeout_s=5.0, max_retries=2,
                                        backoff=2.0, max_staleness=4),
    }


def run_one(g, fed, args, plan, make_sched, *, mesh=None,
            baseline_acc: float = float("nan")) -> dict:
    """One (scenario, scheduler) cell: train under the plan, report the
    degradation row. A crash is caught and reported, never propagated."""
    from repro.api import FedEngine
    from repro.faults import UpdateGuard

    # the finite guard alone catches nan/inf poison; finite "scale"
    # blow-ups need the norm ceiling
    guard = (UpdateGuard(max_norm=1e4)
             if plan is not None and plan.corrupt_mode == "scale" else True)
    row = {
        "dropout": plan.dropout if plan else 0.0,
        "straggler_frac": plan.straggler_frac if plan else 0.0,
        "corrupt": plan.corrupt if plan else 0.0,
        "corrupt_mode": plan.corrupt_mode if plan else "nan",
        "baseline_acc": baseline_acc,
        "final_acc": float("nan"), "acc_delta": float("nan"),
        "rounds_completed": 0, "params_finite": False, "crashed": False,
        "executor": "", "faults": {},
    }
    try:
        engine = FedEngine(g, fed, args.method, rounds=args.rounds,
                           clients_per_round=args.cohort, seed=args.seed,
                           eval_every=args.rounds, scheduler=make_sched(),
                           faults=plan, guard=guard, mesh=mesh)
        state = engine.init_state()
        result = engine.run(state)
        leaves = [np.asarray(x) for x in
                  __import__("jax").tree_util.tree_leaves(state.params)]
        row.update(
            executor=engine.last_executor or "",
            final_acc=float(result.final.get("acc", float("nan"))),
            rounds_completed=int(state.round) + 1,
            params_finite=all(np.isfinite(x).all() for x in leaves),
            faults=state.fault_events.snapshot(),
        )
        if math.isfinite(baseline_acc) and math.isfinite(row["final_acc"]):
            row["acc_delta"] = baseline_acc - row["final_acc"]
    except Exception as e:                                # noqa: BLE001
        row["crashed"] = True
        row["error"] = f"{type(e).__name__}: {e}"
    return row


def run_matrix(args) -> tuple[list, int]:
    """Baselines + the scenario matrix through every scheduler (plus the
    client-sharded executor when devices allow). Returns (rows, crashes)."""
    import jax

    from repro.faults import FaultPlan
    from repro.graph.data import make_dataset
    from repro.federated.partition import partition_graph

    g = make_dataset(args.dataset, scale=args.scale, seed=args.seed)
    fed = partition_graph(g, args.clients, alpha=0.5, seed=args.seed)
    scenarios = list(_QUICK_SCENARIOS)
    if not args.quick:
        scenarios += _FULL_EXTRA
    rows, crashes = [], 0
    for name, make_sched in _schedulers(args).items():
        base = run_one(g, fed, args, None, make_sched)
        base.update(scenario="baseline", scheduler=name,
                    baseline_acc=base["final_acc"], acc_delta=0.0)
        print(f"# baseline[{name}] acc={base['final_acc']:.3f} "
              f"executor={base['executor']}")
        rows.append(base)
        crashes += base["crashed"]
        for drop, strag, corrupt in scenarios:
            plan = FaultPlan(seed=args.seed + 7, dropout=drop,
                             straggler_frac=strag, corrupt=corrupt,
                             corrupt_mode=args.corrupt_mode)
            row = run_one(g, fed, args, plan, make_sched,
                          baseline_acc=base["final_acc"])
            row.update(scenario=plan.describe(), scheduler=name)
            rows.append(row)
            crashes += row["crashed"]
            print(f"# {name:13s} {plan.describe():24s} "
                  f"acc={row['final_acc']:.3f} (delta {row['acc_delta']:+.3f}) "
                  f"rounds={row['rounds_completed']} "
                  f"executor={row['executor']} faults={row['faults']}")
    if jax.device_count() >= 2:
        # sharded executors carry dropout as zero-weight dead slots (corrupt
        # needs the guard -> unsupported there, gated by the engine)
        from repro.sharding.fed import make_client_mesh

        n = max(d for d in range(1, jax.device_count() + 1)
                if args.cohort % d == 0)
        mesh = make_client_mesh(n)
        base = run_one(g, fed, args, None, _schedulers(args)["sync_fused"],
                       mesh=mesh)
        base.update(scenario="baseline", scheduler="sync_sharded",
                    baseline_acc=base["final_acc"], acc_delta=0.0)
        rows.append(base)
        crashes += base["crashed"]
        plan = FaultPlan(seed=args.seed + 7, dropout=0.3, straggler_frac=0.25)
        row = run_one(g, fed, args, plan, _schedulers(args)["sync_fused"],
                      mesh=mesh, baseline_acc=base["final_acc"])
        row.update(scenario=plan.describe(), scheduler="sync_sharded")
        rows.append(row)
        crashes += row["crashed"]
        print(f"# sync_sharded  {plan.describe():24s} "
              f"acc={row['final_acc']:.3f} executor={row['executor']} "
              f"faults={row['faults']}")
    return rows, crashes


def run_serve_chaos(args) -> tuple[dict, dict]:
    """Torn-checkpoint recovery + poisoned-feature fallback + shed load.
    Returns (serve_block, ckpt_block) for the payload."""
    import jax.numpy as jnp

    from repro.api import FedEngine
    from repro.checkpoint import latest_step
    from repro.faults import tear_file
    from repro.graph.data import make_dataset
    from repro.federated.partition import partition_graph
    from repro.serve import (LoadGenerator, QueryEngine, ServedModel,
                             save_federation)

    g = make_dataset(args.dataset, scale=args.scale, seed=args.seed)
    fed = partition_graph(g, args.clients, alpha=0.5, seed=args.seed)
    engine = FedEngine(g, fed, args.method, rounds=2, clients_per_round=args.cohort,
                       seed=args.seed, eval_every=2)
    state = engine.init_state()
    engine.run(state)
    ckpt_dir = tempfile.mkdtemp(prefix="fed_chaos_ckpt_")
    save_federation(ckpt_dir, 1, state)
    torn_path = save_federation(ckpt_dir, 2, state)
    tear_file(torn_path)                     # newest checkpoint is now torn
    torn_step = latest_step(ckpt_dir)
    model = ServedModel.restore(ckpt_dir, g, fed, seed=args.seed)
    ckpt = {"torn_step": int(torn_step), "recovered_step": model.restored_step,
            "recovered": model.restored_step == 1}
    print(f"# ckpt: step {torn_step} torn -> restored step "
          f"{model.restored_step}")

    qe = QueryEngine(model, deadline_ms=50.0, max_queue=32)
    qe.warmup()
    ids = np.arange(min(16, model.n_active))
    warm, _ = qe.serve_batch([ids], policy="historical")
    # poison the streamed features: the fresh path must degrade to the
    # warm cache, never crash or serve non-finite logits
    model.feat = model.feat.at[:].set(jnp.nan)
    fell, info = qe.serve_batch([ids], policy="fresh")
    model.feat = jnp.asarray(model.store.features)       # recover
    fresh2, info2 = qe.serve_batch([ids], policy="fresh")
    gen = LoadGenerator(qe, seed=args.seed, n_queries=80, n_updates=4,
                        mode="open", rate=5000.0,
                        policy_mix={"historical": 0.7, "fresh": 0.3})
    ledger = gen.run()
    serve = {
        **qe.degraded_snapshot(),
        "n_shed": ledger.rejects,
        "fresh_fell_back": bool(info["fell_back"]),
        "fallback_finite": bool(np.isfinite(fell[0]).all()),
        "fallback_matches_warm": bool(np.array_equal(fell[0], warm[0])),
        "recovered_fresh_ok": bool(not info2["fell_back"]
                                   and np.isfinite(fresh2[0]).all()),
        "h1_finite_frac": model.summary()["h1_finite_frac"],
    }
    print(f"# serve: fell_back={serve['fresh_fell_back']} "
          f"finite={serve['fallback_finite']} shed={serve['n_shed']} "
          f"h1_finite_frac={serve['h1_finite_frac']:.3f}")
    return serve, ckpt


def main(argv=None) -> int:
    import jax

    args = build_args(argv)
    rows, crashes = run_matrix(args)
    serve, ckpt = run_serve_chaos(args)
    deltas = [r["acc_delta"] for r in rows if math.isfinite(r["acc_delta"])]
    payload = {
        "bench": "fault_tolerance",
        "devices": jax.device_count(),
        "quick": bool(args.quick),
        "seed": args.seed,
        "dataset": args.dataset,
        "scale": args.scale,
        "clients": args.clients,
        "rounds": args.rounds,
        "cohort": args.cohort,
        "method": args.method,
        "acc_bound": args.acc_bound,
        "max_acc_delta": max(deltas) if deltas else float("nan"),
        "crashes": int(crashes),
        "all_finite": all(r["params_finite"] for r in rows if not r["crashed"]),
        "rows": rows,
        "serve": serve,
        "ckpt": ckpt,
    }
    problems = validate_bench_faults(payload)
    if problems:
        raise SystemExit("refusing to write invalid BENCH_faults.json:\n  "
                         + "\n  ".join(problems))
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out}")
    print(f"# {len(rows)} rows: crashes={crashes} "
          f"all_finite={payload['all_finite']} "
          f"max_acc_delta={payload['max_acc_delta']:.3f} "
          f"(bound {args.acc_bound})")
    failures = []
    if crashes:
        failures.append(f"{crashes} scenario runs crashed")
    if not payload["all_finite"]:
        failures.append("non-finite merged params survived a run")
    if payload["max_acc_delta"] > args.acc_bound:
        failures.append(f"accuracy degraded {payload['max_acc_delta']:.3f} "
                        f"> bound {args.acc_bound}")
    if not ckpt["recovered"]:
        failures.append("torn checkpoint was not recovered from")
    if not (serve["fresh_fell_back"] and serve["fallback_finite"]):
        failures.append("poisoned fresh path did not degrade to the warm cache")
    if failures:
        print("# FAIL: " + "; ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
