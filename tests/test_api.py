"""Tests for the repro.api surface: engine/legacy parity, the method and
aggregator registries, and each pluggable protocol."""
import jax.numpy as jnp
import numpy as np
import pytest

from _legacy_simulator import legacy_run_federated
from repro.api import (
    AdaptiveSyncController,
    BanditStrategy,
    BaseCallback,
    EarlyStopCallback,
    EvalCallback,
    FedAvg,
    FedEngine,
    FixedSyncController,
    GeneratorStrategy,
    HistoryCallback,
    LossBiasedSelector,
    MethodStrategy,
    SizeBiasedSelector,
    UniformSelector,
    WeightedFedAvg,
    available_aggregators,
    available_methods,
    build_aggregator,
    build_strategy,
    method_config,
    register_method,
    register_strategy_kind,
    strategy_kind_for,
    unregister_method,
)
from repro.core.fedais import MethodConfig
from repro.core.sync import adaptive_tau

PAPER_METHODS = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph",
                 "fedlocal", "fedais1", "fedais2", "fedais")

PARITY_KEYS = ("test_acc", "test_loss", "tau", "comm_total", "comm_embed",
               "flops", "wall_clock")


# ---------------------------------------------------------------------------
# engine vs legacy-loop parity (the refactor's correctness contract)
# ---------------------------------------------------------------------------

def _assert_parity(g, fed, mcfg, **kw):
    legacy = legacy_run_federated(g, fed, mcfg, **kw)
    new = FedEngine(g, fed, mcfg, **kw).run()
    for k in PARITY_KEYS:
        assert legacy.history[k] == new.history[k], f"history[{k!r}] diverged"
    assert legacy.final == new.final


def test_engine_matches_legacy_fedais_smoke(small_fed):
    """Fast-lane parity: FedAIS bit-for-bit vs the frozen legacy loop."""
    g, fed = small_fed
    _assert_parity(g, fed, method_config("fedais", tau0=4),
                   rounds=2, clients_per_round=3, seed=0)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["fedais", "fedsage+", "fedgraph", "fedall"])
def test_engine_matches_legacy(small_fed, method):
    """Full parity: generator- and bandit-state methods included."""
    g, fed = small_fed
    _assert_parity(g, fed, method_config(method, tau0=4 if method == "fedais" else 1),
                   rounds=4, clients_per_round=4, seed=0)


@pytest.mark.slow
def test_engine_matches_legacy_early_stop_and_eval_every(small_fed):
    g, fed = small_fed
    _assert_parity(g, fed, method_config("fedais"), rounds=5,
                   clients_per_round=3, seed=1, eval_every=2, target_acc=0.2)


# ---------------------------------------------------------------------------
# method registry
# ---------------------------------------------------------------------------

def test_registry_unknown_method_raises():
    with pytest.raises(KeyError, match="unknown method"):
        method_config("fedbogus")


def test_registry_all_paper_methods_resolve():
    assert set(PAPER_METHODS) <= set(available_methods())
    for name in PAPER_METHODS:
        mcfg = method_config(name)
        assert mcfg.name == name
        strat = build_strategy(mcfg)
        assert isinstance(strat, MethodStrategy)


def test_registry_strategy_kinds():
    assert isinstance(build_strategy(method_config("fedsage+")), GeneratorStrategy)
    assert isinstance(build_strategy(method_config("fedgraph")), BanditStrategy)
    assert type(build_strategy(method_config("fedais"))) is MethodStrategy


def test_strategy_auto_inference_from_flags():
    """Custom MethodConfigs (legacy shim path) still resolve via flags."""
    assert strategy_kind_for(MethodConfig(name="x", use_generator=True)) == "generator"
    assert strategy_kind_for(MethodConfig(name="x", bandit_fanout=True)) == "bandit"
    assert strategy_kind_for(MethodConfig(name="x")) == "plain"


def test_registry_overrides_and_custom_registration():
    mcfg = method_config("fedais", tau0=7, neighbor_fanout=3)
    assert mcfg.tau0 == 7 and mcfg.neighbor_fanout == 3

    class NullStrategy(MethodStrategy):
        pass

    register_strategy_kind("null-test", NullStrategy)
    register_method("mymethod-test", strategy="null-test",
                    importance_sampling=False, tau0=3)
    try:
        mcfg = method_config("mymethod-test")
        assert mcfg.tau0 == 3 and mcfg.strategy == "null-test"
        assert isinstance(build_strategy(mcfg), NullStrategy)
        with pytest.raises(KeyError, match="already registered"):
            register_method("mymethod-test")
    finally:
        unregister_method("mymethod-test")
        from repro.api.strategies import STRATEGY_KINDS
        STRATEGY_KINDS.pop("null-test", None)
    assert "mymethod-test" not in available_methods()


def test_baselines_method_config_delegates_to_registry():
    from repro.federated.baselines import method_config as legacy_mc

    assert legacy_mc("fedais", tau0=9) == method_config("fedais", tau0=9)
    with pytest.raises(KeyError):
        legacy_mc("nope")


# ---------------------------------------------------------------------------
# aggregators (incl. the previously dead fedavg_weighted)
# ---------------------------------------------------------------------------

def test_aggregator_registry():
    assert set(available_aggregators()) >= {"fedavg", "weighted"}
    assert isinstance(build_aggregator("fedavg"), FedAvg)
    assert isinstance(build_aggregator("weighted"), WeightedFedAvg)
    with pytest.raises(KeyError, match="unknown aggregator"):
        build_aggregator("median")


def test_fedavg_vs_weighted_aggregate():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    np.testing.assert_allclose(np.asarray(FedAvg().aggregate(stacked)["w"]), [5.0])
    out = WeightedFedAvg().aggregate(stacked, jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5])
    with pytest.raises(ValueError):
        WeightedFedAvg().aggregate(stacked, None)


def test_weighted_aggregator_via_method_config(small_fed):
    """MethodConfig.aggregator='weighted' routes through WeightedFedAvg."""
    g, fed = small_fed
    mcfg = method_config("fedais", aggregator="weighted")
    eng = FedEngine(g, fed, mcfg, rounds=2, clients_per_round=3, seed=0)
    assert isinstance(eng.aggregator, WeightedFedAvg)
    res = eng.run()
    assert np.isfinite(res.final["loss"])
    assert res.final["acc"] >= 0.0
    # a registry key passed directly to the engine resolves too (fail-fast)
    eng2 = FedEngine(g, fed, method_config("fedais"), rounds=1,
                     aggregator="weighted")
    assert isinstance(eng2.aggregator, WeightedFedAvg)
    with pytest.raises(KeyError, match="unknown aggregator"):
        FedEngine(g, fed, method_config("fedais"), rounds=1, aggregator="median")


# ---------------------------------------------------------------------------
# selectors
# ---------------------------------------------------------------------------

class _FakeEngine:
    def __init__(self, sizes, m, node_mask=None):
        class _Fed:
            pass
        self.fed = _Fed()
        self.fed.n_clients = len(sizes)
        self.fed.client_sizes = np.asarray(sizes, np.int32)
        self.fed.node_mask = node_mask
        self.clients_per_round = m


class _FakeState:
    def __init__(self, seed=0, prev_loss=None):
        self.rng = np.random.default_rng(seed)
        self.prev_loss = prev_loss


def test_uniform_selector_matches_legacy_stream():
    from repro.federated.server import select_clients

    eng = _FakeEngine([5] * 10, 4)
    got = UniformSelector().select(eng, _FakeState(seed=7))
    want = select_clients(np.random.default_rng(7), 10, 4)
    np.testing.assert_array_equal(got, want)


def test_size_biased_selector_prefers_big_clients():
    eng = _FakeEngine([1, 1, 1, 1000], 1)
    picks = [int(SizeBiasedSelector().select(eng, _FakeState(seed=s))[0])
             for s in range(20)]
    assert picks.count(3) >= 18


def test_size_biased_selector_skips_empty_clients():
    """A skewed partition can leave clients with zero nodes; the round must
    shrink instead of crashing on rng.choice with too few nonzero probs."""
    eng = _FakeEngine([0, 7, 0, 0], 3)
    sel = SizeBiasedSelector().select(eng, _FakeState(seed=0))
    assert sel.tolist() == [1]


def test_loss_biased_selector_prefers_high_loss():
    eng = _FakeEngine([5] * 4, 2, node_mask=np.ones((4, 2)))
    prev = np.asarray([[0.1, 0.1], [9.0, 9.0], [-1.0, -1.0], [0.5, 0.5]])
    sel = set(LossBiasedSelector().select(
        eng, _FakeState(seed=0, prev_loss=prev)).tolist())
    assert sel == {2, 1}   # never-seen client first, then the lossiest


def test_loss_biased_selector_ranks_empty_clients_last():
    """Zero-node clients can never produce a loss; they must not hog the
    unseen-first inf slot forever."""
    mask = np.asarray([[0.0, 0.0], [1.0, 1.0], [1.0, 1.0]])
    prev = np.asarray([[-1.0, -1.0], [2.0, 2.0], [-1.0, -1.0]])
    eng = _FakeEngine([0, 2, 2], 2, node_mask=mask)
    sel = LossBiasedSelector().select(eng, _FakeState(seed=0, prev_loss=prev))
    assert sel.tolist() == [2, 1]   # unseen non-empty first, empty client last


def test_loss_biased_selector_ignores_padding():
    """Padded slots of visited clients hold 0.0; they must not deflate small
    clients' mean loss (loss bias, not size bias)."""
    mask = np.asarray([[1.0, 1.0, 1.0, 1.0], [1.0, 0.0, 0.0, 0.0]])
    prev = np.asarray([[2.0, 2.0, 2.0, 2.0], [3.0, 0.0, 0.0, 0.0]])
    eng = _FakeEngine([4, 1], 1, node_mask=mask)
    sel = LossBiasedSelector().select(eng, _FakeState(seed=0, prev_loss=prev))
    assert sel.tolist() == [1]   # mean 3.0 beats mean 2.0 despite padding


# ---------------------------------------------------------------------------
# sync controllers
# ---------------------------------------------------------------------------

def test_adaptive_sync_controller_matches_eq11():
    mcfg = method_config("fedais", tau0=8)
    ctl = AdaptiveSyncController()
    assert ctl.initial(mcfg) == 8
    assert ctl.update(mcfg, 0.5, 1.0) == adaptive_tau(0.5, 1.0, 8)


def test_fixed_sync_controller_is_constant():
    mcfg = method_config("fedpns")   # tau0=2, adaptive off
    ctl = FixedSyncController()
    assert ctl.initial(mcfg) == 2
    assert ctl.update(mcfg, 1e-9, 1.0) == 2


def test_adaptive_sync_controller_respects_fixed_methods():
    mcfg = method_config("fedpns")
    assert AdaptiveSyncController().update(mcfg, 1e-9, 1.0) == mcfg.tau0


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------

def test_callback_hooks_and_early_stop(small_fed):
    g, fed = small_fed
    seen = {"starts": 0, "rounds": 0, "ends": 0}

    class Spy(BaseCallback):
        def on_run_start(self, engine, state):
            seen["starts"] += 1

        def on_round_end(self, ctx):
            seen["rounds"] += 1
            assert ctx.metrics is not None and "acc" in ctx.metrics

        def on_run_end(self, engine, state):
            seen["ends"] += 1

    cbs = [EvalCallback(1), HistoryCallback(), Spy(), EarlyStopCallback(0.0)]
    res = FedEngine(g, fed, method_config("fedais"), rounds=5,
                    clients_per_round=3, seed=0, callbacks=cbs).run()
    # target_acc=0.0 stops after the very first evaluated round
    assert seen == {"starts": 1, "rounds": 1, "ends": 1}
    assert len(res.history["test_acc"]) == 1
    assert res.final  # final eval still recorded after early stop


def test_explicit_callbacks_reject_default_stack_knobs(small_fed):
    """target_acc/verbose/eval_every only parameterize the default callback
    stack; silently dropping them alongside an explicit stack is an error."""
    g, fed = small_fed
    with pytest.raises(ValueError, match="default callback stack"):
        FedEngine(g, fed, method_config("fedais"), rounds=2, target_acc=0.5,
                  callbacks=[EvalCallback()])


def test_explicit_cost_model_rejects_custom_delay(small_fed):
    """Same fail-fast contract: delay only parameterizes the default
    PaperCostModel, so combining it with an explicit cost_model is an error."""
    from repro.api import PaperCostModel
    from repro.federated.costs import DelayModel

    g, fed = small_fed
    with pytest.raises(ValueError, match="default PaperCostModel"):
        FedEngine(g, fed, method_config("fedais"), rounds=2,
                  delay=DelayModel(client_flops_per_s=1e9),
                  cost_model=PaperCostModel())
    # explicit cost model with the default delay is fine
    eng = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    cost_model=PaperCostModel(DelayModel(latency_s=0.2)))
    assert eng.cost_model.delay.latency_s == 0.2


def test_register_strategy_kind_overwrite():
    from repro.api.strategies import STRATEGY_KINDS

    class A(MethodStrategy):
        pass

    class B(MethodStrategy):
        pass

    register_strategy_kind("overwrite-test", A)
    try:
        with pytest.raises(KeyError, match="already registered"):
            register_strategy_kind("overwrite-test", B)
        register_strategy_kind("overwrite-test", B, overwrite=True)
        assert STRATEGY_KINDS["overwrite-test"] is B
    finally:
        STRATEGY_KINDS.pop("overwrite-test", None)
