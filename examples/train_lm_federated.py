"""FedAIS scheduling applied to a transformer LM (the paper -> LM bridge).

Trains the bundled ~100M-class ``mini`` dense LM with federated local SGD
where (a) client batches are chosen by loss-delta importance (Eq. 7-8) and
(b) the sync interval follows the adaptive Eq. 11 rule. This is the
end-to-end training driver deliverable (a few hundred steps on CPU).

    PYTHONPATH=src python examples/train_lm_federated.py --steps 120
"""
import argparse

from repro.launch.train import train, train_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args()

    class A:  # argparse shim reused by launch.train
        arch = "mini"
        steps = args.steps
        batch = args.batch
        seq_len = args.seq_len
        lr = 3e-4
        seed = 0
        log_every = 20
        ckpt_dir = None
        ckpt_every = 10_000
        clients = args.clients
        tau0 = 4

    print("=== centralized baseline ===")
    base = train(A)
    print("\n=== FedAIS-scheduled federated ===")
    fed = train_federated(A)
    print(f"\ncentralized: {base['first_loss']:.3f} -> {base['final_loss']:.3f}")
    print(f"federated  : {fed['first_loss']:.3f} -> {fed['final_loss']:.3f} "
          f"({fed['sync_events']} model syncs)")


if __name__ == "__main__":
    main()
