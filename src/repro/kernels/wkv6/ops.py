"""Public wrapper: (B, T, H, N) layout -> per-head rows, padding, reshape.

``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.wkv6.wkv6 import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,w: (B, T, H, N); u: (H, N). Returns (y (B,T,H,N), S (B,H,N,N)).

    Pads T to a chunk multiple with w=1, k=0 (identity steps) so the final
    state matches the unpadded recurrence.
    """
    interpret = resolve_interpret(interpret)
    B, T, H, N = r.shape
    ct = min(chunk, max(8, T))
    pad = (-T) % ct

    def to_rows(x, fill=0.0):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)), constant_values=fill)
        return x

    rr, kk, vv = to_rows(r), to_rows(k), to_rows(v)
    ww = to_rows(w, fill=1.0)
    uu = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)

    y, s = wkv6_pallas(rr, kk, vv, ww, uu, chunk=ct, interpret=interpret)
    y = y[:, :T].reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return y, s.reshape(B, H, N, N)
