"""Pure-jnp oracle for the block-sparse SpMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ref(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Y = A @ X in fp32, cast back to x.dtype."""
    return (a.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)


def neighbor_mean_ref(features: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray):
    """Padded-neighbor-list mean aggregation oracle.

    features (M, D); nbr_idx (N, K) int32 into rows of features; nbr_mask
    (N, K) {0,1}. Returns (N, D) mean of valid neighbor rows (0 for isolated).
    """
    gathered = features[nbr_idx] * nbr_mask[..., None]            # (N, K, D)
    deg = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    return (gathered.sum(1) / deg).astype(features.dtype)
