"""Paper Fig. 3: test accuracy vs communication volume (comm-to-target).

The paper's claim: FedAIS needs far less communication to reach a target
accuracy than the baselines. We report, per method, the accuracy trajectory
against cumulative bytes and the bytes needed to first reach the target.
"""
from __future__ import annotations


from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup

METHODS = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph", "fedais")


def run(quick: bool = True) -> list[dict]:
    datasets = ["reddit"] if quick else ["reddit", "amazon2m"]
    scale = 96 if quick else 64
    rounds = 15 if quick else 50
    rows = []
    for ds in datasets:
        g, fed = fed_setup(ds, scale, 16, "iid")
        curves = {}
        for m in METHODS:
            mcfg = method_config(m, tau0=4 if m == "fedais" else
                                 (2 if m == "fedpns" else 1))
            res = FedEngine(g, fed, mcfg, rounds=rounds,
                            clients_per_round=5, seed=0).run()
            curves[m] = res
        # target = 95% of the best final accuracy across methods
        target = 0.95 * max(r.final["acc"] for r in curves.values())
        for m, res in curves.items():
            comm = res.comm_to_acc(target)
            rows.append({
                "dataset": ds,
                "method": m,
                "target_acc": round(target * 100, 2),
                "comm_to_target_mb": round(comm / 1e6, 2) if comm else None,
                "final_acc": round(res.final["acc"] * 100, 2),
                "total_comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
                "embed_comm_mb": round(res.final["comm_embed_bytes"] / 1e6, 2),
            })
        # derived headline: FedAIS savings vs the costliest baseline
        ais = next(r for r in rows if r["dataset"] == ds and r["method"] == "fedais")
        base = [r for r in rows if r["dataset"] == ds and r["method"] != "fedais"
                and r["comm_to_target_mb"]]
        if ais["comm_to_target_mb"] and base:
            worst = max(b["comm_to_target_mb"] for b in base)
            rows.append({
                "dataset": ds, "method": "SAVINGS",
                "fedais_vs_worst_baseline_pct":
                    round(100 * (1 - ais["comm_to_target_mb"] / worst), 1),
            })
    return rows
