"""Reproduce the paper's variance analysis (Eq. 3-5 / Theorem 1) empirically:
measure (a) the embedding-approximation error introduced by historical
embeddings at different staleness levels and (b) the minibatch-variance
reduction from importance sampling vs uniform.

    PYTHONPATH=src python examples/variance_analysis.py
"""
import jax
import jax.numpy as jnp

from repro.core.importance import importance_probs, sampling_variance, uniform_probs
from repro.core.variance import embedding_error, theorem1_bound
from repro.graph.data import make_dataset
from repro.graph.csr import build_padded_neighbors
from repro.models.gcn import gcn_batch_forward, gcn_full_forward, gcn_init, per_node_loss


def main():
    g = make_dataset("pubmed", scale=32, seed=0)
    idx, mask = build_padded_neighbors(g.adjacency_lists(), 16)
    feats = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    idx, mask = jnp.asarray(idx), jnp.asarray(mask)
    n = g.n_nodes
    params = gcn_init(jax.random.PRNGKey(0), g.n_features, g.n_classes)

    # exact layer-1 embeddings
    from repro.models.gcn import _aggregate, _sage_layer
    h1_exact = _sage_layer(params, 0, feats, _aggregate(feats, idx, mask))

    print("== (a) embedding-approximation error vs staleness (Thm. 1 regime) ==")
    key = jax.random.PRNGKey(1)
    # only HALF the nodes are in-batch: out-of-batch neighbors read the
    # (noisy = stale) historical table — exactly the Eq. (6) approximation.
    batch = jnp.arange(n // 2)
    h2_exact_logits = gcn_full_forward(params, feats, idx, mask)[: n // 2]
    for staleness in (0.0, 0.1, 0.5, 1.0):
        noise = staleness * jax.random.normal(key, h1_exact.shape) * h1_exact.std()
        hist1 = jnp.concatenate([h1_exact + noise, jnp.zeros((1, 256))])
        logits, _, _ = gcn_batch_forward(params, feats, jnp.zeros((1, g.n_features)),
                                         hist1, idx, mask, batch)
        err = embedding_error(logits, h2_exact_logits, jnp.ones(n // 2))
        bound = theorem1_bound(1.0, float(jnp.abs(noise).max() + 1e-9),
                               float(mask.sum(1).mean()), 2)
        print(f"  staleness={staleness:.1f}: output L2 err={float(err):.4f} "
              f"(Thm.1-style bound scale={bound:.2f})")

    print("\n== (b) minibatch variance: importance vs uniform (Eq. 7) ==")
    logits = gcn_full_forward(params, feats, idx, mask)
    losses = per_node_loss(logits, labels)
    ones = jnp.ones(n)
    p_imp = importance_probs(losses, ones)
    p_uni = uniform_probs(ones)
    v_imp = float(sampling_variance(p_imp, losses, ones))
    v_uni = float(sampling_variance(p_uni, losses, ones))
    print(f"  Eq.7 objective: importance={v_imp:.1f}  uniform={v_uni:.1f}  "
          f"reduction={100*(1-v_imp/v_uni):.1f}%")


if __name__ == "__main__":
    main()
