"""Fig. 8 (extension): synchronous vs asynchronous round scheduling under
heterogeneous client delays.

Per-client compute speeds are drawn from a seeded lognormal (a ~2.2x spread,
the straggler regime async scheduling targets). Three schedulers run the
same method (FedAIS) at an equal total communication budget (merged-update
count is held constant, so model up/down-link traffic matches):

    sync_uniform    the lockstep SyncScheduler with uniform delay pricing
                    (the engine default — optimistic, no stragglers)
    sync_lockstep   full-quorum AsyncScheduler with the heterogeneous speed
                    factors: identical trajectory to lockstep rounds, but the
                    virtual clock waits for the slowest cohort member — the
                    fair synchronous baseline under heterogeneity
    async_qN        buffered AsyncScheduler (quorum N < cohort): merges a
                    quorum early, stragglers land late with staleness-
                    discounted weights; runs proportionally more merges so
                    the comm budget matches

The figure of merit is wall-clock to a fixed accuracy target.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fed_setup
from repro.api import AsyncScheduler, FedEngine, method_config

HET_SIGMA = 0.8   # lognormal sigma of per-client compute-speed factors


def _wall_and_comm_to(res, target):
    idx = next((i for i, a in enumerate(res.history["test_acc"]) if a >= target),
               None)
    if idx is None:
        return None, None
    return res.history["wall_clock"][idx], res.history["comm_total"][idx]


def run(quick: bool = True) -> list[dict]:
    ds = "pubmed"
    g, fed = fed_setup(ds, 32 if quick else 64, 12, "0.5")
    rounds = 12 if quick else 30
    m = 6
    q = m // 2
    rng = np.random.default_rng(0)
    factors = np.exp(rng.normal(0.0, HET_SIGMA, fed.n_clients))

    mcfg = method_config("fedais", tau0=4)
    # (name, scheduler, merges): merges * merged-per-round is constant, so
    # every variant spends the same model-traffic budget
    variants = [
        ("sync_uniform", None, rounds),
        ("sync_lockstep", AsyncScheduler(speed_factors=factors), rounds),
        (f"async_q{q}", AsyncScheduler(quorum=q, speed_factors=factors),
         rounds * m // q),
    ]

    results = {}
    for name, sched, merges in variants:
        kw = dict(rounds=merges, clients_per_round=m, seed=0)
        eng = (FedEngine(g, fed, mcfg, **kw) if sched is None
               else FedEngine(g, fed, mcfg, scheduler=sched, **kw))
        results[name] = eng.run()

    target = 0.95 * min(r.history["test_acc"][-1] for r in results.values())
    rows = []
    for name, res in results.items():
        wall, comm = _wall_and_comm_to(res, target)
        rows.append({
            "scheduler": name,
            "dataset": ds,
            "merges": len(res.history["test_acc"]),
            "target_acc": round(target, 4),
            "reached_target": wall is not None,
            "wall_to_target_s": round(wall, 4) if wall is not None else None,
            "comm_to_target_mb": round(comm / 1e6, 2) if comm is not None else None,
            "final_acc": round(res.history["test_acc"][-1], 4),
            "total_wall_s": round(res.history["wall_clock"][-1], 4),
            # final, not history[-1]: includes dispatched-but-unmerged
            # in-flight updates the async scheduler bills at run end
            "total_comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
            "staleness_max": max(res.history.get("staleness_max", [0])),
        })
    base = next(r for r in rows if r["scheduler"] == "sync_lockstep")
    base_wall = base["wall_to_target_s"] or base["total_wall_s"]
    for r in rows:
        w = r["wall_to_target_s"] or r["total_wall_s"]
        r["speedup_vs_lockstep"] = round(base_wall / w, 2) if w else None
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv

    emit_csv("fig8_async", run(quick=True))
