"""Multi-pod dry-run of the PAPER'S OWN workload: one FedAIS round chunk
(Algorithm 1) with the client cohort sharded across the production mesh.

This is now a thin caller of the engine's own sharded executor: it lowers
``repro.sharding.fed.build_sharded_chunk`` — the exact scanned
``round_step`` ``FedEngine`` runs when given a mesh — over abstract
client-sharded arguments, so the dry-run and real training share one
code path. The vmapped client axis shard_maps over a ``("clients",)``
mesh axis: the cross-client ghost pull reads the replicated historical
tables, FedAvg lowers to a weighted all-reduce (psum), and the
historical/ghost write-back all-gathers the cohort's fresh embeddings —
exactly the embedding-synchronization network phase of the real
deployment. This is the FedGCN-scale companion to launch/dryrun.py's LM
cases.

    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh pod1
    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh pod1 --pods 16

``--pods P`` lowers the pod-table mode instead (repro.sharding.tables): a
``("pods", "clients")`` 2-D mesh whose table shards stay resident per pod,
with the ghost exchange as a bucketed all-to-all — the report then carries
a ``pods`` ledger (ghost-cut entries, all-to-all vs all-gather bytes, and
the replicated-table byte count the sharding avoids). Sweep ``--clients``
at a fixed ``--cohort`` to verify the write-back scales with the ghost
cut, not with K.

Run as a script this forces fake XLA host devices (512 by default, so
both pod chip counts fit on CPU); importing the module never touches
``XLA_FLAGS`` — pass ``--force-devices N`` (0 disables) or use
``--mesh host`` to run on whatever devices already exist.
"""
import argparse
import json
import os
import time

import jax
import numpy as np

from repro.api.engine import _LIGHT_STATS
from repro.api.registry import method_config
from repro.core.fedais import make_vmapped_update
from repro.federated.partition import ghost_exchange_buckets
from repro.launch.mesh import production_chip_count
from repro.models.gcn import HIDDEN, gcn_flops_per_node, gcn_param_count
from repro.sharding.fed import (
    abstract_chunk_args,
    build_sharded_chunk,
    client_axis_of,
    cohort_padding,
    make_client_mesh,
)
from repro.sharding.tables import (
    abstract_pod_chunk_args,
    build_pod_sharded_chunk,
    make_pod_mesh,
)
from repro.utils.hlo import collective_stats
from repro.utils.roofline import RooflineReport

# chip counts come from the production mesh definition (launch/mesh.py)
MESH_CHIPS = {
    "pod1": production_chip_count(multi_pod=False),
    "pod2": production_chip_count(multi_pod=True),
}


def _force_host_devices(n: int) -> None:
    """Fake XLA host devices; only effective before the backend initializes
    (caller flags win for duplicates, preserving any prior forced count)."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", ""))


def synthetic_ghost_buckets(n_clients: int, n_max: int, g_max: int,
                            n_pods: int, *, fill: float = 1.0, seed: int = 0):
    """A partition-shaped ghost topology for lowering the pod chunk without
    real data: each client's ghost slots point at uniform random (owner,
    row) pairs, ``fill`` controlling the occupied fraction (the ghost-cut
    knob the write-back bytes should track)."""
    rng = np.random.default_rng(seed)
    mask = (rng.random((n_clients, g_max)) < fill).astype(np.float32)
    owner = rng.integers(0, n_clients, size=(n_clients, g_max)).astype(np.int32)
    owner = np.where(mask > 0, owner, -1)
    row = rng.integers(0, n_max, size=(n_clients, g_max)).astype(np.int32)
    return ghost_exchange_buckets(owner, row, mask, n_pods)


def dryrun_mesh(mesh_name: str, args) -> dict:
    """Lower one sharded round chunk on ``mesh_name``'s chip count and
    report collectives + roofline. With ``--pods P`` the mesh is the 2-D
    ``("pods", "clients")`` grid and the historical tables shard over the
    pod axis (repro.sharding.tables) — the collectives then include the
    ghost-bucket all-to-all and a cohort-sized (K-independent) write-back
    all-gather instead of replicated-table traffic. Returns the result row
    (status key "ok"/"error")."""
    chips = MESH_CHIPS.get(mesh_name, len(jax.devices()))
    K = args.clients or chips
    m = args.cohort or K
    pods = args.pods
    mcfg = method_config("fedais", local_epochs=4, batch_cap=args.n_max)
    buckets = None
    pad = cohort_padding(m, chips)
    if pods:
        if chips % pods:
            raise ValueError(f"{chips} chips do not split into {pods} pods")
        mesh = make_pod_mesh(pods, chips // pods)
        buckets = synthetic_ghost_buckets(K, args.n_max, args.g_max, pods,
                                          fill=args.ghost_fill)
        vm = make_vmapped_update(mcfg, args.n_max, args.g_max, HIDDEN[0],
                                 ghost_source="prefetched")
        chunk = build_pod_sharded_chunk(vm, mesh, m, buckets, _LIGHT_STATS)
        sargs = abstract_pod_chunk_args(
            mesh, buckets, n_clients=K, cohort=m + pad, n_max=args.n_max,
            g_max=args.g_max, n_feat=args.features, n_classes=args.classes)
    else:
        mesh = make_client_mesh(chips)
        axis = client_axis_of(mesh)
        vm = make_vmapped_update(mcfg, args.n_max, args.g_max, HIDDEN[0])
        chunk = build_sharded_chunk(vm, mesh, axis, m_real=m,
                                    light_stats=_LIGHT_STATS)
        sargs = abstract_chunk_args(
            mesh, n_clients=K, cohort=m + pad, n_max=args.n_max,
            g_max=args.g_max, n_feat=args.features, n_classes=args.classes)

    t0 = time.time()
    compiled = chunk.lower(*sargs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = collective_stats(compiled.as_text())

    n_params = gcn_param_count(args.features, args.classes)
    # per-round model flops: J epochs x batch fwd+bwd over the m-cohort
    flops_model = 3.0 * gcn_flops_per_node(args.features, args.classes, 8.0) \
        * args.n_max * mcfg.local_epochs * m
    rep = RooflineReport(
        arch="fedgcn-graphsage", shape=f"K{K}", mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)) * chips,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
        collective_bytes=float(coll.total_bytes) * chips,
        model_flops=flops_model,
    )
    result = {
        "status": "ok", "arch": "fedgcn-graphsage", "shape": f"K{K}",
        "mesh": mesh_name, "chips": chips, "clients": K, "cohort": m,
        "cohort_pad": pad,
        "gcn_params": n_params,
        "compile_s": round(time.time() - t0, 1),
        "collectives": {k: int(v) for k, v in coll.bytes_by_kind.items()},
        "roofline": rep.row(),
        "memory": {"temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
    }
    if pods:
        # the table-placement ledger the pod mode exists for: per-device
        # table memory is K/P rows, the ghost exchange is bucket-sized
        # (scales with the ghost-edge cut), and the write-back moves cohort
        # rows — compare against what replicating the (K, n_tot, H1) table
        # per chunk costs the client-sharded executor
        n_tot = args.n_max + args.g_max
        table_bytes = K * n_tot * HIDDEN[0] * 4
        result["pods"] = {
            "n_pods": pods,
            "ghost_cut_entries": buckets.n_entries,
            "bucket_size": buckets.bucket_size,
            "all_to_all_bytes": int(coll.bytes_by_kind.get("all-to-all", 0)),
            "all_gather_bytes": int(coll.bytes_by_kind.get("all-gather", 0)),
            "replicated_hist1_bytes": table_bytes,
            "table_shard_rows_per_pod": buckets.rows_per_pod,
        }
    print(rep.pretty())
    print(f"    [{mesh_name}] K={K}" + (f" pods={pods}" if pods else "")
          + f" compile={result['compile_s']}s collectives: {coll.summary()}")
    if pods:
        p = result["pods"]
        print(f"    [{mesh_name}] ghost-cut={p['ghost_cut_entries']} entries; "
              f"write-back a2a={p['all_to_all_bytes']:,}B + "
              f"ag={p['all_gather_bytes']:,}B vs replicated hist1 "
              f"{p['replicated_hist1_bytes']:,}B")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "both", "host"],
                    help="pod chip counts, or 'host' = all existing devices")
    ap.add_argument("--clients", type=int, default=0, help="default: one per chip")
    ap.add_argument("--cohort", type=int, default=0,
                    help="clients selected per round (default: all K) — fix "
                         "it while sweeping --clients to see which "
                         "collectives scale with the total client count")
    ap.add_argument("--pods", type=int, default=0,
                    help="shard the historical tables over this many pods "
                         "(a ('pods','clients') 2-D mesh; 0 = replicated "
                         "tables, cohort-only sharding)")
    ap.add_argument("--ghost-fill", type=float, default=0.5,
                    help="occupied fraction of ghost slots in the synthetic "
                         "pod topology — the ghost-cut knob the --pods "
                         "write-back bytes should track")
    ap.add_argument("--n-max", type=int, default=512)
    ap.add_argument("--g-max", type=int, default=256)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--classes", type=int, default=41)   # reddit-like
    ap.add_argument("--force-devices", type=int, default=None,
                    help="force N fake XLA host devices before the backend "
                         "initializes (default: 512 for pod meshes, off for "
                         "--mesh host; 0 disables)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.force_devices is None and args.mesh != "host":
        args.force_devices = max(MESH_CHIPS.values())
    if args.force_devices:
        _force_host_devices(args.force_devices)

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    rc = 0
    for mesh_name in meshes:
        try:
            result = dryrun_mesh(mesh_name, args)
        except Exception as e:
            print(f"[{mesh_name}] ERROR: {type(e).__name__}: {e}")
            rc = 1
            continue
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"_pods{args.pods}" if args.pods else ""
            with open(os.path.join(args.out, f"fedgcn_{mesh_name}{tag}.json"),
                      "w") as f:
                json.dump(result, f, indent=1)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
