"""Per-architecture PartitionSpec rules for the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    - batch dims shard over ("pod", "data")
    - weight feature dims shard over "model" (tensor parallel): column for
      in-projections, row for out-projections; MoE expert axis over "model"
    - FSDP (train mode): the non-"model" weight dim additionally shards over
      "data" (ZeRO-style); "pod" replicates weights (pure DP across pods)
    - long_500k (batch=1): the KV-cache/sequence dim shards over "data"

Rules are name-based on the trailing dims of each leaf; leading stacked-unit
axes (scan-over-layers) and the MoE expert axis are padded with the right
prefix. Non-divisible cases fall back to replication (checked against the
actual mesh axis sizes) — e.g. arctic's 56 heads never constrain us because
we shard feature dims, not head counts (DESIGN.md §6.5).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# trailing-dims rule per leaf name. "F" = fsdp axis ("data" in train mode,
# else None); "M" = "model".
_RULES_2D = {
    # embeddings / heads
    "embed": ("M", "F"),
    "lm_head": ("F", "M"),
    "pos_emb": (None, "M"),
    "enc_pos": (None, "M"),
    # attention
    "wq": ("F", "M"), "wk": ("F", "M"), "wv": ("F", "M"), "wo": ("M", "F"),
    # dense mlp
    "w_in": ("F", "M"), "w_gate": ("F", "M"), "w_out": ("M", "F"),
    # rwkv time-mix / channel-mix
    "wr": ("F", "M"), "wg": ("F", "M"),
    "wck": ("F", "M"), "wcv": ("M", "F"), "wcr": ("F", "M"),
    "mix_w1": (None, None), "decay_w1": (None, None), "decay_w2": (None, None),
    # griffin
    "w_rec_in": ("F", "M"), "w_gate_in": ("F", "M"),
    "w_a": (None, "M"), "w_i": (None, "M"), "conv_w": (None, "M"),
    # gcn (federated sharded simulator)
    "w_self0": ("F", "M"), "w_nbr0": ("F", "M"),
    "w_self1": ("F", "M"), "w_nbr1": ("F", "M"), "w_cls": (None, None),
}

# MoE expert stacks: (E, d, ff)-shaped, expert axis -> "model"
_RULES_MOE_3D = {
    "w_in": ("M", "F", None),
    "w_gate": ("M", "F", None),
    "w_out": ("M", None, "F"),
}


def _axis(sym, *, fsdp: bool):
    if sym == "M":
        return "model"
    if sym == "F":
        return "data" if fsdp else None
    return sym


def _leaf_name(path) -> tuple[str, bool]:
    keys = [str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path]
    name = keys[-1] if keys else ""
    in_moe = "moe" in keys
    return name, in_moe


def _divisible(dim: int | None, axis, mesh: Mesh) -> bool:
    if axis is None or dim is None:
        return True
    sizes = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        sizes *= mesh.shape[a]
    return dim % sizes == 0


def param_spec(path, leaf, mesh: Mesh, *, fsdp: bool) -> P:
    name, in_moe = _leaf_name(path)
    shape = leaf.shape
    if in_moe and name in _RULES_MOE_3D and len(shape) >= 3:
        rule = _RULES_MOE_3D[name]
    elif name in _RULES_2D:
        rule = _RULES_2D[name]
    else:
        rule = ()
    # align rule to trailing dims, pad leading (stacked-unit) dims with None
    axes = [None] * len(shape)
    for i, sym in enumerate(rule):
        pos = len(shape) - len(rule) + i
        if pos < 0:
            continue
        ax = _axis(sym, fsdp=fsdp)
        if _divisible(shape[pos], ax, mesh):
            axes[pos] = ax
    return P(*axes)


def param_spec_tree(params_shapes, mesh: Mesh, *, fsdp: bool = False,
                    profile: str = "tp"):
    """profile "tp": tensor-parallel rules above (+FSDP for train).
    profile "dp": replicate all weights; batch shards over every mesh axis —
    the §Perf H2 fix for small models where TP wastes ICI on weight
    all-gathers (rwkv6-1.6b: collective term 7.1s -> see EXPERIMENTS.md)."""
    if profile == "dp":
        return jax.tree_util.tree_map(lambda leaf: P(), params_shapes)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh, fsdp=fsdp), params_shapes
    )


def param_sharding_tree(params_shapes, mesh: Mesh, *, fsdp: bool = False):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_spec_tree(params_shapes, mesh, fsdp=fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )


def dp_axes(mesh: Mesh, profile: str = "tp"):
    """Batch-parallel axes: ("pod","data") when a pod axis exists; the "dp"
    profile additionally folds the model axis into the batch axes."""
    axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if profile == "dp":
        axes = axes + ("model",)
    return axes


def batch_spec(mesh: Mesh, batch_size: int, ndim: int, profile: str = "tp") -> P:
    """Shard the leading batch dim over dp axes (when divisible)."""
    axes = dp_axes(mesh, profile)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if batch_size % total == 0:
        lead = axes if len(axes) > 1 else axes[0]
    elif batch_size % mesh.shape[axes[-1]] == 0:
        lead = axes[-1]
    else:
        lead = None
    return P(lead, *([None] * (ndim - 1)))


def decode_state_spec(path, leaf, mesh: Mesh, batch: int) -> P:
    """KV caches (U, B, S, Hkv, hd) / recurrent states: shard B over dp axes;
    batch=1 long-context: shard the cache sequence dim over "data"."""
    name, _ = _leaf_name(path)
    shape = leaf.shape
    axes: list = [None] * len(shape)
    dp = dp_axes(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    if name in ("k", "v", "xk", "xv") and len(shape) >= 4:
        # (..., B, S, Hkv, hd)
        b_pos = len(shape) - 4
        s_pos = len(shape) - 3
        if shape[b_pos] % total == 0:
            axes[b_pos] = dp if len(dp) > 1 else dp[0]
        elif shape[b_pos] % mesh.shape[dp[-1]] == 0:
            axes[b_pos] = dp[-1]
        elif shape[s_pos] % mesh.shape["data"] == 0:
            axes[s_pos] = "data"   # long-context: sequence-shard the cache
        if shape[-2] % mesh.shape["model"] == 0 and shape[-2] >= mesh.shape["model"]:
            axes[-2] = "model"     # kv heads over model axis when they fit
        return P(*axes)
    # recurrent states: (..., B, ...) — find a batch-sized dim to shard
    for pos in range(len(shape)):
        if shape[pos] == batch and batch % mesh.shape[dp[-1]] == 0:
            axes[pos] = dp[-1]
            break
    return P(*axes)


def activation_rules(mesh: Mesh, *, train: bool, profile: str = "tp") -> dict:
    """Logical-axis -> mesh-axis map consumed by shard_activation()."""
    dp = dp_axes(mesh, profile)
    batch_ax = dp if len(dp) > 1 else dp[0]
    if profile == "dp":
        return {"batch": batch_ax, "seq": None, "heads": None, "kv_heads": None,
                "ff": None, "embed": None, "vocab": None, "experts": None,
                "boundary_seq": None}
    return {
        "batch": batch_ax,
        "seq": None,
        "heads": "model",
        "kv_heads": None,
        "ff": "model",
        "embed": None,
        "vocab": "model",
        "experts": "model",
        # layer-boundary activations: sequence-parallel over the model axis
        # during training (remat residuals shrink x model-axis; §Perf H3.3)
        "boundary_seq": "model" if train else None,
    }
