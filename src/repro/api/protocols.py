"""Pluggable component protocols for the FedEngine, plus default impls.

Each protocol isolates one axis of the method-space that the paper's
Algorithm 1 fixes to a single choice:

    ClientSelector  which clients participate in a round
    Aggregator      how client models merge on the server
    SyncController  how the embedding-sync interval tau evolves (Eq. 11)
    CostModel       what a round costs (bytes / FLOPs / wall-clock)
    RoundScheduler  when client updates merge (lockstep vs buffered-async)
    RoundCallback   side effects at round boundaries (eval, logging, ...)

Default implementations reproduce the legacy ``run_federated`` loop
bit-for-bit (see tests/test_api.py parity tests). Custom components are
plain objects satisfying the protocol — no registration required, pass
them to ``FedEngine(..., selector=..., aggregator=...)``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Protocol, Sequence, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.plan import corrupt_params_stack
from repro.federated.costs import (
    BYTES_F32,
    CostMeter,
    DelayModel,
    VirtualClock,
    model_bytes,
    seq_sum,
)
from repro.federated.server import fedavg, fedavg_weighted, select_clients, update_tau

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.engine import EngineState, FedEngine
    from repro.core.fedais import MethodConfig


# ---------------------------------------------------------------------------
# client selection
# ---------------------------------------------------------------------------

@runtime_checkable
class ClientSelector(Protocol):
    def select(self, engine: "FedEngine", state: "EngineState") -> np.ndarray:
        """Return the ids of the clients participating this round.

        Contract: ids must be sampled WITHOUT replacement — the synchronous
        merge write-back scatters by client id and skips duplicate handling
        (only async buffers, which can legitimately hold two updates from
        one client, pay for the dedup). Selectors whose draws depend only
        on the host RNG + static data may set ``precomputable = True`` to
        unlock the fused executor (whole-chunk cohorts drawn up front).
        """
        ...


class UniformSelector:
    """Uniform without replacement — the paper's (and legacy loop's) choice."""

    # depends only on the host RNG stream + static geometry, so a whole
    # chunk of cohorts can be drawn up front by the fused executor
    precomputable = True

    def select(self, engine, state):
        return select_clients(state.rng, engine.fed.n_clients,
                              engine.clients_per_round)


class SizeBiasedSelector:
    """Sample clients with probability proportional to local dataset size.
    Empty clients (a skewed Dirichlet partition can produce them) are never
    selected; the round shrinks if fewer non-empty clients exist than m."""

    precomputable = True    # client sizes are static; only the RNG advances

    def select(self, engine, state):
        sizes = engine.fed.client_sizes.astype(np.float64)
        p = sizes / max(sizes.sum(), 1.0)
        m = min(engine.clients_per_round, engine.fed.n_clients,
                int(np.count_nonzero(p)))
        return state.rng.choice(engine.fed.n_clients, size=m, replace=False, p=p)


class LossBiasedSelector:
    """Prefer clients whose last-seen mean local loss is highest (never-seen
    clients rank first) — the round-level analogue of Eq. 7's node scores."""

    precomputable = False   # reads state.prev_loss, which changes every round

    def select(self, engine, state):
        pl = np.asarray(state.prev_loss)
        # padded slots of a visited client hold 0.0 (loss_all is node-masked),
        # so average only over real nodes with an observed loss
        node_mask = np.asarray(engine.fed.node_mask) > 0
        real = (pl >= 0) & node_mask
        mean_loss = (pl * real).sum(axis=1) / np.maximum(real.sum(axis=1), 1)
        # unseen (but non-empty) clients rank first; clients with no nodes at
        # all can never produce a loss and must rank last, not first forever
        scores = np.where(real.any(axis=1), mean_loss, np.inf)
        scores = np.where(node_mask.any(axis=1), scores, -np.inf)
        # random tie-break keeps unseen clients in shuffled order
        tie = state.rng.random(engine.fed.n_clients)
        order = np.lexsort((tie, -scores))
        m = min(engine.clients_per_round, engine.fed.n_clients)
        return order[:m]


# ---------------------------------------------------------------------------
# server-side aggregation
# ---------------------------------------------------------------------------

@runtime_checkable
class Aggregator(Protocol):
    def aggregate(self, stacked_params, weights=None):
        """Merge a (m, ...) stacked client pytree into one global pytree."""
        ...


class FedAvg:
    """Unweighted mean over the selected clients — Algorithm 1 line 7."""

    uses_weights = False
    jit_safe = True     # pure jnp: traceable inside the fused round_step
    # weighted-mean family: sum(w*x)/sum(w) with uniform w, so the sharded
    # executor may lower this merge to a psum all-reduce across devices
    # (allclose, not bit-identical — reassociated summation order)
    allreduce_safe = True

    def aggregate(self, stacked_params, weights=None):
        return fedavg(stacked_params)


class WeightedFedAvg:
    """Dataset-size-weighted FedAvg (McMahan et al.); the engine passes
    ``fed.client_sizes[sel]`` as the weights."""

    uses_weights = True
    jit_safe = True
    allreduce_safe = True   # sum(w*x)/sum(w): exactly a weighted all-reduce

    def aggregate(self, stacked_params, weights=None):
        if weights is None:
            raise ValueError("WeightedFedAvg needs per-client weights")
        return fedavg_weighted(stacked_params, jnp.asarray(weights, jnp.float32))


def staleness_discount(staleness, *, mode: str = "poly", a: float = 0.5) -> np.ndarray:
    """FedAsync-style staleness discount s(τ) for late-merging updates.

    ``poly``  s(τ) = (1 + τ)^-a      (FedAsync's polynomial family)
    ``exp``   s(τ) = exp(-a τ)
    ``const`` s(τ) = 1               (FedBuff: uniform buffer average)
    """
    s = np.asarray(staleness, np.float64)
    if mode == "poly":
        return (1.0 + s) ** -a
    if mode == "exp":
        return np.exp(-a * s)
    if mode == "const":
        return np.ones_like(s)
    raise ValueError(f"unknown staleness mode {mode!r}; known: poly|exp|const")


@dataclass
class StalenessWeightedAggregator:
    """Wraps a base Aggregator with multiplicative staleness discounts.

    An update dispatched at server version v and merged at version V has
    staleness τ = V - v; its aggregation weight is scaled by s(τ) (see
    ``staleness_discount``), composed with the base aggregator's own weights
    when it uses them (e.g. client sizes for WeightedFedAvg). When every
    update is fresh (all τ = 0, so every s(τ) = 1) the merge delegates to the
    base aggregator unchanged — this is what makes a full-quorum
    AsyncScheduler bit-identical to the synchronous engine.
    """

    base: "Aggregator" = field(default_factory=FedAvg)
    mode: str = "poly"
    a: float = 0.5

    uses_weights = True
    jit_safe = False    # host numpy discounts; async merges are eager anyway

    def aggregate(self, stacked_params, weights=None, staleness=None):
        if staleness is None:
            return self.base.aggregate(stacked_params, weights)
        d = staleness_discount(staleness, mode=self.mode, a=self.a)
        if d.size and float(d.min()) == 1.0:   # all fresh: exactly the base merge
            return self.base.aggregate(stacked_params, weights)
        # a stale merge becomes a discounted weighted mean — only valid for
        # mean-family bases; a custom rule (median, trimmed mean, ...) must
        # declare how it composes rather than being silently replaced
        uses_weights = getattr(self.base, "uses_weights", None)
        if uses_weights is None:
            raise TypeError(
                f"{type(self.base).__name__} does not declare `uses_weights`; "
                "StalenessWeightedAggregator can only fold discounts into "
                "mean-family aggregators — set `uses_weights` on the base "
                "(True to compose with its weights, False for a plain "
                "discounted mean) or implement staleness in the base itself")
        if uses_weights and weights is not None:
            d = d * np.asarray(weights, np.float64)
        return fedavg_weighted(stacked_params, jnp.asarray(d, jnp.float32))


# ---------------------------------------------------------------------------
# sync-interval control
# ---------------------------------------------------------------------------

@runtime_checkable
class SyncController(Protocol):
    def initial(self, mcfg: "MethodConfig") -> int:
        ...

    def update(self, mcfg: "MethodConfig", test_loss: float,
               initial_loss: float) -> int:
        ...


class AdaptiveSyncController:
    """Wraps server.update_tau: Eq. 11 when ``mcfg.adaptive_sync``, else the
    fixed interval tau0 (FedPNS-style)."""

    def initial(self, mcfg):
        return mcfg.tau0

    def update(self, mcfg, test_loss, initial_loss):
        return update_tau(mcfg, test_loss, initial_loss, mcfg.tau0)


class FixedSyncController:
    """Always tau0, regardless of the loss trajectory."""

    def initial(self, mcfg):
        return mcfg.tau0

    def update(self, mcfg, test_loss, initial_loss):
        return mcfg.tau0


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------

@runtime_checkable
class CostModel(Protocol):
    def round_cost(self, engine: "FedEngine", state: "EngineState",
                   sel: np.ndarray, stats: dict) -> CostMeter:
        ...

    # Required by AsyncScheduler (which prices per-client finish times and
    # bills merges against a virtual clock instead of max(compute) + sync):

    def client_compute_times(self, engine: "FedEngine", state: "EngineState",
                             sel: np.ndarray, stats: dict) -> np.ndarray:
        ...

    def client_comm_times(self, engine: "FedEngine", state: "EngineState",
                          sel: np.ndarray, stats: dict) -> np.ndarray:
        ...

    def sync_overhead(self, engine: "FedEngine", sel: np.ndarray,
                      stats: dict) -> float:
        ...


@dataclass
class PaperCostModel:
    """The paper's analytic byte/FLOP/delay accounting (Fig. 3/4 axes).
    Method-specific extras (FedSage+ generator traffic/compute) come from the
    strategy's cost hooks, keeping this model branch-free.

    Per-client quantities are numpy-vectorized over the selected clients (the
    legacy O(m) Python loop capped scaling at hundreds of clients); meters
    accumulate with ``seq_sum`` so totals stay bit-identical to the loop
    (tests/test_async.py pins this).
    """

    delay: DelayModel = field(default_factory=DelayModel)

    # prices a round purely from the streamed stats + state.tau, so the
    # fused executor can replay cost accounting at the chunk boundary
    fused_safe = True

    # ---- vectorized per-client pieces (shared by the synchronous meter and
    # the async virtual clock) ----

    def client_flops(self, engine, sel, stats) -> np.ndarray:
        sizes = np.asarray(engine.fed.client_sizes[sel], np.int64)
        nodes = sizes + engine.mcfg.local_epochs * np.minimum(
            engine.bsz, np.maximum(sizes, 1))
        return 3.0 * engine.fwd_flops_node * nodes \
            + engine.strategy.extra_flops(engine, sizes)

    def client_embed_bytes(self, engine, stats) -> np.ndarray:
        # vector form of embed_sync_bytes(n_pulled[i], (F, H1)), same
        # left-to-right operand order so each element rounds identically
        n_pulled = np.asarray(stats["n_ghost_pulled"], np.float64)
        return n_pulled * sum((engine.F, engine.H1)) * BYTES_F32

    def client_compute_times(self, engine, state, sel, stats) -> np.ndarray:
        """Per-client local compute time this round (seconds, float64)."""
        return np.asarray(
            self.delay.compute_time(self.client_flops(engine, sel, stats)),
            np.float64)

    def client_comm_times(self, engine, state, sel, stats) -> np.ndarray:
        """Per-client network time this round (seconds, float64): the model
        down/up-link plus the client's own embedding-sync traffic, priced by
        the delay model. The AsyncScheduler folds this into per-client
        finish times when ``comm_factors`` model heterogeneous links —
        compute heterogeneity alone (``speed_factors``) misses clients on
        slow networks."""
        per = 2.0 * model_bytes(engine.n_params) \
            + self.client_embed_bytes(engine, stats)
        return np.asarray(self.delay.comm_time(per), np.float64)

    def sync_overhead(self, engine, sel, stats) -> float:
        """The per-merge server-side communication overhead ``o`` (seconds);
        the wall-clock meter amortizes it by the sync interval tau."""
        embed_total = seq_sum(self.client_embed_bytes(engine, stats))
        return self.delay.comm_time(
            embed_total / max(len(sel), 1) + 2 * model_bytes(engine.n_params))

    def round_cost(self, engine, state, sel, stats):
        cost = CostMeter()
        m = len(sel)
        comm_model = 2 * model_bytes(engine.n_params) \
            + engine.strategy.round_model_bytes(engine)
        comm_embed = self.client_embed_bytes(engine, stats)
        flops = self.client_flops(engine, sel, stats)
        cost.comm_model_bytes += seq_sum(np.full(m, comm_model))
        cost.comm_embed_bytes += seq_sum(comm_embed)
        cost.compute_flops += seq_sum(flops)
        o = self.delay.comm_time(
            cost.comm_embed_bytes / max(m, 1)
            + 2 * model_bytes(engine.n_params))
        per_client_compute = self.delay.compute_time(flops)
        cost.wall_clock_s = float(np.max(per_client_compute)) + o / max(state.tau, 1)
        cost.sync_events = int(np.asarray(stats["n_sync"]).sum())
        return cost


# ---------------------------------------------------------------------------
# round scheduling (lockstep vs buffered-async)
# ---------------------------------------------------------------------------

@runtime_checkable
class RoundScheduler(Protocol):
    """Owns the execution structure of a run: when cohorts dispatch, when
    updates merge, and what wall-clock a merge bills. The engine exposes the
    two halves of a round (``dispatch`` = client work, ``merge`` = server
    work) and the scheduler sequences them."""

    def run(self, engine: "FedEngine", state: "EngineState") -> None:
        ...


@dataclass
class SyncScheduler:
    """The paper's lockstep loop: every round dispatches a fresh cohort and
    blocks until all of it merges. History-identical to the legacy
    ``run_federated`` round loop bit-for-bit — through either executor.

    ``fused`` selects the executor: ``None`` (default) auto-detects — the
    scanned donated-buffer executor (``FedEngine.run_fused``) whenever every
    component is fusable (see ``FedEngine.fused_eligibility``), else the
    per-round stepwise loop; ``True`` forces fused (raising with the reason
    if ineligible); ``False`` forces stepwise.

    When the engine has a device ``mesh``, the fused executor additionally
    shards each chunk's client axis across it — gated by the same
    ``fused_eligibility`` plus ``FedEngine.sharded_eligibility`` (the
    aggregator must be ``allreduce_safe``; ragged cohorts pad with
    zero-weight dummies, or fall back under ``client_sharding="divisible"``).
    On a 2-D ``("pods", "clients")`` mesh the historical tables themselves
    shard over the pod axis first (``FedEngine.pod_sharded_eligibility``).
    Every gate fails soft: pod-sharded -> client-sharded -> fused ->
    stepwise.
    """

    fused: Optional[bool] = None

    def run(self, engine, state):
        fused = self.fused
        if fused is None:
            fused, _ = engine.fused_eligibility()
        elif fused:
            ok, why = engine.fused_eligibility()
            if not ok:
                raise ValueError(f"fused executor unavailable: {why}")
        if fused:
            engine.run_fused(state)
            return
        for t in range(engine.rounds):
            if engine.run_round(state, t):
                break


@dataclass
class AsyncScheduler:
    """Buffered-staleness asynchronous rounds (FedAsync/FedBuff-style).

    ``concurrency`` clients are always in flight. Each dispatched client
    finishes at a virtual time priced by the engine's cost model (per-client
    compute time, optionally scaled by a per-client ``speed_factors``
    multiplier). Arrivals buffer at the server; once ``quorum`` of them are
    in, the server merges the buffer with staleness-discounted aggregation
    weights (see StalenessWeightedAggregator), advances one version, bills
    only the time it actually waited (VirtualClock), and re-dispatches that
    many fresh clients from the new global model. Stragglers keep training
    on the model version they departed with and merge late with staleness
    τ = merge_version - dispatch_version.

    With ``quorum == concurrency`` and homogeneous speed factors every merge
    is a full fresh cohort — history-identical to SyncScheduler, pinned by
    tests/test_async.py.

    Fault tolerance (all off by default; defaults keep the legacy event
    trajectory bit-identical):

    * ``comm_factors`` — per-client communication-time multipliers: each
      in-flight client's finish time adds ``client_comm_times * factor``
      (compute heterogeneity alone, ``speed_factors``, misses slow links).
    * ``timeout_s`` — a server-side wait budget per dispatched client; a
      client that would arrive later (or whose upload the FaultPlan drops)
      times out instead. Timed-out clients are re-dispatched with an
      exponentially growing budget (``timeout_s * backoff**attempt``) up to
      ``max_retries`` times, then abandoned and their slot backfilled with
      a fresh client — bounded retry, no slot ever leaks.
    * ``max_staleness`` — arrivals older than this many versions are
      evicted unmerged (their slot backfills fresh).
    * an engine ``FaultPlan`` — dropped uploads never arrive (without a
      timeout the slot is lost and counted ``n_lost``), stragglers stretch
      finish times by ``delay_factors``, corrupt uploads are poisoned at
      dispatch and quarantined by the engine's merge guard.

    Every event is counted in ``EngineState.fault_events``.
    """

    quorum: Optional[int] = None          # arrivals per merge; None -> concurrency
    concurrency: Optional[int] = None     # clients in flight; None -> clients_per_round
    staleness_mode: str = "poly"
    staleness_a: float = 0.5
    speed_factors: Optional[Union[Sequence[float], np.ndarray]] = None
    comm_factors: Optional[Union[Sequence[float], np.ndarray]] = None
    timeout_s: Optional[float] = None     # per-client server wait budget
    max_retries: int = 2                  # re-dispatches after a timeout
    backoff: float = 2.0                  # timeout budget growth per retry
    max_staleness: Optional[int] = None   # evict arrivals older than this

    def _per_client(self, values, n_clients: int, name: str) -> np.ndarray:
        if values is None:
            return np.ones(n_clients, np.float64)
        arr = np.asarray(values, np.float64)
        if arr.shape != (n_clients,):
            raise ValueError(
                f"{name} must have shape ({n_clients},), got {arr.shape}")
        return arr

    def run(self, engine, state):
        M = self.concurrency if self.concurrency is not None else engine.clients_per_round
        Q = self.quorum if self.quorum is not None else M
        if not 1 <= Q <= M:
            raise ValueError(f"quorum {Q} must be in [1, concurrency {M}]")
        if self.max_retries < 0 or self.backoff < 1.0:
            raise ValueError("max_retries must be >= 0 and backoff >= 1")
        factors = self._per_client(self.speed_factors, engine.fed.n_clients,
                                   "speed_factors")
        comm_f = (None if self.comm_factors is None else
                  self._per_client(self.comm_factors, engine.fed.n_clients,
                                   "comm_factors"))
        plan = getattr(engine, "faults", None)
        plan = plan if (plan is not None and not plan.empty) else None
        agg = engine.aggregator
        if isinstance(agg, StalenessWeightedAggregator):
            # same fail-fast contract as the engine's delay/cost_model knobs:
            # the scheduler's staleness knobs only parameterize its default
            # wrapper, never an explicitly staleness-aware aggregator
            if (self.staleness_mode, self.staleness_a) != ("poly", 0.5):
                raise ValueError(
                    "staleness_mode/staleness_a only configure the "
                    "scheduler's default wrapper; the engine aggregator is "
                    "already a StalenessWeightedAggregator — set mode/a on "
                    "it instead")
        else:
            agg = StalenessWeightedAggregator(
                base=agg, mode=self.staleness_mode, a=self.staleness_a)

        clock = VirtualClock()
        heap: list = []          # (event_time, seq, entry) — seq: stable ties
        seq = 0
        version = 0              # server model version (merge count)
        n_timeouts = 0
        # circuit breaker: total dropout (every upload lost, every retry
        # lost again) must degrade to a truncated run, never an infinite
        # timeout -> retry -> timeout loop against the virtual clock
        timeout_budget = engine.rounds * M * (self.max_retries + 2) * 8

        def dispatch_cohort(m: int, *, at: Optional[float] = None,
                            attempt: int = 0, forced_sel=None) -> None:
            nonlocal seq
            if forced_sel is not None:
                sel = np.asarray(forced_sel)
            else:
                saved = engine.clients_per_round
                engine.clients_per_round = m    # selectors size cohorts from this
                try:
                    sel = np.asarray(engine.selector.select(engine, state))
                finally:
                    engine.clients_per_round = saved
            out = engine.dispatch(state, sel, version)
            if plan is not None:
                cmask = plan.corruptions(version, sel)
                if cmask.any():
                    out = (corrupt_params_stack(out[0], cmask,
                                                plan.corrupt_value()),
                           ) + tuple(out[1:])
                drops = plan.drops(version, sel)
                dfact = plan.delay_factors(sel)
            else:
                drops = np.zeros(len(sel), bool)
                dfact = np.ones(len(sel), np.float64)
            times = engine.cost_model.client_compute_times(engine, state, sel, out[-1])
            ctimes = (None if comm_f is None else
                      engine.cost_model.client_comm_times(engine, state, sel,
                                                          out[-1]))
            base = clock.now if at is None else at
            for pos, cli in enumerate(sel):
                rel = float(times[pos]) * float(factors[cli])
                if ctimes is not None:
                    rel += float(ctimes[pos]) * float(comm_f[cli])
                rel *= float(dfact[pos])
                entry = dict(version=version, pos=pos, client=int(cli),
                             cohort=len(sel), out=out, rel_time=rel,
                             dispatch_time=base, attempt=attempt)
                budget = (None if self.timeout_s is None
                          else self.timeout_s * self.backoff ** attempt)
                if drops[pos] and budget is None:
                    # the upload is lost and the server waits forever for
                    # it: without a timeout this in-flight slot leaks
                    state.fault_events.n_lost += 1
                elif budget is not None and (drops[pos] or rel > budget):
                    entry["timed_out"] = True
                    heapq.heappush(heap, (base + budget, seq, entry))
                    seq += 1
                else:
                    heapq.heappush(heap, (base + rel, seq, entry))
                    seq += 1

        if engine.rounds <= 0:
            return    # SyncScheduler is a no-op here too; don't burn a cohort
        dispatch_cohort(M)
        buffer: list = []
        t = 0
        while t < engine.rounds and heap:
            when, _, entry = heapq.heappop(heap)
            if entry.get("timed_out"):
                state.fault_events.n_timeouts += 1
                n_timeouts += 1
                if n_timeouts > timeout_budget:
                    break           # graceful truncation, never a spin
                if entry["attempt"] < self.max_retries:
                    state.fault_events.n_retries += 1
                    dispatch_cohort(1, at=when, attempt=entry["attempt"] + 1,
                                    forced_sel=[entry["client"]])
                else:
                    state.fault_events.n_aborted += 1
                    dispatch_cohort(1, at=when)     # backfill a fresh slot
                continue
            if (self.max_staleness is not None
                    and version - entry["version"] > self.max_staleness):
                state.fault_events.n_evicted += 1
                dispatch_cohort(1, at=when)         # replace the stale slot
                continue
            buffer.append(entry)
            if len(buffer) < Q:
                continue
            last = entry                       # the quorum-completing arrival
            # canonical merge order (dispatch version, cohort position): a
            # deterministic restack, and for a single full cohort exactly the
            # dispatch order the synchronous engine aggregates in
            entries = sorted(buffer, key=lambda e: (e["version"], e["pos"]))
            buffer = []
            sel = np.asarray([e["client"] for e in entries])
            if (len({e["version"] for e in entries}) == 1
                    and [e["pos"] for e in entries]
                    == list(range(entries[0]["cohort"]))):
                out = entries[0]["out"]        # one whole cohort: reuse as-is
            else:
                rows = [jax.tree_util.tree_map(lambda x, i=e["pos"]: x[i], e["out"])
                        for e in entries]
                out = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)
            staleness = np.asarray([version - e["version"] for e in entries])
            o = engine.cost_model.sync_overhead(engine, sel, out[-1])
            elapsed = clock.merge_elapsed(
                last["dispatch_time"], last["rel_time"], o / max(state.tau, 1))
            stop = engine.merge(
                state, t, sel, out, staleness=staleness, aggregator=agg,
                wall_clock_s=elapsed, virtual_time=clock.now)
            version += 1
            t += 1
            if stop:
                break
            if t < engine.rounds:
                dispatch_cohort(len(entries))

        # Bill work that was dispatched but never merged (in flight or
        # buffered when the run ended): those model downloads, embedding
        # pulls, and local epochs really ran, so comm/compute meters must
        # count them — only wall-clock is forgiven, since the run ended at
        # the last merge and their remaining time overlapped it. With a full
        # quorum nothing is ever left over, keeping sync parity exact.
        leftovers = buffer + [e for _, _, e in heap]
        if leftovers:
            leftovers.sort(key=lambda e: (e["version"], e["pos"]))
            sel = np.asarray([e["client"] for e in leftovers])
            rows = [jax.tree_util.tree_map(lambda x, i=e["pos"]: x[i],
                                           e["out"][-1])
                    for e in leftovers]
            stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)
            cost = engine.cost_model.round_cost(engine, state, sel, stats)
            cost.wall_clock_s = 0.0
            state.result.costs.add(cost)


# ---------------------------------------------------------------------------
# round callbacks
# ---------------------------------------------------------------------------

@runtime_checkable
class RoundCallback(Protocol):
    """Side-effect hooks; see repro.api.callbacks for the default stack."""

    def on_run_start(self, engine: "FedEngine", state: "EngineState") -> None:
        ...

    def on_round_end(self, ctx) -> None:
        ...

    def on_run_end(self, engine: "FedEngine", state: "EngineState") -> None:
        ...
