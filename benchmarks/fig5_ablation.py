"""Paper Fig. 5 ablation: FedAll vs FedAIS1 (importance only) vs FedAIS2
(adaptive sync only) vs full FedAIS."""
from __future__ import annotations

from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup

ABLATIONS = ("fedall", "fedais1", "fedais2", "fedais")


def run(quick: bool = True) -> list[dict]:
    g, fed = fed_setup("coauthor", 32 if quick else 64, 16, "iid")
    rounds = 12 if quick else 40
    rows = []
    for m in ABLATIONS:
        res = FedEngine(g, fed, method_config(m, tau0=4), rounds=rounds,
                        clients_per_round=5, seed=0).run()
        rows.append({
            "method": m,
            "final_acc": round(res.final["acc"] * 100, 2),
            "comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
            "embed_comm_mb": round(res.final["comm_embed_bytes"] / 1e6, 2),
            "sync_events": res.final["sync_events"],
        })
    return rows
