"""End-to-end LM training driver.

Two modes:
  * standard: data-parallel AdamW training of any assigned arch (or the
    bundled ~100M ``mini`` config) on the synthetic token pipeline.
  * --fed: FedAIS-scheduled training — the paper's technique applied to
    sequence models (DESIGN.md §5): clients = data shards, per-round
    importance-weighted batch selection from per-sequence loss deltas
    (Eq. 7-8), local steps with FedAvg sync, and the Eq. 11 rule adapting
    the number of local steps between syncs.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch mini --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch mini --steps 200 --fed --clients 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import ModelConfig, get_smoke_config, list_archs
from repro.core.sync import adaptive_tau
from repro.data.pipeline import TokenPipeline, make_lm_batch
from repro.models import lm
from repro.optim import adamw_init
from repro.optim.schedules import linear_warmup_cosine
from repro.utils.tree import tree_count_params


def mini_config(**overrides) -> ModelConfig:
    """Small dense model for the CPU end-to-end example (fast + learnable).
    Scale up with e.g. ``mini_config(d_model=768, n_layers=12)`` (~100M)."""
    kw = dict(
        arch_id="mini", family="dense", n_layers=4, d_model=384,
        n_heads=6, n_kv_heads=2, d_ff=1536, vocab_size=8192, head_dim=64,
        block_pattern=("attn",), activation="silu", gated_mlp=True,
        dtype="float32", max_seq_len=2048,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def get_train_config(arch: str) -> ModelConfig:
    if arch == "mini":
        return mini_config()
    return get_smoke_config(arch)


def train(args) -> dict:
    cfg = get_train_config(args.arch)
    pipe = TokenPipeline(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)
    opt = adamw_init(params)
    print(f"arch={cfg.arch_id} params={tree_count_params(params)/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq_len}")

    schedule = linear_warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)
    step_fn = jax.jit(lm.make_train_step(cfg, schedule))

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            params = load_checkpoint(args.ckpt_dir, last, params)
            print(f"resumed from step {last}")
            start = last

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_lm_batch(pipe, step)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = args.batch * args.seq_len * (step - start + 1) / max(dt, 1e-9)
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tok_s:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params)
    return {"final_loss": losses[-1], "first_loss": losses[0], "losses": losses}


def train_federated(args) -> dict:
    """FedAIS-scheduled LM training (the paper's bridge to the LM zoo)."""
    cfg = get_train_config(args.arch)
    K = args.clients
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)
    print(f"[fed] arch={cfg.arch_id} params={tree_count_params(params)/1e6:.1f}M clients={K}")

    # each client gets its own (differently-seeded) data shard
    pipes = [TokenPipeline(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed + 7 * k)
             for k in range(K)]
    # constant lr: client Adam state resets every round (FedAvg semantics),
    # so a warmup schedule would pin the lr at its first values forever
    from repro.optim.schedules import constant as constant_schedule
    step_fn = jax.jit(lm.make_train_step(cfg, constant_schedule(args.lr)))
    loss_fn = jax.jit(lambda p, b: lm.lm_loss(p, cfg, b)[0])

    # server aggregation through the repro.api registry's canonical FedAvg
    # (clients train equal token counts per round, so plain FedAvg is exact)
    from repro.api.registry import build_aggregator
    aggregator = build_aggregator("fedavg")

    tau0 = args.tau0
    tau = tau0
    f0 = None
    prev_losses = [None] * K
    rounds = 0
    total_steps = 0
    history = []
    comm_events = 0
    t_start = time.time()

    while total_steps < args.steps:
        new_params = []
        round_losses = []
        for k in range(K):
            p_k, opt_k = params, adamw_init(params)
            # importance-weighted batch choice: prefer the shard batch with
            # the largest loss delta (Eq. 7-8 at sequence-batch granularity)
            candidates = [make_lm_batch(pipes[k], rounds * tau * 3 + c) for c in range(3)]
            if prev_losses[k] is not None:
                deltas = [abs(float(loss_fn(params, b)) - prev_losses[k]) for b in candidates]
                order = np.argsort(deltas)[::-1]
            else:
                order = range(len(candidates))
            picked = [candidates[i] for i in list(order)[: max(1, tau)]]
            last = None
            for j, b in enumerate(picked):
                p_k, opt_k, m = step_fn(p_k, opt_k, b)
                last = float(m["loss"])
            prev_losses[k] = last
            round_losses.append(last)
            new_params.append(p_k)
        # per-leaf stacking keeps the transient K-copy to one leaf at a time
        params = jax.tree_util.tree_map(
            lambda *xs: aggregator.aggregate(jnp.stack(xs)), *new_params)
        comm_events += K
        total_steps += tau * K
        rounds += 1
        f_t = float(np.mean(round_losses))
        if f0 is None:
            f0 = max(f_t, 1e-9)
        tau = adaptive_tau(f_t, f0, tau0)
        history.append({"round": rounds, "loss": f_t, "tau": tau, "steps": total_steps})
        print(f"[fed] round {rounds:3d} steps={total_steps:4d} "
              f"loss={f_t:.4f} tau={tau} syncs={comm_events}")
    return {"history": history, "final_loss": history[-1]["loss"],
            "first_loss": history[0]["loss"], "sync_events": comm_events,
            "wall_s": time.time() - t_start}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mini", choices=["mini", *list_archs()])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fed", action="store_true")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau0", type=int, default=4)
    args = ap.parse_args()
    out = train_federated(args) if args.fed else train(args)
    print(f"loss: {out['first_loss']:.4f} -> {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
