"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True) +
hypothesis property sweeps over shapes/dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # hypothesis, or a skip-stub when absent

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spmm.ops import adjacency_from_neighbors, block_spmm, neighbor_mean
from repro.kernels.spmm.ref import neighbor_mean_ref, spmm_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


# ---------------------------------------------------------------------------
# spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,d", [(64, 64, 32), (100, 130, 70), (256, 256, 128), (33, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_matches_ref(rng, n, m, d, dtype):
    a = (rng.random((n, m)) < 0.1).astype(np.float32) * rng.random((n, m)).astype(np.float32)
    x = rng.standard_normal((m, d)).astype(np.float32)
    a_j, x_j = jnp.asarray(a, dtype), jnp.asarray(x, dtype)
    got = block_spmm(a_j, x_j)
    want = spmm_ref(a_j, x_j)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_spmm_block_skipping_is_exact(rng):
    """Zero tiles are skipped; result must still be exact."""
    a = np.zeros((256, 256), np.float32)
    a[:64, :64] = rng.random((64, 64))          # single live tile
    x = rng.standard_normal((256, 64)).astype(np.float32)
    got = block_spmm(jnp.asarray(a), jnp.asarray(x), block_n=64, block_m=64, block_d=64)
    np.testing.assert_allclose(np.asarray(got), a @ x, atol=1e-4)


@given(n=st.integers(8, 96), k=st.integers(1, 12), d=st.integers(4, 48))
@settings(max_examples=15, deadline=None)
def test_neighbor_mean_property(n, k, d):
    rng = np.random.default_rng(n * 1000 + k * 10 + d)
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.6).astype(np.float32)
    f = rng.standard_normal((n, d)).astype(np.float32)
    got = neighbor_mean(jnp.asarray(f), jnp.asarray(idx), jnp.asarray(mask))
    want = neighbor_mean_ref(jnp.asarray(f), jnp.asarray(idx), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_adjacency_row_normalised(rng):
    idx = rng.integers(0, 32, (16, 6)).astype(np.int32)
    mask = (rng.random((16, 6)) < 0.8).astype(np.float32)
    a = np.asarray(adjacency_from_neighbors(jnp.asarray(idx), jnp.asarray(mask), 32))
    rows = a.sum(-1)
    has_nbrs = mask.sum(-1) > 0
    np.testing.assert_allclose(rows[has_nbrs], 1.0, atol=1e-5)
    np.testing.assert_allclose(rows[~has_nbrs], 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,hd", [(2, 64, 4, 2, 32), (1, 128, 8, 8, 16), (2, 96, 4, 1, 64)])
@pytest.mark.parametrize("window", [None, 16])
def test_flash_attention_matches_ref(rng, b, s, h, hkv, hd, window):
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(rng, dtype, tol):
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), dtype)
    got = flash_attention(q, k, v, block_q=16, block_k=16)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@given(s=st.integers(4, 80), h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       hd=st.sampled_from([8, 16]))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s, h, g, hd):
    """Arbitrary (ragged) seq lens + GQA group sizes match the oracle."""
    if h % g:
        return
    rng = np.random.default_rng(s * 100 + h * 10 + hd)
    q = jnp.asarray(rng.standard_normal((1, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, h // g, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, h // g, hd)), jnp.float32)
    got = flash_attention(q, k, v, block_q=16, block_k=16)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_attention_causality(rng):
    """Changing future keys must not change past outputs."""
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.float32)
    out1 = flash_attention(q, k, v, block_q=8, block_k=8)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    out2 = flash_attention(q, k2, v2, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out1[:, :20]), np.asarray(out2[:, :20]), atol=1e-6)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,n", [(2, 32, 2, 16), (1, 100, 4, 32), (2, 64, 1, 8)])
def test_wkv6_matches_ref(rng, b, t, h, n):
    r, k, v = [jnp.asarray(rng.standard_normal((b, t, h, n)) * 0.5, jnp.float32) for _ in range(3)]
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((b, t, h, n)) * 0.5)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, n)) * 0.1, jnp.float32)
    y, s = wkv6(r, k, v, w, u, chunk=16)
    yr, sr = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-5)


@given(t=st.integers(3, 70), n=st.sampled_from([8, 16]), chunk=st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_wkv6_padding_property(t, n, chunk):
    """Non-multiple T is padded with identity steps: outputs+state exact."""
    rng = np.random.default_rng(t * 31 + n)
    r, k, v = [jnp.asarray(rng.standard_normal((1, t, 2, n)) * 0.3, jnp.float32) for _ in range(3)]
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((1, t, 2, n)))), jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, n)) * 0.1, jnp.float32)
    y, s = wkv6(r, k, v, w, u, chunk=chunk)
    yr, sr = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-5)


def test_wkv6_state_streaming(rng):
    """Running two halves with carried state == running the whole sequence."""
    b, t, h, n = 1, 32, 2, 16
    r, k, v = [jnp.asarray(rng.standard_normal((b, t, h, n)) * 0.4, jnp.float32) for _ in range(3)]
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((b, t, h, n)))), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, n)) * 0.1, jnp.float32)
    y_full, _ = wkv6_ref(r, k, v, w, u)
    y1, s1 = wkv6_ref(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u)
    y2, _ = wkv6_ref(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)


# ---------------------------------------------------------------------------
# spmm: autotune table, block-mask derivation, training-grade VJP
# (CI's "kernels" lane runs exactly these via `-m kernels`)
# ---------------------------------------------------------------------------

@pytest.mark.kernels
def test_autotune_table_shapes_and_tiles_are_sane():
    """Every table key/entry is pow2-bucketed, blocks fit the padded dims,
    and entries keep the TPU tiling discipline (fp32 min tile (8, 128):
    sublane dim a multiple of 8, lane dims multiples of 128) so a table hit
    can compile on-device, not just interpret."""
    from repro.kernels.spmm.ops import AUTOTUNE_TABLE, _pow2ceil

    assert AUTOTUNE_TABLE
    for (n, m, d), (bn, bm, bd) in AUTOTUNE_TABLE.items():
        for v in (n, m, d, bn, bm, bd):
            assert v == _pow2ceil(v), ((n, m, d), (bn, bm, bd))
        assert bn <= n and bm <= m and bd <= d
        assert bn % 8 == 0 and bm % 128 == 0 and bd % 128 == 0


@pytest.mark.kernels
def test_best_block_sizes_table_hit_and_heuristic():
    from repro.kernels.spmm.ops import AUTOTUNE_TABLE, best_block_sizes

    key = sorted(AUTOTUNE_TABLE)[0]
    assert best_block_sizes(*key) == AUTOTUNE_TABLE[key]
    # lookups bucket to the pow2 ceiling, so near-shapes share the entry
    n, m, d = key
    assert best_block_sizes(n - 1 or 1, m - 1, d - 1) == AUTOTUNE_TABLE[key]
    # off-table shapes fall back to the capped covering heuristic
    bn, bm, bd = best_block_sizes(3000, 5000, 7)
    assert (bn, bm, bd) == (128, 128, 8)
    assert best_block_sizes(4, 4, 4) == (4, 4, 4)


@pytest.mark.kernels
def test_adjacency_block_mask_matches_tile_reduce(rng):
    """The O(N*K) scatter-max block mask must equal the O(N*M) tile
    max-reduce over the dense adjacency — including all-padding rows."""
    from repro.kernels.spmm.ops import adjacency_block_mask

    n, m, k = 48, 100, 6
    idx = rng.integers(0, m, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.5).astype(np.float32)
    mask[5] = 0.0
    a = np.asarray(adjacency_from_neighbors(
        jnp.asarray(idx), jnp.asarray(mask), m))
    for bn, bm in ((16, 32), (8, 128), (48, 128)):
        got = np.asarray(adjacency_block_mask(
            jnp.asarray(idx), jnp.asarray(mask), m, bn, bm))
        nb_n, nb_m = -(-n // bn), -(-m // bm)
        ap = np.zeros((nb_n * bn, nb_m * bm), np.float32)
        ap[:n, :m] = a
        want = (np.abs(ap.reshape(nb_n, bn, nb_m, bm)).max(axis=(1, 3))
                > 0).astype(np.int32)
        assert np.array_equal(got, want), (bn, bm)


@pytest.mark.kernels
def test_block_spmm_grad_is_transpose(rng):
    """The custom VJP: dx must equal A^T @ dy (computed densely), and the
    adjacency's cotangent is zero by construction — raw autodiff through
    the Pallas interpreter has no transpose rule, so this path is what
    makes the spmm backend trainable."""
    import jax

    a = (rng.random((40, 56)) < 0.2).astype(np.float32)
    x = rng.standard_normal((56, 24)).astype(np.float32)
    c = rng.standard_normal((40, 24)).astype(np.float32)
    a_j, x_j, c_j = (jnp.asarray(v) for v in (a, x, c))

    def loss(a_, x_):
        return jnp.sum(block_spmm(a_, x_, interpret=True) * c_j)

    da, dx = jax.grad(loss, argnums=(0, 1))(a_j, x_j)
    np.testing.assert_allclose(np.asarray(dx), a.T @ c, atol=1e-4)
    assert np.array_equal(np.asarray(da), np.zeros_like(a))


@pytest.mark.kernels
def test_neighbor_spmm_grad_matches_gather(rng):
    """Gradients through the full neighbor aggregation (adjacency build +
    block mask + kernel) agree with the dense gather backend."""
    import jax

    from repro.models.gcn import neighbor_aggregate

    n, k, d = 30, 5, 12
    idx = jnp.asarray(rng.integers(0, n, (n, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((n, k)) < 0.6).astype(np.float32))
    t = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))

    def loss(table, backend):
        out = neighbor_aggregate(table, idx, mask, backend=backend,
                                 interpret=True)
        return jnp.sum(out ** 2)

    want = jax.grad(loss)(t, "gather")
    got = jax.grad(loss)(t, "spmm")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.kernels
def test_autotune_sweep_smoke():
    """The kernel_bench --autotune-spmm sweep at a tiny off-table shape:
    candidates include the incumbent, timings are positive, the winner is
    one of the candidates, and correctness holds at every candidate."""
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.kernel_bench import autotune_spmm, spmm_candidates

    from repro.kernels.spmm.ops import best_block_sizes

    shape = (16, 64, 32)
    cands = spmm_candidates(*shape)
    assert best_block_sizes(*shape) in cands and len(cands) >= 3
    [row] = autotune_spmm([shape], repeats=1)
    blocks = [tuple(t["blocks"]) for t in row["candidates"]]
    assert sorted(blocks) == sorted(cands)
    assert all(t["us_per_call"] > 0 for t in row["candidates"])
    assert row["best"] in blocks and row["table"] is None
