"""Streaming graph updates for the serving path.

``GraphStore`` owns the mutable serving graph in the same fixed-shape padded
neighbor-list form training evals use (``graph/csr``), pre-allocated to a
node capacity so new nodes append without reshaping anything the jitted
query paths see. ``add_nodes`` / ``add_edges`` mutate the adjacency and
return the *exact* set of cached layer-1 rows the mutation dirties: a row's
h1 depends only on its own features and its 1-hop neighborhood, so adding an
edge (u, v) invalidates {u, v} and adding a node invalidates the node plus
every neighbor it attaches to — nothing else (the layer-2 consumers read h1
at query time and are never cached). ``refresh_invalid`` is the background
re-embed batch (driven through ``QueryEngine.refresh``, which owns the
bucket-shaped compiled compute).

Capacity is elastic: when an insert outgrows the current allocation the
store grows geometrically (``growth`` factor, default 1.5x) instead of
failing, so a long-lived serving process absorbs unbounded streams with
amortized O(1) copies. ``CapacityError`` is reserved for the configurable
hard ceiling (``max_capacity``) — the operator's memory budget — and is
never raised when no ceiling is set.
"""
from __future__ import annotations

import numpy as np


class CapacityError(RuntimeError):
    """The store's configured ``max_capacity`` hard ceiling is exhausted."""


class GraphStore:
    """Mutable padded-adjacency graph with elastic node capacity.

    Arrays (host numpy; the device mirrors live on ``ServedModel``):
        features (capacity, F) float32
        nbr_idx  (capacity, D) int32
        nbr_mask (capacity, D) float32
    Rows ``[0, n_active)`` are live; the rest are zeroed headroom. Inserts
    past the headroom grow the arrays geometrically (``growth``); only the
    optional ``max_capacity`` hard cap ever raises :class:`CapacityError`.
    """

    def __init__(self, features: np.ndarray, nbr_idx: np.ndarray,
                 nbr_mask: np.ndarray, *, capacity: int | None = None,
                 max_capacity: int | None = None, growth: float = 1.5,
                 headroom: float = 0.25, seed: int = 0):
        n, f = features.shape
        d = nbr_idx.shape[1]
        if capacity is None:
            capacity = n + max(64, int(np.ceil(n * headroom)))
        if capacity < n:
            raise ValueError(f"capacity {capacity} < {n} initial nodes")
        if growth <= 1.0:
            raise ValueError(f"growth factor must be > 1, got {growth}")
        if max_capacity is not None and max_capacity < capacity:
            raise ValueError(f"max_capacity {max_capacity} < initial "
                             f"capacity {capacity}")
        self.max_capacity = max_capacity
        self.growth = float(growth)
        self.n_grows = 0
        self.n_active = n
        self.max_deg = d
        self.features = np.zeros((capacity, f), np.float32)
        self.features[:n] = features
        self.nbr_idx = np.zeros((capacity, d), np.int32)
        self.nbr_idx[:n] = nbr_idx
        self.nbr_mask = np.zeros((capacity, d), np.float32)
        self.nbr_mask[:n] = nbr_mask
        self.rng = np.random.default_rng(seed)
        self.n_edges_added = 0
        self.n_edges_evicted = 0          # full rows where a slot was replaced

    @property
    def capacity(self) -> int:
        return self.features.shape[0]

    @property
    def n_features(self) -> int:
        return self.features.shape[1]

    def neighbors(self, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Padded (len(rows), D) neighbor slices for a query/refresh batch."""
        rows = np.asarray(rows, np.int64)
        return self.nbr_idx[rows], self.nbr_mask[rows]

    def degrees(self, rows: np.ndarray | None = None) -> np.ndarray:
        m = self.nbr_mask[: self.n_active] if rows is None else self.nbr_mask[rows]
        return m.sum(-1).astype(np.int64)

    def _grow(self, needed: int) -> None:
        """Geometric reallocation to fit ``needed`` live rows: the new
        capacity is max(ceil(capacity x growth), needed), clamped to the
        ``max_capacity`` ceiling — which is also the only condition that
        still raises :class:`CapacityError`."""
        if needed <= self.capacity:
            return
        if self.max_capacity is not None and needed > self.max_capacity:
            raise CapacityError(
                f"GraphStore hard cap: {needed} nodes exceeds max_capacity "
                f"{self.max_capacity} (raise the ceiling or evict)")
        new_cap = max(int(np.ceil(self.capacity * self.growth)), needed)
        if self.max_capacity is not None:
            new_cap = min(new_cap, self.max_capacity)

        def pad(a: np.ndarray) -> np.ndarray:
            out = np.zeros((new_cap,) + a.shape[1:], a.dtype)
            out[: len(a)] = a
            return out

        self.features = pad(self.features)
        self.nbr_idx = pad(self.nbr_idx)
        self.nbr_mask = pad(self.nbr_mask)
        self.n_grows += 1

    # -- mutations -------------------------------------------------------

    def _check_ids(self, ids: np.ndarray, what: str) -> np.ndarray:
        ids = np.asarray(ids, np.int64).reshape(-1)
        if len(ids) and (ids.min() < 0 or ids.max() >= self.n_active):
            raise ValueError(f"{what} references node outside "
                             f"[0, {self.n_active}): {ids.min()}..{ids.max()}")
        return ids

    def _insert_neighbor(self, u: int, v: int) -> bool:
        """Append v to u's slots (first free one; evict a random slot when
        the row is full — the same capped-degree semantics
        ``build_padded_neighbors`` applies to the static graph). Duplicate
        edges are dropped. Returns True if the row changed."""
        row_mask = self.nbr_mask[u]
        live = row_mask > 0
        if v in self.nbr_idx[u][live]:
            return False
        if live.all():
            slot = int(self.rng.integers(self.max_deg))
            self.n_edges_evicted += 1
        else:
            slot = int(np.argmin(live))
        self.nbr_idx[u, slot] = v
        self.nbr_mask[u, slot] = 1.0
        return True

    def add_edges(self, edges: np.ndarray) -> np.ndarray:
        """Insert undirected edges [(u, v), ...] between live nodes.
        Returns the sorted unique affected rows (the edge endpoints) whose
        cached layer-1 embedding is now stale."""
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        self._check_ids(edges.reshape(-1), "add_edges")
        affected = set()
        for u, v in edges:
            u, v = int(u), int(v)
            if u == v:
                continue
            changed = self._insert_neighbor(u, v)
            changed |= self._insert_neighbor(v, u)
            if changed:
                affected.update((u, v))
                self.n_edges_added += 1
        return np.array(sorted(affected), np.int64)

    def add_nodes(self, feats: np.ndarray,
                  edges: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Append new nodes (optionally with attachment edges, which may
        reference the new ids). Returns ``(new_ids, affected_rows)`` where
        ``affected_rows`` is the new nodes' 1-hop neighborhood — exactly the
        cache rows to invalidate."""
        feats = np.asarray(feats, np.float32).reshape(-1, self.n_features)
        c = len(feats)
        self._grow(self.n_active + c)
        ids = np.arange(self.n_active, self.n_active + c, dtype=np.int64)
        self.features[ids] = feats
        self.n_active += c
        affected = set(int(i) for i in ids)
        if edges is not None and len(edges):
            affected.update(int(r) for r in self.add_edges(edges))
        return ids, np.array(sorted(affected), np.int64)
