"""graph.csr: padded neighbor lists and their CSR edge-array form.

``csr_from_padded`` feeds both the training eval path and the serving
micro-batcher (which pads its output to fixed per-bucket shapes), so its
edge cases — zero-neighbor nodes, fully-masked rows, duplicate slots — and
its bit-level agreement with the dense gather aggregation are pinned here.
"""
import numpy as np
import pytest

from hypcompat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.graph.csr import build_padded_neighbors, csr_from_padded


def segment_mean(table, csr, n):
    """The segment-backend aggregation in plain numpy."""
    out = np.zeros((n, table.shape[1]), table.dtype)
    np.add.at(out, csr["dst"], table[csr["src"]])
    return out * csr["inv_deg"][:, None]


def gather_mean(table, idx, mask):
    """The gather-backend aggregation in plain numpy."""
    g = table[idx] * mask[..., None]
    return g.sum(1) / np.maximum(mask.sum(1), 1.0)[:, None]


def test_zero_neighbor_nodes_emit_no_edges():
    idx, mask = build_padded_neighbors([[1], [], [0, 1]], max_deg=2)
    c = csr_from_padded(idx, mask)
    assert 1 not in c["dst"]                       # isolated node: no edges
    assert len(c["src"]) == int(mask.sum()) == 3
    # inv_deg is defined (not inf/nan) for the isolated row, and the
    # aggregate for it is exactly zero
    assert np.isfinite(c["inv_deg"]).all()
    feats = np.random.default_rng(0).standard_normal((3, 5)).astype(np.float32)
    agg = segment_mean(feats, c, 3)
    assert np.array_equal(agg[1], np.zeros(5, np.float32))


def test_fully_masked_rows():
    """All-padding input (every mask slot zero) produces an empty edge list
    and an all-zero aggregate — not an indexing error."""
    idx = np.zeros((4, 3), np.int32)
    mask = np.zeros((4, 3), np.float32)
    c = csr_from_padded(idx, mask)
    assert c["src"].shape == c["dst"].shape == (0,)
    assert c["inv_deg"].shape == (4,)
    agg = segment_mean(np.ones((4, 2), np.float32), c, 4)
    assert np.array_equal(agg, np.zeros((4, 2), np.float32))


def test_edge_order_is_row_major():
    """dst non-decreasing, slots in list order — the invariant that makes
    the segment reduction's edge visitation order (and so its float sums)
    reproducible run-to-run."""
    idx, mask = build_padded_neighbors([[2, 1], [0], [0, 1]], max_deg=2)
    c = csr_from_padded(idx, mask)
    assert list(c["dst"]) == [0, 0, 1, 2, 2]
    assert list(c["src"]) == [2, 1, 0, 0, 1]


def test_padding_slots_are_excluded():
    idx = np.array([[5, 7, 0], [3, 0, 0]], np.int32)     # 0s are padding
    mask = np.array([[1, 1, 0], [1, 0, 0]], np.float32)
    c = csr_from_padded(idx, mask)
    assert list(c["src"]) == [5, 7, 3]
    assert list(c["dst"]) == [0, 0, 1]
    assert np.allclose(c["inv_deg"], [0.5, 1.0])


def test_degree_cap_subsamples_without_replacement():
    adj = [list(range(1, 11)), [0]] + [[0] for _ in range(9)]
    idx, mask = build_padded_neighbors(adj, max_deg=4, seed=0)
    row = idx[0][mask[0] > 0]
    assert len(row) == 4 == len(set(row.tolist()))       # no duplicates
    assert set(row.tolist()) <= set(range(1, 11))
    # subsampled rows come out SORTED, so the padded form (and every CSR
    # derived from it) is canonical: which neighbors survive depends on the
    # seed, but never the order the rng happened to draw them in
    assert row.tolist() == sorted(row.tolist())


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(st.data())
def test_bucketed_csr_roundtrips_padded_form(data):
    """Property: the jit-stable (n*K,)-slot bucketed CSR is csr_from_padded
    plus inert padding — dropping the slots routed to the overflow segment
    reproduces csr_from_padded's src/dst arrays EXACTLY (same edges, same
    row-major order, so the same per-segment float summation order),
    inv_deg matches bitwise, and every padding slot is (src=0, dst=n)."""
    from repro.graph.csr import bucketed_csr_from_padded

    n = data.draw(st.integers(1, 12), label="n")
    d = data.draw(st.integers(1, 5), label="max_deg")
    adj = [
        data.draw(st.lists(st.integers(0, n - 1), min_size=0, max_size=d,
                           unique=True), label=f"adj[{i}]")
        for i in range(n)
    ]
    idx, mask = build_padded_neighbors(adj, max_deg=d)
    c = csr_from_padded(idx, mask)
    bc = {k: np.asarray(v) for k, v in
          bucketed_csr_from_padded(idx, mask).items()}
    assert bc["src"].shape == bc["dst"].shape == (n * d,)
    real = bc["dst"] < n
    assert np.array_equal(bc["src"][real], c["src"])
    assert np.array_equal(bc["dst"][real], c["dst"])
    assert np.array_equal(bc["inv_deg"], c["inv_deg"])
    assert (bc["src"][~real] == 0).all() and (bc["dst"][~real] == n).all()
    # and the overflow segment is sliced off: the bucketed segment mean
    # equals the packed-CSR segment mean bit for bit
    feats = np.random.default_rng(n * 17 + d).standard_normal(
        (n, 6)).astype(np.float32)
    want = segment_mean(feats, c, n)
    got = np.zeros((n + 1, 6), np.float32)
    np.add.at(got, bc["dst"], feats[bc["src"]])
    assert np.array_equal(got[:n] * bc["inv_deg"][:, None], want)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(st.data())
def test_padded_csr_aggregate_matches_gather_bitwise(data):
    """Property: padded -> CSR -> per-row segment reduction is bit-identical
    to the dense masked gather for any adjacency, including isolated nodes
    and max-degree rows — the neighbor *sums* match exactly (np.add.at
    visits edges in csr order, i.e. the gather's slot order within each
    row), and so do the means once both sides apply the same float32
    normalization constant. (The repo's gather backend divides by deg where
    segment multiplies by inv_deg — a different rounding, which is why
    eval/serving parity is pinned per-backend, never across backends.)"""
    n = data.draw(st.integers(1, 12), label="n")
    d = data.draw(st.integers(1, 5), label="max_deg")
    adj = [
        data.draw(st.lists(st.integers(0, n - 1), min_size=0, max_size=d,
                           unique=True), label=f"adj[{i}]")
        for i in range(n)
    ]
    idx, mask = build_padded_neighbors(adj, max_deg=d)
    feats = np.random.default_rng(n * 31 + d).standard_normal(
        (n, 7)).astype(np.float32)
    c = csr_from_padded(idx, mask)
    seg_sum = np.zeros((n, 7), np.float32)
    np.add.at(seg_sum, c["dst"], feats[c["src"]])
    gat_sum = (feats[idx] * mask[..., None]).sum(1)
    assert np.array_equal(seg_sum, gat_sum)
    assert np.array_equal(seg_sum * c["inv_deg"][:, None],
                          gat_sum * c["inv_deg"][:, None])
    # the mean agrees with the gather backend's divide-form to float tolerance
    assert np.allclose(seg_sum * c["inv_deg"][:, None],
                       gather_mean(feats, idx, mask), rtol=1e-6, atol=1e-7)
