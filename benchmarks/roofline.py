"""Roofline table from the dry-run JSONs (§Roofline deliverable): per
(arch x shape x mesh), the three terms, the dominant bottleneck, and the
useful-FLOPs ratio. Reads benchmarks/results/dryrun/*.json produced by
``python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun``.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_dryrun(pattern: str = "*") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{pattern}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run(quick: bool = True) -> list[dict]:
    raws = load_dryrun()
    rows = []
    for r in raws:
        if r.get("status") == "skipped":
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": "skipped",
            })
            continue
        if r.get("status") != "ok":
            rows.append({
                "arch": r.get("arch"), "shape": r.get("shape"), "mesh": r.get("mesh"),
                "status": "error",
            })
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": round(rf["compute_s"] * 1e3, 3),
            "memory_ms": round(rf["memory_s"] * 1e3, 3),
            "collective_ms": round(rf["collective_s"] * 1e3, 3),
            "dominant": rf["dominant"],
            "useful_flops_ratio": round(rf["useful_flops_ratio"], 3),
            "mfu_upper_pct": round(rf["mfu_upper_bound"] * 100, 2),
            "temp_gb_per_device": round((r["memory"]["temp_bytes"] or 0) / 2**30, 2),
        })
    if not rows:
        rows.append({"status": "no dryrun results found — run repro.launch.dryrun first"})
    return rows
