"""Correctness tests for the §Perf beyond-paper optimizations: every
optimized path must match its paper-faithful baseline numerically
(optimizations change cost, never semantics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, long_context_variant
from repro.models import lm
from repro.sharding.specs import param_spec_tree


def _grad_err(ga, gb):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree_util.tree_leaves(ga), jax.tree_util.tree_leaves(gb)))


@pytest.mark.parametrize("arch", ["dbrx-132b", "arctic-480b"])
def test_moe_einsum_matches_sort(arch, key):
    """H1: the partition-friendly einsum dispatch == the sort dispatch
    (at no-drop capacity), including grouped routing."""
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    a, aux_a = lm.lm_forward(params, cfg, tokens)
    for overrides in ({"moe_impl": "einsum"}, {"moe_impl": "einsum", "moe_group_size": 8}):
        cfg2 = dataclasses.replace(cfg, **overrides)
        b, aux_b = lm.lm_forward(params, cfg2, tokens)
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=3e-5)
        assert abs(float(aux_a) - float(aux_b)) < 1e-5


def test_moe_einsum_gradients_match(key):
    cfg = get_smoke_config("dbrx-132b")
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    cfg2 = dataclasses.replace(cfg, moe_impl="einsum", moe_group_size=8)
    ga = jax.grad(lambda p: lm.lm_loss(p, cfg, batch)[0])(params)
    gb = jax.grad(lambda p: lm.lm_loss(p, cfg2, batch)[0])(params)
    assert _grad_err(ga, gb) < 2e-5


def test_rwkv_chunked_scan_matches(key):
    """H2.2: chunked WKV with boundary remat == plain scan (fwd + grad)."""
    cfg = get_smoke_config("rwkv6-1.6b")
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    cfg2 = dataclasses.replace(cfg, rwkv_chunk=4)
    a, _ = lm.lm_forward(params, cfg, tokens)
    b, _ = lm.lm_forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    ga = jax.grad(lambda p: lm.lm_loss(p, cfg, batch)[0])(params)
    gb = jax.grad(lambda p: lm.lm_loss(p, cfg2, batch)[0])(params)
    assert _grad_err(ga, gb) < 1e-5


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma3-12b", "recurrentgemma-2b"])
def test_flash_vjp_gradients_match_einsum(arch, key):
    """H3: GQA-native flash custom_vjp == einsum attention (fwd + grad)."""
    cfg = get_smoke_config(arch)
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 13), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    cfg2 = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk_size=4)
    a, _ = lm.lm_forward(params, cfg, tokens)
    b, _ = lm.lm_forward(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=3e-5)
    ga = jax.grad(lambda p: lm.lm_loss(p, cfg, batch)[0])(params)
    gb = jax.grad(lambda p: lm.lm_loss(p, cfg2, batch)[0])(params)
    assert _grad_err(ga, gb) < 2e-5


def test_long_context_variant_degrades_global_to_local():
    cfg = get_config("gemma3-12b")
    lc = long_context_variant(cfg)
    assert "attn" not in lc.block_pattern
    assert lc.block_pattern.count("local") == len(lc.block_pattern)
    # archs without the flag are unchanged
    ds = get_config("deepseek-67b")
    assert long_context_variant(ds).block_pattern == ds.block_pattern


def test_dp_profile_replicates_params(key):
    """H2.1: the dp profile replicates every weight (PartitionSpec())."""
    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) != 1:
        pytest.skip("single-device test")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("rwkv6-1.6b")
    shapes = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_spec_tree(shapes, mesh, profile="dp")
    assert all(s == P() for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
