"""Intra-graph federated partition: split one global graph across K clients,
extract cross-client ("ghost") edges, and build fixed-shape per-client arrays
stackable over a leading client axis (vmap/shard_map-ready).

Layout per client k (padded to the max over clients):
    features   (n_max, F)     own node features (rows >= n_k zero)
    labels     (n_max,)
    node_mask  (n_max,)       1 for real own nodes
    train_mask (n_max,)
    nbr_idx    (n_max, K)     neighbor slots; values < n_max index own rows,
                              values >= n_max index ghost slot (v - n_max)
    nbr_mask   (n_max, K)
    ghost_owner (g_max,)      owning client id (-1 pad)
    ghost_row   (g_max,)      row index within the owner's local arrays
    ghost_mask  (g_max,)

The combined embedding table a client sees is [own rows | ghost rows] of
size n_max + g_max — exactly the paper's Eq. (6) split into within-client
in-batch / within-client out-of-batch / cross-client terms.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.data import GraphData


@dataclass
class FederatedGraph:
    """All K clients stacked on a leading axis (numpy; moved to jax later)."""

    name: str
    n_clients: int
    n_max: int
    g_max: int
    max_deg: int
    features: np.ndarray     # (K, n_max, F)
    labels: np.ndarray       # (K, n_max)
    node_mask: np.ndarray    # (K, n_max)
    train_mask: np.ndarray   # (K, n_max)
    val_mask: np.ndarray     # (K, n_max)
    nbr_idx: np.ndarray      # (K, n_max, D)
    nbr_mask: np.ndarray     # (K, n_max, D)
    ghost_owner: np.ndarray  # (K, g_max)
    ghost_row: np.ndarray    # (K, g_max)
    ghost_mask: np.ndarray   # (K, g_max)
    global_ids: np.ndarray   # (K, n_max) original node id (-1 pad)
    n_classes: int
    n_cross_edges: int       # Table-1 style ΔE diagnostic

    @property
    def n_features(self) -> int:
        return self.features.shape[2]

    @property
    def client_sizes(self) -> np.ndarray:
        return self.node_mask.sum(axis=1).astype(np.int32)


def partition_graph(
    graph: GraphData,
    n_clients: int,
    *,
    alpha: float | None = None,   # None -> iid, else Dirichlet(alpha) non-iid
    max_deg: int = 32,
    edge_keep: float = 0.5,       # paper: 50% local-subgraph edge downsampling
    seed: int = 0,
) -> FederatedGraph:
    rng = np.random.default_rng(seed)
    n = graph.n_nodes
    c = graph.n_classes

    # ---- assign nodes to clients ----
    assign = np.empty(n, np.int64)
    if alpha is None:
        assign[:] = rng.integers(0, n_clients, size=n)
    else:
        # Dirichlet per class: p_i ~ Dir_K(alpha); class-i nodes split by p_i
        for cls in range(c):
            ids = np.where(graph.labels == cls)[0]
            rng.shuffle(ids)
            p = rng.dirichlet(np.full(n_clients, alpha))
            counts = rng.multinomial(len(ids), p)
            assign[ids] = np.repeat(np.arange(n_clients), counts)

    client_nodes = [np.where(assign == k)[0] for k in range(n_clients)]
    n_max = max(1, max(len(v) for v in client_nodes))
    local_of = np.full(n, -1, np.int64)
    for k, ids in enumerate(client_nodes):
        local_of[ids] = np.arange(len(ids))

    # ---- split edges, downsample within-client edges ----
    e = graph.edges
    same = assign[e[:, 0]] == assign[e[:, 1]]
    within = e[same]
    cross = e[~same]
    if edge_keep < 1.0 and len(within):
        within = within[rng.random(len(within)) < edge_keep]

    # ---- per-client adjacency over [own | ghost] rows ----
    F = graph.n_features
    feats = np.zeros((n_clients, n_max, F), np.float32)
    labels = np.zeros((n_clients, n_max), np.int32)
    node_mask = np.zeros((n_clients, n_max), np.float32)
    train_mask = np.zeros((n_clients, n_max), np.float32)
    val_mask = np.zeros((n_clients, n_max), np.float32)
    global_ids = np.full((n_clients, n_max), -1, np.int32)

    adj = [[[] for _ in range(n_max)] for _ in range(n_clients)]
    ghosts: list[dict[int, int]] = [dict() for _ in range(n_clients)]  # global id -> slot

    def ghost_slot(k: int, gid: int) -> int:
        d = ghosts[k]
        if gid not in d:
            d[gid] = len(d)
        return d[gid]

    for u, v in within:
        k = assign[u]
        adj[k][local_of[u]].append(int(local_of[v]))
        adj[k][local_of[v]].append(int(local_of[u]))
    for u, v in cross:
        ku, kv = assign[u], assign[v]
        adj[ku][local_of[u]].append(n_max + ghost_slot(ku, int(v)))
        adj[kv][local_of[v]].append(n_max + ghost_slot(kv, int(u)))

    g_max = max(1, max(len(d) for d in ghosts))
    ghost_owner = np.full((n_clients, g_max), -1, np.int32)
    ghost_row = np.zeros((n_clients, g_max), np.int32)
    ghost_mask = np.zeros((n_clients, g_max), np.float32)

    nbr_idx = np.zeros((n_clients, n_max, max_deg), np.int32)
    nbr_mask = np.zeros((n_clients, n_max, max_deg), np.float32)

    for k in range(n_clients):
        ids = client_nodes[k]
        nk = len(ids)
        if nk:
            feats[k, :nk] = graph.features[ids]
            labels[k, :nk] = graph.labels[ids]
            node_mask[k, :nk] = 1.0
            train_mask[k, :nk] = graph.train_mask[ids]
            val_mask[k, :nk] = graph.val_mask[ids]
            global_ids[k, :nk] = ids
        for gid, slot in ghosts[k].items():
            ghost_owner[k, slot] = assign[gid]
            ghost_row[k, slot] = local_of[gid]
            ghost_mask[k, slot] = 1.0
        for i in range(nk):
            nbrs = adj[k][i]
            if not nbrs:
                continue
            if len(nbrs) > max_deg:
                nbrs = list(rng.choice(nbrs, size=max_deg, replace=False))
            nbr_idx[k, i, : len(nbrs)] = nbrs
            nbr_mask[k, i, : len(nbrs)] = 1.0

    return FederatedGraph(
        name=graph.name, n_clients=n_clients, n_max=n_max, g_max=g_max,
        max_deg=max_deg, features=feats, labels=labels, node_mask=node_mask,
        train_mask=train_mask, val_mask=val_mask, nbr_idx=nbr_idx,
        nbr_mask=nbr_mask, ghost_owner=ghost_owner, ghost_row=ghost_row,
        ghost_mask=ghost_mask, global_ids=global_ids, n_classes=graph.n_classes,
        n_cross_edges=int(len(cross)),
    )
