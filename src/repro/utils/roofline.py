"""Roofline model for TPU v5e meshes.

Three terms per (arch, shape, mesh), all in seconds (lower bound estimates):

    compute    = HLO_FLOPs       / (chips * PEAK_FLOPS)
    memory     = HLO_bytes       / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
pre-partition totals -> divided by chip count); collective_bytes comes from
``utils.hlo.collective_stats`` over the post-SPMD module (per-partition) so it
is multiplied back by chips before normalising -- both conventions are handled
by the caller passing ``per_device`` flags.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

# TPU v5e hardware constants (per chip), per assignment.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # whole-program FLOPs (all chips)
    hlo_bytes: float              # whole-program HBM bytes accessed
    collective_bytes: float       # whole-program bytes crossing ICI
    model_flops: float            # 6*N*D (dense) or 6*N_active*D analytic
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- how much compiled compute is 'useful'."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def mfu_upper_bound(self) -> float:
        """Model-FLOPs utilisation if the dominant term were the runtime."""
        t = self.bound_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    def row(self) -> dict:
        d = asdict(self)
        d.update(
            dominant=self.dominant,
            bound_s=self.bound_s,
            useful_flops_ratio=self.useful_flops_ratio,
            mfu_upper_bound=self.mfu_upper_bound,
        )
        return d

    def pretty(self) -> str:
        return (
            f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} "
            f"comp={self.compute_s*1e3:9.3f}ms mem={self.memory_s*1e3:9.3f}ms "
            f"coll={self.collective_s*1e3:9.3f}ms dom={self.dominant:10s} "
            f"useful={self.useful_flops_ratio:6.3f} mfu<= {self.mfu_upper_bound*100:5.1f}%"
        )


def model_flops_dense(n_params: int, tokens: int) -> float:
    """Standard 6*N*D estimate for a dense decoder train step."""
    return 6.0 * n_params * tokens


def model_flops_forward(n_params: int, tokens: int) -> float:
    """2*N*D for inference (prefill/decode) steps."""
    return 2.0 * n_params * tokens
