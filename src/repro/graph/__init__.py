"""Graph substrate: synthetic datasets, padded adjacency, centralized samplers."""
