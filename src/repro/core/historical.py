"""Historical embedding store (paper Eq. 6) — device-resident HBM tables.

Per client: layer-0 ghost features (synced cross-client raw inputs) and a
layer-1 table over [own | ghost] rows. In-batch rows are refreshed by the
client itself after each local step ("push"); ghost rows refresh only at
synchronization epochs ("pull" from the owner's table). Staleness counters
feed the Theorem-1 style diagnostics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class HistoricalState(NamedTuple):
    ghost_feat: jnp.ndarray   # (K, g_max, F)   layer-0 cross-client features
    hist1: jnp.ndarray        # (K, n_max + g_max, H1)
    age: jnp.ndarray          # (K, n_max + g_max) int32 epochs since refresh


def init_historical(n_clients: int, n_max: int, g_max: int, n_feat: int, h1: int) -> HistoricalState:
    return HistoricalState(
        ghost_feat=jnp.zeros((n_clients, g_max, n_feat), jnp.float32),
        hist1=jnp.zeros((n_clients, n_max + g_max, h1), jnp.float32),
        age=jnp.zeros((n_clients, n_max + g_max), jnp.int32),
    )


def push_embeddings(hist1: jnp.ndarray, age: jnp.ndarray, batch_idx: jnp.ndarray,
                    values: jnp.ndarray, valid: jnp.ndarray):
    """Client-side push of freshly computed in-batch embeddings (one client).

    hist1 (n_tot, H1); batch_idx (b,); values (b, H1); valid (b,) bool.
    """
    vals = jnp.where(valid[:, None], values, hist1[batch_idx])
    hist1 = hist1.at[batch_idx].set(vals)
    age = (age + 1).at[batch_idx].set(jnp.where(valid, 0, age[batch_idx] + 1))
    return hist1, age


def pull_ghosts(
    hist1_all: jnp.ndarray,     # (K, n_tot, H1) all clients' tables (snapshot)
    feats_all: jnp.ndarray,     # (K, n_max, F) all clients' features
    ghost_owner: jnp.ndarray,   # (g_max,) this client's ghost owners
    ghost_row: jnp.ndarray,     # (g_max,)
    ghost_mask: jnp.ndarray,    # (g_max,)
):
    """Cross-client embedding synchronization for one client: fetch the
    owners' current layer-1 embeddings and layer-0 features for every ghost.
    Returns (ghost_feat (g,F), ghost_h1 (g,H1)). In the real deployment this
    is the network transfer; the simulator charges its bytes to the cost
    meter and (on TPU) it lowers to a gather across the client mesh axis."""
    owner = jnp.maximum(ghost_owner, 0)
    gf = feats_all[owner, ghost_row] * ghost_mask[:, None]
    gh = hist1_all[owner, ghost_row] * ghost_mask[:, None]
    return gf, gh


def pull_ghosts_prefetched(
    ghost_src_feat: jnp.ndarray,   # (g_max, F) pre-gathered owner features
    ghost_src_h1: jnp.ndarray,     # (g_max, H1) pre-exchanged owner h1 rows
    ghost_mask: jnp.ndarray,       # (g_max,)
):
    """The pod-sharded twin of ``pull_ghosts``: when the (K, n_tot, H1)
    tables shard over a pod mesh axis there is no replicated ``hist1_all``
    to gather from, so the executor exchanges the owner rows up front (a
    ``ghost_owner``-keyed bucketed all-to-all over the round-start table
    snapshot — see ``federated.partition.ghost_exchange_buckets`` and
    ``sharding.tables``) and hands each client its pre-gathered sources.
    Same contract as ``pull_ghosts``: for slots with ``ghost_mask > 0`` the
    returned rows equal ``feats_all[owner, row]`` / ``hist1_all[owner, row]``
    exactly (the sources are a round-start snapshot either way), masked
    slots are 0."""
    gf = ghost_src_feat * ghost_mask[:, None]
    gh = ghost_src_h1 * ghost_mask[:, None]
    return gf, gh


def staleness_metrics(age: jnp.ndarray, node_mask: jnp.ndarray) -> dict:
    m = node_mask > 0
    a = jnp.where(m, age, 0)
    return {
        "mean_age": a.sum() / jnp.maximum(m.sum(), 1),
        "max_age": a.max(),
    }
