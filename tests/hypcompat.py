"""Optional-hypothesis shim.

``from hypcompat import given, settings, st`` gives the real hypothesis API
when the package is installed (see requirements-dev.txt). When it is not,
property tests are individually skipped instead of erroring the whole module
at collection time, so the plain unit tests in the same file still run.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(pip install -r requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Any strategies.<name>(...) call resolves to a placeholder."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()
