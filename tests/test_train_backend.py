"""FedEngine(train_backend=...): aggregation backends on the TRAINING path.

``gather`` is the bit-parity reference the repo's history pins. This file
pins what makes ``segment`` (and, at tiny shapes, ``spmm`` in interpret
mode) a drop-in replacement inside LocalUpdate:

* **per-method parity** — for every registered method family, the segment
  history reproduces gather's tau/flops columns exactly, its comm bytes to
  1% (a near-tie ghost selection may move a row), and its losses to
  float tolerance; tau-gated rounds keep gating on the same rounds (the
  embed-comm increment pattern is the witness);
* **batch-forward parity** — ``gcn_batch_forward`` agrees across backends
  under jit with a *traced* batch (the executors' situation), including
  isolated rows (all-padding neighbor lists) and ragged batches, for both
  the values and the parameter gradients (spmm differentiates through its
  custom VJP);
* **executor parity** — stepwise/fused agree on one device; the
  client-sharded and pod-sharded executors join under the sharded lane's
  8 fake devices, all with ``train_backend="segment"``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FedEngine, SyncScheduler, method_config
from repro.models.gcn import gcn_batch_forward, gcn_init

EXACT_KEYS = ("tau", "flops")
CLOSE_KEYS = ("test_acc", "test_loss")
# ghost selection ranks float importance scores: a backend's different
# summation order can flip a near-tie by ~1e-6 and move a row or two on
# the wire, so byte columns are pinned to 1% rather than bitwise (the
# sync-gating pattern itself stays exact — see the tau-gated test)
COMM_KEYS = ("comm_total", "comm_embed", "wall_clock")

# one method per strategy family — the full registry rides the same
# LocalUpdate, so these pin every code path train_backend touches
METHODS = ("fedais", "fedall", "fedrandom", "fedpns", "fedsage+")

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs >=8 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _run(g, fed, method="fedais", *, rounds=4, m=4, tau0=4, **kw):
    eng = FedEngine(g, fed, method_config(method, tau0=tau0), seed=0,
                    rounds=rounds, clients_per_round=m, eval_every=2, **kw)
    return eng, eng.run()


def _assert_parity(ref, got):
    assert set(ref.history) == set(got.history)
    for k in ref.history:
        if k in CLOSE_KEYS:
            np.testing.assert_allclose(
                np.asarray(got.history[k], np.float64),
                np.asarray(ref.history[k], np.float64),
                rtol=1e-4, atol=1e-6, err_msg=f"history[{k!r}]")
        elif k in COMM_KEYS:
            np.testing.assert_allclose(
                np.asarray(got.history[k], np.float64),
                np.asarray(ref.history[k], np.float64),
                rtol=1e-2, err_msg=f"history[{k!r}]")
        else:
            assert ref.history[k] == got.history[k], f"history[{k!r}] diverged"


def test_engine_rejects_unknown_train_backend(small_fed):
    g, fed = small_fed
    with pytest.raises(ValueError, match="train_backend"):
        FedEngine(g, fed, method_config("fedais"), train_backend="dense")


def test_gather_default_is_bit_inert(small_fed):
    """Passing train_backend='gather' explicitly replays the history of an
    engine that never heard of the argument, bit-for-bit."""
    g, fed = small_fed
    _, base = _run(g, fed)
    _, gat = _run(g, fed, train_backend="gather")
    assert base.history == gat.history
    assert base.final == gat.final


@pytest.mark.parametrize("method", METHODS)
def test_method_parity_segment_vs_gather(small_fed, method):
    """The in-trace bucketed-CSR segment path trains every method family to
    the same discrete trajectory (which clients ran, which rounds synced,
    what it cost) with losses allclose — summation order is the only
    difference."""
    g, fed = small_fed
    _, ref = _run(g, fed, method)
    _, seg = _run(g, fed, method, train_backend="segment")
    _assert_parity(ref, seg)


def test_tau_gated_rounds_stay_gated_under_segment(small_fed):
    """tau0=8 gates the embedding sync off on some rounds; the backend swap
    must not change WHICH rounds sync. The witness is the increment pattern
    of the cumulative embed-comm column — exact byte counts may move by a
    near-tie ghost row, and once one flips the two trajectories genuinely
    diverge (this shape does flip one), so the pins here are the discrete
    skeleton and convergence, not the mid-run float path."""
    g, fed = small_fed
    _, ref = _run(g, fed, rounds=6, tau0=8)
    _, seg = _run(g, fed, rounds=6, tau0=8, train_backend="segment")

    def synced(res):
        c = np.asarray(res.history["comm_embed"], np.float64)
        return (np.diff(np.concatenate([[0.0], c])) > 0).tolist()

    assert synced(ref) == synced(seg)
    assert ref.history["tau"] == seg.history["tau"]
    np.testing.assert_allclose(
        np.asarray(seg.history["comm_embed"], np.float64),
        np.asarray(ref.history["comm_embed"], np.float64), rtol=1e-2)
    assert np.isfinite(seg.history["test_loss"]).all()
    assert abs(seg.final["acc"] - ref.final["acc"]) < 0.05


def test_stepwise_matches_fused_under_segment(small_fed):
    g, fed = small_fed
    _, step = _run(g, fed, train_backend="segment",
                   scheduler=SyncScheduler(fused=False))
    _, fused = _run(g, fed, train_backend="segment",
                    scheduler=SyncScheduler(fused=None))
    _assert_parity(step, fused)


def test_spmm_train_backend_tiny():
    """spmm rides the Pallas kernel (interpret mode off-TPU — slow, so the
    federation is tiny): discrete columns exact vs gather, losses allclose."""
    from repro.federated.partition import partition_graph
    from repro.graph.data import make_dataset

    g = make_dataset("pubmed", scale=16, seed=0)
    fed = partition_graph(g, 4, alpha=0.5, seed=0)
    _, ref = _run(g, fed, rounds=2, m=2)
    _, spm = _run(g, fed, rounds=2, m=2, train_backend="spmm")
    _assert_parity(ref, spm)


# ---------------------------------------------------------------------------
# gcn_batch_forward: value + gradient parity under jit with traced batches
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def batch_case():
    """A synthetic padded batch with the awkward rows: isolated nodes
    (all-padding neighbor lists), duplicate neighbor slots, ghost reads,
    and a ragged (non-power-of-two) batch."""
    rng = np.random.default_rng(11)
    n, g_, k, f = 21, 6, 5, 12
    params = gcn_init(jax.random.PRNGKey(2), f, 3, hidden=(8, 4))
    feats = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    ghost = jnp.asarray(rng.standard_normal((g_, f)).astype(np.float32))
    hist1 = jnp.asarray(rng.standard_normal((n + g_, 8)).astype(np.float32))
    idx = rng.integers(0, n + g_, (n, k)).astype(np.int32)
    idx[3] = idx[3, 0]                                   # duplicate slots
    mask = (rng.random((n, k)) < 0.6).astype(np.float32)
    mask[[0, 7]] = 0.0                                   # isolated rows
    batch = jnp.asarray(np.array([0, 3, 5, 7, 8, 13, 20], np.int32))
    return params, feats, ghost, hist1, jnp.asarray(idx), jnp.asarray(mask), batch


@pytest.mark.parametrize("backend", ["segment", "spmm"])
def test_batch_forward_backend_parity(batch_case, backend):
    params, feats, ghost, hist1, idx, mask, batch = batch_case

    from functools import partial

    @partial(jax.jit, static_argnums=0)
    def fwd(be, b):
        return gcn_batch_forward(params, feats, ghost, hist1, idx[b], mask[b],
                                 b, backend=be, interpret=True)

    want = fwd("gather", batch)
    got = fwd(backend, batch)
    for w, g_ in zip(want, got):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(w),
                                   atol=1e-5, rtol=1e-5)
    # isolated rows aggregate to exactly zero -> identical self-only output
    assert np.array_equal(np.asarray(got[0])[0], np.asarray(want[0])[0])


@pytest.mark.parametrize("backend", ["segment", "spmm"])
def test_batch_forward_grad_parity(batch_case, backend):
    """Parameter gradients through the backend forward match gather — the
    spmm case exercises the kernel's custom VJP (raw autodiff through the
    Pallas interpreter is not defined)."""
    params, feats, ghost, hist1, idx, mask, batch = batch_case
    labels = jnp.asarray(np.arange(len(batch)) % 3)

    def loss(p, be):
        logits, _, _ = gcn_batch_forward(p, feats, ghost, hist1, idx[batch],
                                         mask[batch], batch, backend=be,
                                         interpret=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    want = jax.grad(loss)(params, "gather")
    got = jax.grad(loss)(params, backend)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, rtol=1e-4, err_msg=k)


# ---------------------------------------------------------------------------
# multi-device executors (sharded lane)
# ---------------------------------------------------------------------------

@pytest.mark.sharded
@needs_devices
def test_executor_parity_under_segment(small_fed):
    """fused vs client-sharded vs pod-sharded, all with
    train_backend='segment': the executors shard WHO computes, the backend
    changes HOW a batch aggregates — they must compose without moving the
    discrete trajectory."""
    from repro.sharding.fed import make_client_mesh
    from repro.sharding.tables import make_pod_mesh

    g, fed = small_fed
    eng_f, res_f = _run(g, fed, train_backend="segment")
    eng_c, res_c = _run(g, fed, train_backend="segment",
                        mesh=make_client_mesh(8))
    eng_p, res_p = _run(g, fed, train_backend="segment",
                        mesh=make_pod_mesh(4, 2))
    assert eng_f.last_executor == "fused"
    assert eng_c.last_executor == "sharded_fused"
    assert eng_p.last_executor == "pod_sharded"
    _assert_parity(res_f, res_c)
    _assert_parity(res_f, res_p)
