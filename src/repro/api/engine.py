"""FedEngine: the composable federated training engine (Algorithm 1).

The engine owns only the method-agnostic spine of a round:

    select clients -> strategy hooks -> vmapped LocalUpdate -> aggregate
    -> historical write-back -> cost accounting -> callbacks

Everything method- or policy-specific is a pluggable component (see
repro.api.protocols / strategies / callbacks / registry). The per-client
LocalUpdate is jit-compiled once per MethodConfig and vmapped over the m
selected clients, so one round = one XLA call; the cross-client ghost pull
inside lowers to a gather over the stacked client axis (on a TPU mesh this
is the all-to-all of the real deployment — see launch/fed_dryrun.py).

``repro.federated.simulator.run_federated`` is a thin compatibility shim
over ``FedEngine(...).run()`` and is proven history-identical to the legacy
monolith by tests/test_api.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.callbacks import RoundContext, default_callbacks
from repro.api.protocols import (
    AdaptiveSyncController,
    PaperCostModel,
    UniformSelector,
)
from repro.api.registry import (
    build_aggregator,
    build_scheduler,
    build_strategy,
    method_config,
)
from repro.core.fedais import MethodConfig, batch_size_for, make_local_update
from repro.core.historical import init_historical
from repro.federated.costs import CostMeter, DelayModel
from repro.federated.partition import FederatedGraph
from repro.federated.server import build_eval_graph, evaluate_global
from repro.graph.data import GraphData
from repro.models.gcn import HIDDEN, gcn_flops_per_node, gcn_init, gcn_param_count

_CLIENT_ARRAY_KEYS = (
    "features", "labels", "node_mask", "train_mask",
    "nbr_idx", "nbr_mask", "ghost_owner", "ghost_row", "ghost_mask",
)


@dataclass
class RunResult:
    method: str
    dataset: str
    history: dict = field(default_factory=dict)     # per-round lists
    final: dict = field(default_factory=dict)
    costs: CostMeter = field(default_factory=CostMeter)

    def record(self, **kv):
        for k, v in kv.items():
            self.history.setdefault(k, []).append(v)

    def rounds_to_acc(self, target: float) -> int | None:
        for i, a in enumerate(self.history.get("test_acc", [])):
            if a >= target:
                return i + 1
        return None

    def comm_to_acc(self, target: float) -> float | None:
        for a, c in zip(self.history.get("test_acc", []), self.history.get("comm_total", [])):
            if a >= target:
                return c
        return None


@dataclass
class EngineState:
    """Everything mutable across rounds; components read/write this."""

    rng: np.random.Generator          # host RNG (client selection, ...)
    key: jnp.ndarray                  # device PRNG chain
    params: Any                       # global model pytree
    hist: Any                         # HistoricalState (hist1/age tables)
    ghost_feat: jnp.ndarray           # (K, g_max, F) synced/imputed ghosts
    prev_loss: jnp.ndarray            # (K, n_max) last-seen per-node loss
    arrays: dict                      # device-resident stacked client arrays
    result: RunResult
    tau: int = 1                      # current sync interval
    initial_loss: Optional[float] = None
    round: int = 0
    last_eval: Optional[tuple] = None  # (round, metrics) from EvalCallback


def _client_slice(arrays: dict, ids: np.ndarray) -> dict:
    return {k: v[ids] for k, v in arrays.items()}


class FedEngine:
    """Composable federated trainer over a partitioned graph.

    ``method`` is a registered method name (see repro.api.registry) or an
    explicit MethodConfig. Any pluggable component can be overridden via
    keyword; the defaults reproduce the paper's Algorithm 1 exactly.
    """

    def __init__(
        self,
        graph: GraphData,
        fed: FederatedGraph,
        method: Union[str, MethodConfig],
        *,
        rounds: int = 30,
        clients_per_round: int = 10,
        seed: int = 0,
        target_acc: float | None = None,
        delay: DelayModel = DelayModel(),
        eval_every: int = 1,
        verbose: bool = False,
        selector=None,
        aggregator=None,
        sync=None,
        cost_model=None,
        strategy=None,
        scheduler=None,
        callbacks: Optional[Sequence] = None,
    ):
        self.graph, self.fed = graph, fed
        self.mcfg = method_config(method) if isinstance(method, str) else method
        self.rounds = rounds
        self.clients_per_round = clients_per_round
        self.seed = seed

        # ---- pluggable components ----
        self.strategy = strategy if strategy is not None else build_strategy(self.mcfg)
        self.selector = selector if selector is not None else UniformSelector()
        if aggregator is None:
            aggregator = build_aggregator(self.mcfg.aggregator)
        elif isinstance(aggregator, str):   # registry key, e.g. "weighted"
            aggregator = build_aggregator(aggregator)
        self.aggregator = aggregator
        self.sync = sync if sync is not None else AdaptiveSyncController()
        if cost_model is None:
            cost_model = PaperCostModel(delay)
        elif delay != DelayModel():
            # same fail-fast contract as the callbacks/knobs conflict below
            raise ValueError("`delay` only configures the default "
                             "PaperCostModel; give your explicit cost_model "
                             "its own delay instead")
        self.cost_model = cost_model
        if scheduler is None:
            scheduler = self.mcfg.scheduler     # registry key, "sync" default
        if isinstance(scheduler, str):
            scheduler = build_scheduler(scheduler)
        self.scheduler = scheduler
        if callbacks is None:
            self.callbacks = default_callbacks(eval_every=eval_every, verbose=verbose,
                                               target_acc=target_acc)
        else:
            # an explicit callback stack replaces the default one wholesale;
            # the convenience knobs only parameterize the default stack
            if eval_every != 1 or verbose or target_acc is not None:
                raise ValueError(
                    "eval_every/verbose/target_acc only configure the default "
                    "callback stack; with an explicit `callbacks` list, drop "
                    "them and add EvalCallback/VerboseCallback/"
                    "EarlyStopCallback to your list instead")
            self.callbacks = list(callbacks)

        # ---- static geometry + compiled LocalUpdate ----
        self.F, self.H1 = fed.n_features, HIDDEN[0]
        self.n_params = gcn_param_count(self.F, fed.n_classes)
        avg_deg = float(fed.nbr_mask.sum() / np.maximum(fed.node_mask.sum(), 1))
        self.fwd_flops_node = gcn_flops_per_node(self.F, fed.n_classes, avg_deg)
        self.bsz = batch_size_for(self.mcfg, fed.n_max)
        local_update = make_local_update(self.mcfg, fed.n_max, fed.g_max, self.H1)
        self._vm = jax.jit(jax.vmap(
            local_update,
            in_axes=(None, 0, None, None, 0, 0, 0, 0, None, 0, None, 0)))
        self.eval_graph = build_eval_graph(graph, max_deg=fed.max_deg, seed=seed)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def init_state(self) -> EngineState:
        fed, seed = self.fed, self.seed
        K, n_max, g_max, F = fed.n_clients, fed.n_max, fed.g_max, self.F
        arrays = {k: jnp.asarray(getattr(fed, k)) for k in _CLIENT_ARRAY_KEYS}
        state = EngineState(
            rng=np.random.default_rng(seed),
            key=jax.random.PRNGKey(seed),
            params=gcn_init(jax.random.PRNGKey(seed + 1), F, fed.n_classes),
            hist=init_historical(K, n_max, g_max, F, self.H1),
            ghost_feat=jnp.zeros((K, g_max, F), jnp.float32),
            prev_loss=jnp.full((K, n_max), -1.0, jnp.float32),
            arrays=arrays,
            result=RunResult(method=self.mcfg.name, dataset=self.graph.name),
            tau=self.sync.initial(self.mcfg),
        )
        self.strategy.setup(self, state)
        return state

    def dispatch(self, state: EngineState, sel: np.ndarray, t: int):
        """Client half of a round: RNG split, strategy hooks, vmapped
        LocalUpdate for the cohort ``sel`` departing from server version
        ``t`` (the global batch-epoch offset). Returns the stacked outputs
        ``(params, hist1, age, ghost_feat, stats)``."""
        state.round = t
        sel_j = jnp.asarray(sel)
        state.key, *ks = jax.random.split(state.key, len(sel) + 1)
        keys = jnp.stack(ks)

        fanouts = self.strategy.choose_fanouts(self, sel)
        self.strategy.pre_round(self, state, sel)

        client_data = _client_slice(state.arrays, sel)
        return self._vm(
            state.params, client_data, state.arrays["features"], state.hist.hist1,
            state.hist.hist1[sel_j], state.hist.age[sel_j], state.ghost_feat[sel_j],
            state.prev_loss[sel_j], jnp.asarray(state.tau, jnp.int32), fanouts,
            jnp.asarray(t * self.mcfg.local_epochs, jnp.int32), keys,
        )

    def merge(self, state: EngineState, t: int, sel: np.ndarray, out,
              *, staleness: np.ndarray | None = None, aggregator=None,
              wall_clock_s: float | None = None,
              virtual_time: float | None = None) -> bool:
        """Server half of a round ``t``: aggregation, historical write-back,
        cost accounting, strategy/callback hooks. Async schedulers pass the
        per-update ``staleness`` (for discounted weights), a staleness-aware
        ``aggregator``, and the virtual-clock ``wall_clock_s`` actually
        waited (overriding the lockstep max(compute)+sync billing). Returns
        True if a callback requested stop."""
        state.round = t
        sel_j = jnp.asarray(sel)
        new_params_stack, new_hist1, new_age, new_ghost_feat, stats = out

        agg = self.aggregator if aggregator is None else aggregator
        weights = jnp.asarray(self.fed.client_sizes[sel], jnp.float32)
        if staleness is None:
            state.params = agg.aggregate(new_params_stack, weights)
        else:
            state.params = agg.aggregate(new_params_stack, weights, staleness)

        # A client can be merged twice in one buffer (re-selected while its
        # previous update was still in flight): every update aggregates, but
        # the client-state write-back keeps only the freshest entry (``sel``
        # arrives sorted by dispatch version, so the last occurrence wins).
        if len(np.unique(sel)) != len(sel):
            _, last_rev = np.unique(np.asarray(sel)[::-1], return_index=True)
            w = np.sort(len(sel) - 1 - last_rev)
            sel_j = jnp.asarray(np.asarray(sel)[w])
            new_hist1, new_age = new_hist1[w], new_age[w]
            new_ghost_feat, loss_all = new_ghost_feat[w], stats["loss_all"][w]
        else:
            loss_all = stats["loss_all"]
        state.hist = state.hist._replace(
            hist1=state.hist.hist1.at[sel_j].set(new_hist1),
            age=state.hist.age.at[sel_j].set(new_age),
        )
        state.ghost_feat = state.ghost_feat.at[sel_j].set(new_ghost_feat)
        state.prev_loss = state.prev_loss.at[sel_j].set(loss_all)

        cost = self.cost_model.round_cost(self, state, sel, stats)
        if wall_clock_s is not None:
            cost.wall_clock_s = wall_clock_s    # overlapped (virtual-clock) billing
        state.result.costs.add(cost)
        self.strategy.post_round(self, state, sel, stats)

        ctx = RoundContext(engine=self, state=state, t=t, rounds=self.rounds,
                           virtual_time=virtual_time, staleness=staleness)
        for cb in self.callbacks:
            cb.on_round_end(ctx)
        return ctx.stop

    def run_round(self, state: EngineState, t: int) -> bool:
        """One lockstep federated round; True if a callback requested stop."""
        state.round = t
        sel = self.selector.select(self, state)
        out = self.dispatch(state, sel, t)
        return self.merge(state, t, sel, out)

    def run(self, state: EngineState | None = None) -> RunResult:
        if state is None:
            state = self.init_state()
        for cb in self.callbacks:
            cb.on_run_start(self, state)
        self.scheduler.run(self, state)
        if state.last_eval is not None and state.last_eval[0] == state.round:
            # EvalCallback already scored this round's (unchanged) params;
            # don't pay for the same server eval twice
            final_eval = state.last_eval[1]
        else:
            final_eval = evaluate_global(state.params, self.eval_graph, "test")
        state.result.final = dict(final_eval, **state.result.costs.snapshot())
        for cb in self.callbacks:
            cb.on_run_end(self, state)
        return state.result
