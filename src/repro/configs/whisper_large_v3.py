"""whisper-large-v3 [audio] — 32L d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866, encoder-decoder with conv frontend STUB (input_specs provides
precomputed mel/conv frame embeddings, per the assignment carve-out).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-large-v3",
        family="audio",
        n_layers=32,                 # decoder layers
        n_encoder_layers=32,
        encoder_seq_len=1500,        # stub frame embeddings (B, 1500, d)
        d_model=1280,
        n_heads=20,                  # MHA: kv = heads
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        source="arXiv:2212.04356",
        block_pattern=("dec",),
        pos_embedding="learned",
        activation="gelu",
        gated_mlp=False,
        max_seq_len=32768,           # assignment decode shape exceeds whisper's 448; backbone supports it
    )


def smoke() -> ModelConfig:
    return smoke_variant(config(), n_kv_heads=4)


register("whisper-large-v3", config, smoke)
