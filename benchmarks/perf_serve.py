"""Serving-latency benchmark: the train -> checkpoint -> serve pipeline.

Wraps ``repro.launch.serve_fed.run_pipeline`` (the CI serve-smoke entry
point): trains a small federation, restores it into the warm-cache serving
stack, drives mixed query/update traffic through both arrival disciplines,
and reports the latency ledger as benchmark rows. The open-loop run writes
the schema-guarded ``BENCH_serve.json`` at the repo root (the serving perf
trajectory); the closed-loop run only reports rows.

    PYTHONPATH=src python -m benchmarks.run --only perf_serve
"""
from __future__ import annotations


def _row(payload: dict, variant: str) -> dict:
    return {
        "variant": variant,
        "mode": payload["mode"],
        "backend": payload["backend"],
        "n_queries": payload["n_queries"],
        "n_updates": payload["n_updates"],
        "queries_per_s": payload["queries_per_s"],
        "p50_ms": payload["p50_ms"],
        "p99_ms": payload["p99_ms"],
        "batch_occupancy": payload["batch_occupancy"],
        "cache_hit_rate": payload["cache_hit_rate"],
        "rows_refreshed": payload["rows_refreshed"],
    }


def run(quick: bool = True) -> list[dict]:
    import os
    import tempfile

    from repro.launch.serve_fed import build_args, run_pipeline

    rows = []
    # one training run, one checkpoint dir, two serving disciplines
    ckpt_dir = tempfile.mkdtemp(prefix="perf_serve_ckpt_")
    for mode in ("open", "closed"):
        argv = ["--mode", mode, "--ckpt-dir", ckpt_dir]
        if quick:
            argv.append("--quick")
        if mode == "closed":
            # the open-loop payload is the canonical BENCH_serve.json;
            # keep the closed-loop one out of the trajectory file
            argv += ["--out", os.path.join(tempfile.gettempdir(),
                                           "BENCH_serve_closed.json")]
        payload = run_pipeline(build_args(argv))
        rows.append(_row(payload, f"serve_{mode}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit_csv, save_rows

    rows = run(quick=True)
    emit_csv("perf_serve", rows)
    save_rows("perf_serve", rows)
