"""Padded neighbor-list representation (the TPU-friendly adjacency form).

PyG-style ragged CSR is replaced by fixed-shape (n, max_deg) index/mask
arrays — jit-stable shapes, gathers vectorise, and the Pallas SpMM kernel
consumes the same structure (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def build_padded_neighbors(
    adj: list[list[int]],
    max_deg: int | None = None,
    *,
    cap: int = 64,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """adjacency lists -> (nbr_idx (n, K) int32, nbr_mask (n, K) float32).

    Nodes with more than K neighbors get a uniform random subset (the paper
    caps sampled neighbors at 10 anyway); padding rows point at 0 with mask 0.
    """
    rng = np.random.default_rng(seed)
    n = len(adj)
    if max_deg is None:
        max_deg = min(cap, max((len(a) for a in adj), default=1) or 1)
    idx = np.zeros((n, max_deg), np.int32)
    mask = np.zeros((n, max_deg), np.float32)
    for i, nbrs in enumerate(adj):
        if not nbrs:
            continue
        if len(nbrs) > max_deg:
            # sort the subsample so slot order (hence csr_from_padded's edge
            # order) is canonical for a given (adj, seed) — rng.choice
            # returns draw order, which would leak into every downstream
            # summation order
            nbrs = np.sort(rng.choice(nbrs, size=max_deg, replace=False))
        idx[i, : len(nbrs)] = nbrs
        mask[i, : len(nbrs)] = 1.0
    return idx, mask


def csr_from_padded(nbr_idx: np.ndarray, nbr_mask: np.ndarray) -> dict:
    """Flatten a padded (n, K) neighbor list into CSR-style edge arrays.

    Returns ``{"src": (E,) int32, "dst": (E,) int32, "inv_deg": (n,) float32}``
    holding only the E real edges (mask > 0), ordered row-major (dst
    non-decreasing, slots in list order). This is the ``segment_sum``
    aggregation form: a mean-aggregate becomes
    ``segment_sum(table[src], dst, n) * inv_deg[:, None]`` — no padded
    ``(n, K, d)`` gather is ever materialized, and E excludes every padding
    slot the dense form pays for.
    """
    idx = np.asarray(nbr_idx)
    real = np.asarray(nbr_mask) > 0
    dst, slot = np.nonzero(real)
    deg = real.sum(-1)
    return {
        "src": idx[dst, slot].astype(np.int32),
        "dst": dst.astype(np.int32),
        "inv_deg": (1.0 / np.maximum(deg, 1)).astype(np.float32),
    }


def bucketed_csr_from_padded(nbr_idx, nbr_mask) -> dict:
    """Jit-stable bucketed CSR: every (row, slot) pair becomes an edge slot.

    Returns ``{"src": (E_cap,) int32, "dst": (E_cap,) int32,
    "inv_deg": (n,) float32}`` with ``E_cap = n * K`` — a fixed shape that
    depends only on the padded neighbor arrays, so it can be built *inside*
    a traced computation from traced batch rows (the training hot path,
    where ``csr_from_padded``'s dynamic E would break jit). Padding slots
    route to an overflow segment ``n`` (src clamped to 0), so a
    mean-aggregate is ``segment_sum(table[src], dst, n + 1)[:n]
    * inv_deg[:, None]``.

    Real edges keep ``csr_from_padded``'s row-major slot order: filtering
    the bucketed arrays to ``dst < n`` reproduces its ``src``/``dst``
    exactly (pinned by tests/test_csr.py), so per-segment summation order
    — hence the float sums — match the packed form bit for bit.
    """
    idx = jnp.asarray(nbr_idx)
    mask = jnp.asarray(nbr_mask)
    n, k = idx.shape
    real = mask > 0
    src = jnp.where(real, idx, 0).reshape(-1).astype(jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    dst = jnp.where(real, rows, n).reshape(-1).astype(jnp.int32)
    deg = real.sum(-1)
    return {
        "src": src,
        "dst": dst,
        "inv_deg": (1.0 / jnp.maximum(deg, 1)).astype(jnp.float32),
    }


def degree_stats(mask: np.ndarray) -> dict:
    deg = mask.sum(-1)
    return {
        "mean": float(deg.mean()),
        "max": float(deg.max()),
        "isolated_frac": float((deg == 0).mean()),
    }
