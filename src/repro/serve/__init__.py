"""repro.serve: checkpoint-backed online inference for the federated GCN.

``ServedModel`` restores a ``save_federation`` checkpoint (params + the
(K, n_tot, H1) historical tables) into a device-resident warm embedding
cache; ``QueryEngine`` answers micro-batched node-classification queries
over it at pre-jitted bucket shapes; ``GraphStore`` absorbs streaming graph
updates with exact 1-hop cache invalidation; ``LoadGenerator`` drives the
stack with seeded synthetic traffic and emits the schema-guarded
``BENCH_serve.json`` latency ledger. Entry point: ``launch/serve_fed.py``.
"""
from repro.serve.engine import CACHE_POLICIES, DEFAULT_BUCKETS, QueryEngine
from repro.serve.loadgen import (
    LOAD_MODES,
    LatencyLedger,
    LoadGenerator,
    validate_bench_serve,
)
from repro.serve.model import (
    SERVE_BACKENDS,
    WARM_MODES,
    ServedModel,
    federation_template,
    federation_tree,
    save_federation,
)
from repro.serve.updates import CapacityError, GraphStore

__all__ = [
    "CACHE_POLICIES",
    "DEFAULT_BUCKETS",
    "LOAD_MODES",
    "SERVE_BACKENDS",
    "WARM_MODES",
    "CapacityError",
    "GraphStore",
    "LatencyLedger",
    "LoadGenerator",
    "QueryEngine",
    "ServedModel",
    "federation_template",
    "federation_tree",
    "save_federation",
    "validate_bench_serve",
]
