"""Pod-sharded historical tables: the second unit of federated scale-out.

Client sharding (repro.sharding.fed) splits each round's cohort across
devices but still replicates the (K, n_tot, H1) ``hist1``/``age`` tables —
and the (K, g_max, F) synced-ghost and (K, n_max) prev-loss tables — on
every device, and re-broadcasts them at every chunk boundary. That is the
cross-client communication/memory wall FedGCN-style systems hit first: per
-device table memory and write-back traffic both scale with the TOTAL
client count K, not with the work a round actually does.

This module shards the tables themselves over a ``("pods", "clients")``
2-D mesh: pod p owns the table rows of its resident clients (the K axis
block-partitioned over the ``"pods"`` axis with ``NamedSharding``), while
each round's cohort still splits over all P×C devices. Three exchanges
replace the replicated-table dataflow, sized by what the round touches
rather than by K:

* **ghost-bucket all-to-all** — the cross-pod embedding synchronization.
  ``pull_ghosts`` cannot gather from a replicated ``hist1_all`` snapshot
  any more, so each round starts with a ``jax.lax.all_to_all`` over
  partition-time send/recv buckets (``federated.partition.
  ghost_exchange_buckets``): pod p sends pod q exactly the deduplicated
  owner rows q's residents reference as ghosts. Bytes scale with the
  ghost-edge cut — the quantity FedAIS's adaptive sync bounds — not with
  K·n_tot·H1.
* **owner-keyed cohort fetch** — the m selected clients' own table rows
  are pulled from their owner pods by a masked psum (each row has exactly
  one non-zero contributor), O(m·n_tot) bytes.
* **cohort write-back** — fresh rows all-gather across the cohort axis
  (O(m·n_tot), K-independent) and each pod scatters only the rows it owns
  (out-of-range ids drop, so dummies and non-residents never land).

Aggregation stays the weighted psum all-reduce of the client-sharded
executor, with an optional ``reduce="pairwise"`` mode that gathers the
per-device partial sums and reduces them in a fixed fp32 binary tree —
deterministic summation order for when all-reduce reassociation drift
matters at depth.

Parity contract (tests/test_pod_sharding.py): history is allclose to the
client-sharded and unsharded fused runs with every discrete column exact —
the per-client computation is identical (``pull_ghosts_prefetched`` hands
each client the same round-start snapshot rows), only the merge's summation
order differs.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.federated.partition import GhostBuckets, pod_table_padding
from repro.sharding.fed import CLIENT_AXIS

POD_AXIS = "pods"


def make_pod_mesh(n_pods: int, n_client_shards: Optional[int] = None) -> Mesh:
    """A ``(n_pods, n_client_shards)`` mesh with ``("pods", "clients")``
    axes: tables shard over the first, each round's cohort over both. With
    ``n_client_shards=None`` all visible devices are used (they must split
    evenly). On CPU, force fake devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devs = jax.devices()
    if n_pods < 1:
        raise ValueError(f"need n_pods >= 1, got {n_pods}")
    if n_client_shards is None:
        if len(devs) % n_pods:
            raise ValueError(
                f"{len(devs)} devices do not split into {n_pods} pods; pass "
                "n_client_shards explicitly")
        n_client_shards = len(devs) // n_pods
    n = n_pods * n_client_shards
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_pod_mesh needs 1..{len(devs)} devices, asked for "
            f"{n_pods}x{n_client_shards} (force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n_pods, n_client_shards), (POD_AXIS, CLIENT_AXIS),
                         devices=devs[:n])


def pod_axes_of(mesh: Mesh) -> Optional[tuple[str, str]]:
    """The (table, cohort) axis pair of a pod mesh: ``("pods", "clients")``
    when both axes are present, else None (not a pod mesh)."""
    if POD_AXIS in mesh.shape and CLIENT_AXIS in mesh.shape:
        return (POD_AXIS, CLIENT_AXIS)
    return None


def pad_tables_to_pods(tables, n_pods: int):
    """Pad each (K, ...) table with zero rows so K splits evenly over the
    pod axis. Returns the padded tuple (no-op when already divisible)."""
    K = tables[0].shape[0]
    pad = pod_table_padding(K, n_pods)      # the bucket builder's Kp rule
    if not pad:
        return tuple(tables)
    return tuple(
        jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1)) for t in tables)


def shard_tables_to_mesh(tables, mesh: Mesh):
    """Commit each (Kp, ...) table to the mesh sharded over the pod axis on
    its leading (client) dimension — pod p holds its residents' rows,
    replicated across the ``"clients"`` axis."""
    sh = NamedSharding(mesh, P(POD_AXIS))
    return tuple(jax.device_put(t, sh) for t in tables)


def pairwise_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic fp32 binary-tree reduction over the leading axis:
    pairs sum left-to-right level by level, so the association order is
    fixed by the leading-axis length alone (never by how XLA schedules an
    all-reduce). Used by ``reduce="pairwise"`` merges."""
    while x.shape[0] > 1:
        n = x.shape[0]
        even = (n // 2) * 2
        y = x[0:even:2] + x[1:even:2]
        if n % 2:
            y = jnp.concatenate([y, x[even:]], axis=0)
        x = y
    return x[0]


def _pod_step(vm, mesh: Mesh, buckets: GhostBuckets, reduce: str):
    """The per-round client half over a ``("pods", "clients")`` mesh: ghost
    all-to-all, owner-keyed cohort fetch, vmapped LocalUpdate on each
    device's cohort slice, weighted merge, and the pod-local write-back.
    Table in/out specs are P("pods"); cohort specs P(("pods", "clients"))."""
    P_, C = mesh.shape[POD_AXIS], mesh.shape[CLIENT_AXIS]
    rpp = buckets.rows_per_pod
    axes = (POD_AXIS, CLIENT_AXIS)

    def step(params, client, feats_all, hist_sh, age_sh, gfeat_sh, pl_sh,
             sel, tau, fanouts, eoff, keys, w,
             send_client, send_row, send_mask, recv_src, recv_pos, recv_mask):
        p_i = jax.lax.axis_index(POD_AXIS)
        c_i = jax.lax.axis_index(CLIENT_AXIS)
        mL = keys.shape[0]

        # ---- ghost-bucket all-to-all: round-start hist1 rows cross pods ----
        # send_* arrive (1, P, B) — this pod's row of the (P, P, B) plan
        sc, sr, sm = send_client[0], send_row[0], send_mask[0]
        sbuf = hist_sh[sc, sr] * sm[..., None]                  # (P, B, H1)
        rbuf = jax.lax.all_to_all(sbuf, POD_AXIS, 0, 0, tiled=True)
        # reassemble my residents' ghost-source rows from the received buckets
        gh_res = rbuf[recv_src, recv_pos] * recv_mask[..., None]  # (rpp, g, H1)

        # ---- owner-keyed fetch of the cohort's table rows ----
        # exactly one (pod, clients=0) device contributes each row; the psum
        # broadcasts it (ints stay exact, floats gain only +0.0 terms)
        owner_pod = sel // rpp                 # padded dummies (id Kp) -> P_
        local_row = jnp.clip(sel - owner_pod * rpp, 0, rpp - 1)
        own = (owner_pod == p_i) & (c_i == 0)

        def fetch(tbl):
            rows = jnp.where(own.reshape((-1,) + (1,) * (tbl.ndim - 1)),
                             tbl[local_row], 0)
            return jax.lax.psum(rows, axes)

        d = p_i * C + c_i

        def chunk_of(tbl):
            return jax.lax.dynamic_slice_in_dim(fetch(tbl), d * mL, mL, 0)

        hist_l = chunk_of(hist_sh)
        age_l = chunk_of(age_sh)
        gfeat_l = chunk_of(gfeat_sh)
        pl_l = chunk_of(pl_sh)
        ghs_l = chunk_of(gh_res)               # (mL, g_max, H1) ghost sources

        # layer-0 ghost features: local gather on the replicated features
        # (same clamped indices pull_ghosts would use)
        owner = jnp.maximum(client["ghost_owner"], 0)
        gfs_l = feats_all[owner, client["ghost_row"]]     # (mL, g_max, F)

        out = vm(params, client, gfs_l, ghs_l, hist_l, age_l, gfeat_l, pl_l,
                 tau, fanouts, eoff, keys)
        new_params, new_hist1, new_age, new_gfeat, stats = out

        # ---- aggregation: weighted all-reduce, or fp32 pairwise tree ----
        if reduce == "psum":
            wsum = jax.lax.psum(w.sum(), axes)

            def wmean(x):
                wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
                return jax.lax.psum((x * wb).sum(axis=0), axes) / wsum
        else:   # "pairwise": association fixed by device count, not by XLA
            wsum = pairwise_sum(jax.lax.all_gather(w.sum(), axes))

            def wmean(x):
                wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
                part = jax.lax.all_gather((x * wb).sum(axis=0), axes, axis=0)
                return pairwise_sum(part) / wsum

        agg = jax.tree_util.tree_map(wmean, new_params)

        # ---- write-back: cohort all-gather + pod-local scatter ----
        # fresh rows cross the mesh once (O(m * n_tot), K-independent); each
        # pod then scatters only its residents — non-owned and dummy rows
        # get an out-of-range target and the scatter drops them
        def gather_cohort(x):
            return jax.lax.all_gather(x, axes, axis=0, tiled=True)

        tgt = jnp.where(owner_pod == p_i, sel - p_i * rpp, rpp)
        hist_sh = hist_sh.at[tgt].set(gather_cohort(new_hist1))
        age_sh = age_sh.at[tgt].set(gather_cohort(new_age))
        gfeat_sh = gfeat_sh.at[tgt].set(gather_cohort(new_gfeat))
        pl_sh = pl_sh.at[tgt].set(gather_cohort(stats["loss_all"]))
        return agg, hist_sh, age_sh, gfeat_sh, pl_sh, stats

    t, c, r = P(POD_AXIS), P(axes), P()
    return shard_map(
        step, mesh=mesh,
        in_specs=(r, c, r, t, t, t, t, r, r, c, r, c, c, t, t, t, t, t, t),
        out_specs=(r, t, t, t, t, c),
        check_rep=False)


def build_pod_sharded_chunk(vm, mesh: Mesh, m_real: int,
                            buckets: GhostBuckets,
                            light_stats: Sequence[str], *,
                            reduce: str = "psum"):
    """The pod-sharded twin of ``sharding.fed.build_sharded_chunk``: one
    jitted donated chunk scanning ``round_step`` over S rounds with the
    historical tables resident as pod shards.

    Same argument order as the client-sharded chunk; the four table
    arguments arrive padded to ``buckets.n_clients_padded`` rows and
    committed to the mesh with ``P("pods")`` shardings
    (``pad_tables_to_pods`` + ``shard_tables_to_mesh``). ``vm`` must be the
    ``ghost_source="prefetched"`` vmapped LocalUpdate. Cohort padding uses
    dummy id ``n_clients_padded`` (fully out of range of the padded tables,
    so fetches are zero and write-backs drop). ``reduce`` picks the merge:
    ``"psum"`` (weighted all-reduce) or ``"pairwise"`` (fp32 tree)."""
    if reduce not in ("psum", "pairwise"):
        raise ValueError(f"unknown reduce {reduce!r}; known: psum | pairwise")
    step = _pod_step(vm, mesh, buckets, reduce)
    light_stats = tuple(light_stats)
    bkt = tuple(jnp.asarray(a) for a in (
        buckets.send_client, buckets.send_row, buckets.send_mask,
        buckets.recv_src, buckets.recv_pos, buckets.recv_mask))

    def chunk(params, hist1, age, ghost_feat, prev_loss, key, arrays,
              sel_stack, fan_stack, w_stack, eoffs, tau):
        m_pad = sel_stack.shape[1]
        pad = m_pad - m_real

        def round_step(carry, xs):
            params, hist1, age, ghost_feat, prev_loss, key = carry
            sel, fanouts, w, eoff = xs
            # the unsharded executor's exact key chain: split for the real
            # cohort only, dummies ride along on a constant zero key
            ks = jax.random.split(key, m_real + 1)
            key, keys = ks[0], ks[1:]
            if pad:
                keys = jnp.concatenate(
                    [keys, jnp.zeros((pad,) + keys.shape[1:], keys.dtype)])
            client = {k: v[sel] for k, v in arrays.items()}
            out = step(params, client, arrays["features"], hist1, age,
                       ghost_feat, prev_loss, sel, tau, fanouts, eoff, keys,
                       w, *bkt)
            params, hist1, age, ghost_feat, prev_loss, stats = out
            light = {k: stats[k][:m_real] for k in light_stats}
            return (params, hist1, age, ghost_feat, prev_loss, key), light

        return jax.lax.scan(round_step,
                            (params, hist1, age, ghost_feat, prev_loss, key),
                            (sel_stack, fan_stack, w_stack, eoffs))

    return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4, 5))


def abstract_pod_chunk_args(mesh: Mesh, buckets: GhostBuckets, *,
                            n_clients: int, cohort: int, n_max: int,
                            g_max: int, n_feat: int, n_classes: int,
                            max_deg: int = 16, rounds: int = 1):
    """ShapeDtypeStructs matching ``build_pod_sharded_chunk``'s signature —
    ``sharding.fed.abstract_chunk_args`` (same argument order, same client
    arrays) with the four table leaves re-struck: padded to
    ``buckets.n_clients_padded`` rows and carrying ``P("pods")``
    NamedShardings. The ``--pods`` dry-run path."""
    from repro.models.gcn import HIDDEN

    from repro.sharding.fed import abstract_chunk_args

    base = list(abstract_chunk_args(
        mesh, n_clients=n_clients, cohort=cohort, n_max=n_max, g_max=g_max,
        n_feat=n_feat, n_classes=n_classes, max_deg=max_deg, rounds=rounds))
    t = NamedSharding(mesh, P(POD_AXIS))
    Kp, n_tot = buckets.n_clients_padded, n_max + g_max
    base[1] = jax.ShapeDtypeStruct((Kp, n_tot, HIDDEN[0]), jnp.float32,
                                   sharding=t)           # hist1
    base[2] = jax.ShapeDtypeStruct((Kp, n_tot), jnp.int32, sharding=t)  # age
    base[3] = jax.ShapeDtypeStruct((Kp, g_max, n_feat), jnp.float32,
                                   sharding=t)           # ghost features
    base[4] = jax.ShapeDtypeStruct((Kp, n_max), jnp.float32,
                                   sharding=t)           # prev loss
    return tuple(base)
