from repro.kernels.spmm.ops import block_spmm

__all__ = ["block_spmm"]
