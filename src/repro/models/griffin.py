"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Recurrence: a_t = a^(c*r_t) with a = sigmoid(Lambda) (diagonal, in (0,1)),
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t). Diagonal linear
recurrence -> jax.lax.associative_scan for the full-sequence path (log-depth,
TPU-friendly), O(1)-state single step for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def rglru_block_init(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    dt = cfg.jnp_dtype
    ks = iter(jax.random.split(key, 10))
    nx = lambda a, b: dense_init(next(ks), a, b, dt)
    # Lambda init so that a = sigmoid(Lambda) in approx (0.9, 0.999)
    lam_u = jax.random.uniform(next(ks), (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(lam_u / (1 - lam_u))
    return {
        "ln": rmsnorm_init(d, dt),
        "w_rec_in": nx(d, w),          # recurrent branch input proj
        "w_gate_in": nx(d, w),         # multiplicative (gelu) branch
        "conv_w": (jax.random.normal(next(ks), (cfg.conv1d_width, w), jnp.float32) * 0.02).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "lam": lam,
        "w_a": nx(w, w), "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": nx(w, w), "b_i": jnp.zeros((w,), jnp.float32),
        "w_out": nx(w, d),
    }


def _causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B,T,W), w: (K,W)."""
    K = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (K - 1 - i, 0), (0, 0)))[:, : x.shape[1]] for i in range(K)]
    out = sum(p * w[i].astype(x.dtype) for i, p in enumerate(pads))
    return out + b.astype(x.dtype)


def _rglru_gates(p, cfg, x):
    """x: (..., W) conv output -> (log_a, scaled input) both fp32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(x32 @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -cfg.rglru_c * r * jax.nn.softplus(p["lam"])       # log sigmoid(lam)^(c r)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    return a, gated


def rglru_scan(a, b, h0=None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    a, b: (B, T, W) fp32. Returns (h (B,T,W), final state (B,W)).
    """
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_block_apply(p, cfg, x):
    """Full-sequence Griffin recurrent block. x: (B,T,d)."""
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    rec = h @ p["w_rec_in"]
    rec = _causal_conv1d(rec, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(p, cfg, rec)
    y, _ = rglru_scan(a, b)
    gate = jax.nn.gelu(h @ p["w_gate_in"])
    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    return x + out


def rglru_init_state(cfg, batch: int) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), cfg.jnp_dtype),
    }


def rglru_block_decode(p, cfg, x, state):
    """x: (B,1,d) -> (out, new state)."""
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    rec = h @ p["w_rec_in"]                                     # (B,1,W)
    window = jnp.concatenate([state["conv"], rec], axis=1)      # (B,K,W)
    K = p["conv_w"].shape[0]
    conv_out = (
        jnp.einsum("bkw,kw->bw", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )[:, None]
    a, b = _rglru_gates(p, cfg, conv_out)
    hnew = a[:, 0] * state["h"] + b[:, 0]
    gate = jax.nn.gelu(h @ p["w_gate_in"])
    out = (hnew[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return x + out, {"h": hnew, "conv": window[:, 1:]}
