"""Method strategies: the per-method round hooks the legacy loop hid behind
``if mcfg.use_generator:`` / ``if mcfg.bandit_fanout:`` branches.

A MethodStrategy owns all method-specific mutable state (FedSage+ generator
parameters, FedGraph bandit tables) and exposes four round hooks plus two
cost hooks, so the FedEngine round loop and the PaperCostModel stay
branch-free. New methods subclass MethodStrategy, register a kind with
``register_strategy_kind``, then register a method name in
repro.api.registry pointing at that kind.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated import baselines as B
from repro.federated.costs import model_bytes

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.engine import EngineState, FedEngine
    from repro.core.fedais import MethodConfig


class MethodStrategy:
    """Default (plain) strategy: fixed fanout, no extra state or cost."""

    def __init__(self, mcfg: "MethodConfig"):
        self.mcfg = mcfg

    def setup(self, engine: "FedEngine", state: "EngineState") -> None:
        """Allocate method-specific state before round 0."""

    def choose_fanouts(self, engine: "FedEngine", sel: np.ndarray) -> jnp.ndarray:
        """Per-selected-client neighbor fanout for this round."""
        return jnp.full((len(sel),), self.mcfg.neighbor_fanout, jnp.int32)

    def pre_round(self, engine: "FedEngine", state: "EngineState",
                  sel: np.ndarray) -> None:
        """Before the vmapped LocalUpdate (e.g. ghost-feature imputation)."""

    def post_round(self, engine: "FedEngine", state: "EngineState",
                   sel: np.ndarray, stats: dict) -> None:
        """After merge (e.g. bandit reward updates)."""

    # ---- cost hooks (consumed by PaperCostModel) ----

    def round_model_bytes(self, engine: "FedEngine") -> float:
        """Extra per-client model-channel bytes (rides the up/down-link)."""
        return 0.0

    def extra_flops(self, engine: "FedEngine", client_size):
        """Extra per-client compute on top of the GCN fwd+bwd. ``client_size``
        may be a scalar or an int ndarray over the cohort (the vectorized
        cost model passes the whole selection at once); implementations must
        be elementwise arithmetic."""
        return 0.0


class GeneratorStrategy(MethodStrategy):
    """FedSage+ lite: a locally trained generator imputes ghost features, so
    no embedding sync happens; generator params ride the model link."""

    def setup(self, engine, state):
        self.gen_params = B.generator_init(
            jax.random.PRNGKey(engine.seed + 2), engine.F)
        rev_np, rev_mask_np = B.ghost_reverse_map(engine.fed)
        self.rev, self.rev_mask = jnp.asarray(rev_np), jnp.asarray(rev_mask_np)

    def pre_round(self, engine, state, sel):
        arrays = state.arrays
        K, n_max, F = engine.fed.n_clients, engine.fed.n_max, engine.F
        self.gen_params, _gen_loss = B.generator_train_step(
            self.gen_params,
            arrays["features"].reshape(K * n_max, F),
            jnp.minimum(arrays["nbr_idx"].reshape(K * n_max, -1), n_max * K - 1),
            arrays["nbr_mask"].reshape(K * n_max, -1)
            * (arrays["nbr_idx"].reshape(K * n_max, -1) < n_max),
            arrays["node_mask"].reshape(K * n_max),
        )
        imputed = jax.vmap(B.generator_impute, in_axes=(None, 0, 0, 0, 0))(
            self.gen_params, arrays["features"], self.rev, self.rev_mask,
            arrays["ghost_mask"])
        state.ghost_feat = imputed

    def round_model_bytes(self, engine):
        return 2 * model_bytes(B.generator_param_count(engine.F))

    def extra_flops(self, engine, client_size):
        return 6.0 * engine.F * 64 * client_size


class BanditStrategy(MethodStrategy):
    """FedGraph lite: per-client epsilon-greedy bandit over fanout actions,
    rewarded by the round-over-round local-loss improvement.

    Reward attribution assumes a client's updates are observed in dispatch
    order. Synchronous merges guarantee that (one update per client per
    round). Async merges restack each buffer by (dispatch version, cohort
    position), so a client selected twice while in flight rewards oldest ->
    freshest within the merge — matching the engine write-back's
    dedup-keeps-freshest rule — but a straggler can still arrive in a LATER
    merge than a fresher update it departed before. Such out-of-order
    arrivals are skipped: their "improvement" would be measured against a
    loss the bandit already advanced past, inverting the reward's sign.
    ``state.last_staleness`` carries the per-update staleness the async
    merge observed (None on sync paths, where every update is this
    round's and the skip can never fire — legacy rewards bit-for-bit).
    """

    def setup(self, engine, state):
        self.bandit = B.FanoutBandit(engine.fed.n_clients, seed=engine.seed)
        self.last_client_loss = np.zeros(engine.fed.n_clients)
        # dispatch version of each client's last rewarded update
        self.last_reward_version = np.full(engine.fed.n_clients, -1, np.int64)

    def choose_fanouts(self, engine, sel):
        return jnp.asarray([self.bandit.choose(int(k)) for k in sel], jnp.int32)

    def post_round(self, engine, state, sel, stats):
        mean_losses = np.asarray(stats["epoch_losses"]).mean(axis=1)
        staleness = state.last_staleness
        if staleness is None:               # sync: every update is this round's
            versions = np.full(len(sel), state.round, np.int64)
        else:
            versions = state.round - np.asarray(staleness, np.int64)
        for i, k in enumerate(sel):
            v = int(versions[i])
            if v < self.last_reward_version[k]:
                continue    # stale straggler ordered after a fresher update
            reward = (self.last_client_loss[k] - float(mean_losses[i])
                      if self.last_client_loss[k] else 0.0)
            self.bandit.update(int(k), reward)
            self.last_client_loss[k] = float(mean_losses[i])
            self.last_reward_version[k] = v


# ---------------------------------------------------------------------------
# strategy-kind registry
# ---------------------------------------------------------------------------

STRATEGY_KINDS: dict[str, type] = {
    "plain": MethodStrategy,
    "generator": GeneratorStrategy,
    "bandit": BanditStrategy,
}


def register_strategy_kind(kind: str, cls: type, *, overwrite: bool = False) -> type:
    """Register a MethodStrategy subclass under a string kind (idempotent
    for the same class; raises on silent overwrite unless ``overwrite``)."""
    existing = STRATEGY_KINDS.get(kind)
    if existing is not None and existing is not cls and not overwrite:
        raise KeyError(f"strategy kind {kind!r} already registered to {existing!r}")
    STRATEGY_KINDS[kind] = cls
    return cls


def strategy_kind_for(mcfg: "MethodConfig") -> str:
    """Resolve a config to a strategy kind: the explicit ``mcfg.strategy``
    wins; ``'auto'`` infers from the legacy feature flags (this is the ONLY
    place those flags are branched on — never in the round loop)."""
    kind = getattr(mcfg, "strategy", "auto") or "auto"
    if kind != "auto":
        return kind
    if mcfg.use_generator:
        return "generator"
    if mcfg.bandit_fanout:
        return "bandit"
    return "plain"


def build_strategy(mcfg: "MethodConfig") -> MethodStrategy:
    kind = strategy_kind_for(mcfg)
    try:
        cls = STRATEGY_KINDS[kind]
    except KeyError:
        raise KeyError(
            f"unknown strategy kind {kind!r}; known: {sorted(STRATEGY_KINDS)}"
        ) from None
    return cls(mcfg)
