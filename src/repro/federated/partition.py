"""Intra-graph federated partition: split one global graph across K clients,
extract cross-client ("ghost") edges, and build fixed-shape per-client arrays
stackable over a leading client axis (vmap/shard_map-ready).

Layout per client k (padded to the max over clients):
    features   (n_max, F)     own node features (rows >= n_k zero)
    labels     (n_max,)
    node_mask  (n_max,)       1 for real own nodes
    train_mask (n_max,)
    nbr_idx    (n_max, K)     neighbor slots; values < n_max index own rows,
                              values >= n_max index ghost slot (v - n_max)
    nbr_mask   (n_max, K)
    ghost_owner (g_max,)      owning client id (-1 pad)
    ghost_row   (g_max,)      row index within the owner's local arrays
    ghost_mask  (g_max,)

The combined embedding table a client sees is [own rows | ghost rows] of
size n_max + g_max — exactly the paper's Eq. (6) split into within-client
in-batch / within-client out-of-batch / cross-client terms.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.data import GraphData


def pod_table_padding(n_clients: int, n_pods: int) -> int:
    """Dummy client rows appended so the K-sized historical tables split
    evenly across ``n_pods`` pod shards (rows ``>= n_clients`` stay zero and
    are never selected or referenced by ghost buckets)."""
    return (-n_clients) % n_pods


@dataclass
class GhostBuckets:
    """Partition-time routing plan for the cross-pod ghost exchange.

    When the historical tables shard their client (K) axis over a pod mesh
    axis, ``pull_ghosts`` can no longer gather from a replicated
    ``hist1_all`` — each ghost's layer-1 source row lives only on the pod
    that owns that client. The exchange becomes a bucketed all-to-all: pod
    ``p`` sends, for every destination pod ``q``, the (deduplicated) table
    rows that ``q``'s resident clients reference as ghosts; ``q``
    reassembles its residents' (g_max,) ghost-source rows from the received
    buckets. The buckets depend only on the partition's ghost topology
    (``ghost_owner``/``ghost_row``/``ghost_mask``) and the pod count, so
    they are built once here on the host and baked into the compiled chunk
    as constants.

    Shapes (P = n_pods, B = bucket_size, Kp = padded client count):
        send_client (P, P, B)  row index within the SOURCE pod's table shard
        send_row    (P, P, B)  row within the owner's (n_tot,) table (< n_max)
        send_mask   (P, P, B)  1 for real entries, 0 for bucket padding
        recv_src    (Kp, g_max) source pod of each resident ghost slot
        recv_pos    (Kp, g_max) position within that pod's received bucket
        recv_mask   (Kp, g_max) ghost_mask of real residents, 0 on padding

    ``send_*[p, q]`` is what pod p sends to pod q; after the all-to-all,
    pod q's receive buffer slot p holds exactly those rows, and
    ``recv_*[k]`` (k resident on q) indexes into it.
    """

    n_pods: int
    rows_per_pod: int       # padded K / n_pods
    bucket_size: int        # B: max entries over all (src, dst) pod pairs
    n_entries: int          # total real (deduplicated) bucket entries
    send_client: np.ndarray
    send_row: np.ndarray
    send_mask: np.ndarray
    recv_src: np.ndarray
    recv_pos: np.ndarray
    recv_mask: np.ndarray

    @property
    def n_clients_padded(self) -> int:
        return self.n_pods * self.rows_per_pod


def ghost_exchange_buckets(
    ghost_owner: np.ndarray,    # (K, g_max) owning client id (-1 pad)
    ghost_row: np.ndarray,      # (K, g_max) row within the owner's arrays
    ghost_mask: np.ndarray,     # (K, g_max)
    n_pods: int,
) -> GhostBuckets:
    """Build the per-pod send/recv index buckets for the ghost all-to-all.

    Clients are block-assigned to pods by id: pod p owns rows
    ``[p * rows_per_pod, (p + 1) * rows_per_pod)`` of the padded table.
    Every (owner, row) source pair needed by some resident of pod q appears
    exactly once in the owner pod's send bucket for q (duplicates across
    residents of the same pod deduplicate; the same source row needed by
    residents of DIFFERENT pods is sent once per destination).
    """
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    K, g_max = ghost_owner.shape
    pad = pod_table_padding(K, n_pods)
    Kp = K + pad
    rpp = Kp // n_pods

    # (src, dst) -> {(owner, row): bucket position}; dicts keep insertion
    # order, so bucket layout is deterministic for a given partition
    buckets: list[list[dict]] = [[{} for _ in range(n_pods)]
                                 for _ in range(n_pods)]
    recv_src = np.zeros((Kp, g_max), np.int32)
    recv_pos = np.zeros((Kp, g_max), np.int32)
    recv_mask = np.zeros((Kp, g_max), np.float32)
    for k in range(K):
        q = k // rpp
        for s in range(g_max):
            if ghost_mask[k, s] <= 0:
                continue
            o, r = int(ghost_owner[k, s]), int(ghost_row[k, s])
            p = o // rpp
            d = buckets[p][q]
            pos = d.setdefault((o, r), len(d))
            recv_src[k, s] = p
            recv_pos[k, s] = pos
            recv_mask[k, s] = 1.0

    n_entries = sum(len(d) for row in buckets for d in row)
    B = max(1, max(len(d) for row in buckets for d in row))
    send_client = np.zeros((n_pods, n_pods, B), np.int32)
    send_row = np.zeros((n_pods, n_pods, B), np.int32)
    send_mask = np.zeros((n_pods, n_pods, B), np.float32)
    for p in range(n_pods):
        for q in range(n_pods):
            for (o, r), pos in buckets[p][q].items():
                send_client[p, q, pos] = o - p * rpp
                send_row[p, q, pos] = r
                send_mask[p, q, pos] = 1.0
    return GhostBuckets(
        n_pods=n_pods, rows_per_pod=rpp, bucket_size=B, n_entries=n_entries,
        send_client=send_client, send_row=send_row, send_mask=send_mask,
        recv_src=recv_src, recv_pos=recv_pos, recv_mask=recv_mask,
    )


def simulate_ghost_exchange(buckets: GhostBuckets,
                            hist1_all: np.ndarray) -> np.ndarray:
    """Host-side (numpy) reference of the on-device exchange: build every
    pod's send buffers from its table shard, swap them all-to-all, and
    reassemble per-resident ghost-source rows. Returns (Kp, g_max, H1) —
    row [k, s] is ``hist1_all[ghost_owner[k, s], ghost_row[k, s]]`` for
    every real ghost slot and 0 elsewhere. The property tests pin this
    against ``core.historical.pull_ghosts``; ``sharding.tables`` runs the
    same dataflow with ``jax.lax.all_to_all``."""
    P, B = buckets.n_pods, buckets.bucket_size
    rpp, Kp = buckets.rows_per_pod, buckets.n_clients_padded
    K, n_tot, H1 = hist1_all.shape
    shards = np.zeros((P, rpp, n_tot, H1), hist1_all.dtype)
    shards.reshape(Kp, n_tot, H1)[:K] = hist1_all
    # send: sbuf[p, q] = the rows pod p sends to pod q
    sbuf = (shards[np.arange(P)[:, None, None],
                   buckets.send_client, buckets.send_row]
            * buckets.send_mask[..., None])
    # all-to-all: pod q's receive slot p holds what pod p addressed to q
    rbuf = np.swapaxes(sbuf, 0, 1)          # rbuf[q, p] = sbuf[p, q]
    pod = np.arange(Kp) // rpp
    out = (rbuf[pod[:, None], buckets.recv_src, buckets.recv_pos]
           * buckets.recv_mask[..., None])
    return out


def exchange_ghost_features(buckets: GhostBuckets,
                            features: np.ndarray, *,
                            dtype: str = "fp32") -> np.ndarray:
    """Bucketed owner exchange of the layer-0 ghost features (host, once per
    partition): the same send/recv routing as the hist1 all-to-all applied
    to the static (K, n_max, F) feature shards, so each pod fills its
    residents' (g_max, F) ghost-source rows purely from received buckets —
    no pod ever reads a replicated features array. Returns (Kp, g_max, F):
    row [k, s] is ``features[ghost_owner[k, s], ghost_row[k, s]]`` for every
    real ghost slot and 0 elsewhere (exactly the gf half of
    ``core.historical.pull_ghosts``). Ghost sources are always owner OWN
    rows (< n_max), so the hist-table routing indexes features directly.

    ``dtype`` quantizes the exchanged rows through the repro.federated.quant
    codec (this exchange IS the wire for ghost features in the pod-sharded
    executor). The round-trip runs through the same jax codec the in-trace
    ghost pull uses, so the prefetched rows match the ``"tables"``-mode
    pull's decode bit-for-bit (per-row codec commutes with the row gather).
    """
    out = simulate_ghost_exchange(buckets, features).astype(np.float32)
    if dtype != "fp32":
        from repro.federated.quant import quant_roundtrip
        import jax.numpy as jnp
        out = np.asarray(quant_roundtrip(jnp.asarray(out), dtype))
    return out


@dataclass
class WriteBackPlan:
    """Host-built per-chunk routing for the cohort-keyed write-back exchange.

    After a round, each device holds fresh table rows for its cohort slice;
    the owner pods need them. The dense path all-gathers every cohort row to
    every device (m rows each, K-independent but cohort-dense). This plan
    shrinks it to a two-stage exchange sized by what each pod PAIR actually
    routes: stage 1 all-gathers the cohort slice within a pod row (m/P
    rows), stage 2 scatters those rows into per-destination-pod send
    buckets and swaps them with one ``all_to_all`` over the pod axis
    (``cap`` rows per pod pair, ``cap`` ≈ m/P² in expectation).

    Built on the host per chunk from the selected cohorts alone (the
    sel_stack is host-known before the chunk launches), baked in as scan
    inputs. Shapes (S = rounds, m = padded cohort, P = pods):
        dst (S, m)           owner pod of each cohort entry (P for dummies —
                             the send-bucket scatter drops them)
        pos (S, m)           slot within the (src pod, dst pod) send bucket
        recv (S, P, P, cap)  recv[s, q, p, j]: destination-local table row
                             of the j-th entry pod p sent pod q (sentinel
                             ``rows_per_pod`` on unused slots — the table
                             scatter drops them)

    ``cap`` is the max (src, dst) bucket occupancy rounded up to a power of
    two, so nearby cohort distributions reuse one compiled chunk shape.
    Cohorts are assumed duplicate-free per round (sync selectors sample
    without replacement), matching the dense path's scatter semantics.
    """

    n_pods: int
    n_client_shards: int
    rows_per_pod: int
    cap: int
    max_occupancy: int      # real max bucket fill before pow2 rounding
    dst: np.ndarray
    pos: np.ndarray
    recv: np.ndarray


def writeback_routing(sel_stack: np.ndarray, n_pods: int,
                      n_client_shards: int, rows_per_pod: int,
                      *, cap: int | None = None) -> WriteBackPlan:
    """Route a chunk's (S, m) padded cohort ids into write-back buckets.

    Cohort entry i of round s lives on device ``i // mL`` (mL = m/(P·C));
    after the stage-1 intra-pod all-gather, pod row p holds cohort slice
    ``[p·C·mL, (p+1)·C·mL)`` in device order — so the source pod of entry i
    is ``i // (C·mL)``. The owner pod is ``sel // rows_per_pod``; ids >=
    ``n_pods * rows_per_pod`` (cohort dummies) get the sentinel destination
    ``n_pods``. Positions count up per (src, dst) pair in cohort order, so
    the exchange is deterministic for a given sel_stack."""
    sel_stack = np.asarray(sel_stack)
    S, m = sel_stack.shape
    n_dev = n_pods * n_client_shards
    if m % n_dev:
        raise ValueError(f"padded cohort {m} does not split over "
                         f"{n_pods}x{n_client_shards} devices")
    msl = m // n_pods                       # pod-row cohort slice
    Kp = n_pods * rows_per_pod
    dst = np.full((S, m), n_pods, np.int32)
    pos = np.zeros((S, m), np.int32)
    occ = np.zeros((S, n_pods, n_pods), np.int64)
    src = np.arange(m) // msl
    for s in range(S):
        for i in range(m):
            k = int(sel_stack[s, i])
            if not 0 <= k < Kp:
                continue                    # dummy: sentinel dst drops it
            q = k // rows_per_pod
            dst[s, i] = q
            pos[s, i] = occ[s, src[i], q]
            occ[s, src[i], q] += 1
    max_occ = int(occ.max(initial=0))
    need = max(1, max_occ)
    if cap is None:
        cap = 1 << (need - 1).bit_length()  # pow2: bounded retrace shapes
    elif cap < need:
        raise ValueError(f"cap {cap} < max bucket occupancy {need}")
    recv = np.full((S, n_pods, n_pods, cap), rows_per_pod, np.int32)
    for s in range(S):
        for i in range(m):
            q = int(dst[s, i])
            if q >= n_pods:
                continue
            recv[s, q, src[i], pos[s, i]] = \
                int(sel_stack[s, i]) - q * rows_per_pod
    return WriteBackPlan(
        n_pods=n_pods, n_client_shards=n_client_shards,
        rows_per_pod=rows_per_pod, cap=int(cap), max_occupancy=max_occ,
        dst=dst, pos=pos, recv=recv)


def simulate_writeback_exchange(plan: WriteBackPlan, s: int,
                                values: np.ndarray,
                                table: np.ndarray) -> np.ndarray:
    """Host-side (numpy) reference of round ``s``'s on-device write-back:
    scatter the cohort's fresh rows into per-pod send buckets, swap them
    all-to-all, and scatter each pod's received rows into its table shard.
    ``values`` is the round's (m, ...) fresh rows in cohort order, ``table``
    the (Kp, ...) padded table; returns the updated copy. The property
    tests pin this bit-for-bit against the dense scatter
    ``table[sel[i]] = values[i]`` for every real cohort id."""
    P, rpp, cap = plan.n_pods, plan.rows_per_pod, plan.cap
    m = values.shape[0]
    sbuf = np.zeros((P, P, cap) + values.shape[1:], values.dtype)
    src = np.arange(m) // (m // P)
    for i in range(m):
        q = int(plan.dst[s, i])
        if q < P:
            sbuf[src[i], q, plan.pos[s, i]] = values[i]
    rbuf = np.swapaxes(sbuf, 0, 1)          # rbuf[q, p] = sbuf[p, q]
    out = np.array(table)
    for q in range(P):
        for p in range(P):
            for j in range(cap):
                r = int(plan.recv[s, q, p, j])
                if r < rpp:
                    out[q * rpp + r] = rbuf[q, p, j]
    return out


@dataclass
class FederatedGraph:
    """All K clients stacked on a leading axis (numpy; moved to jax later)."""

    name: str
    n_clients: int
    n_max: int
    g_max: int
    max_deg: int
    features: np.ndarray     # (K, n_max, F)
    labels: np.ndarray       # (K, n_max)
    node_mask: np.ndarray    # (K, n_max)
    train_mask: np.ndarray   # (K, n_max)
    val_mask: np.ndarray     # (K, n_max)
    nbr_idx: np.ndarray      # (K, n_max, D)
    nbr_mask: np.ndarray     # (K, n_max, D)
    ghost_owner: np.ndarray  # (K, g_max)
    ghost_row: np.ndarray    # (K, g_max)
    ghost_mask: np.ndarray   # (K, g_max)
    global_ids: np.ndarray   # (K, n_max) original node id (-1 pad)
    n_classes: int
    n_cross_edges: int       # Table-1 style ΔE diagnostic

    @property
    def n_features(self) -> int:
        return self.features.shape[2]

    @property
    def client_sizes(self) -> np.ndarray:
        return self.node_mask.sum(axis=1).astype(np.int32)


def partition_graph(
    graph: GraphData,
    n_clients: int,
    *,
    alpha: float | None = None,   # None -> iid, else Dirichlet(alpha) non-iid
    max_deg: int = 32,
    edge_keep: float = 0.5,       # paper: 50% local-subgraph edge downsampling
    seed: int = 0,
) -> FederatedGraph:
    rng = np.random.default_rng(seed)
    n = graph.n_nodes
    c = graph.n_classes

    # ---- assign nodes to clients ----
    assign = np.empty(n, np.int64)
    if alpha is None:
        assign[:] = rng.integers(0, n_clients, size=n)
    else:
        # Dirichlet per class: p_i ~ Dir_K(alpha); class-i nodes split by p_i
        for cls in range(c):
            ids = np.where(graph.labels == cls)[0]
            rng.shuffle(ids)
            p = rng.dirichlet(np.full(n_clients, alpha))
            counts = rng.multinomial(len(ids), p)
            assign[ids] = np.repeat(np.arange(n_clients), counts)

    client_nodes = [np.where(assign == k)[0] for k in range(n_clients)]
    n_max = max(1, max(len(v) for v in client_nodes))
    local_of = np.full(n, -1, np.int64)
    for k, ids in enumerate(client_nodes):
        local_of[ids] = np.arange(len(ids))

    # ---- split edges, downsample within-client edges ----
    e = graph.edges
    same = assign[e[:, 0]] == assign[e[:, 1]]
    within = e[same]
    cross = e[~same]
    if edge_keep < 1.0 and len(within):
        within = within[rng.random(len(within)) < edge_keep]

    # ---- per-client adjacency over [own | ghost] rows ----
    F = graph.n_features
    feats = np.zeros((n_clients, n_max, F), np.float32)
    labels = np.zeros((n_clients, n_max), np.int32)
    node_mask = np.zeros((n_clients, n_max), np.float32)
    train_mask = np.zeros((n_clients, n_max), np.float32)
    val_mask = np.zeros((n_clients, n_max), np.float32)
    global_ids = np.full((n_clients, n_max), -1, np.int32)

    adj = [[[] for _ in range(n_max)] for _ in range(n_clients)]
    ghosts: list[dict[int, int]] = [dict() for _ in range(n_clients)]  # global id -> slot

    def ghost_slot(k: int, gid: int) -> int:
        d = ghosts[k]
        if gid not in d:
            d[gid] = len(d)
        return d[gid]

    for u, v in within:
        k = assign[u]
        adj[k][local_of[u]].append(int(local_of[v]))
        adj[k][local_of[v]].append(int(local_of[u]))
    for u, v in cross:
        ku, kv = assign[u], assign[v]
        adj[ku][local_of[u]].append(n_max + ghost_slot(ku, int(v)))
        adj[kv][local_of[v]].append(n_max + ghost_slot(kv, int(u)))

    g_max = max(1, max(len(d) for d in ghosts))
    ghost_owner = np.full((n_clients, g_max), -1, np.int32)
    ghost_row = np.zeros((n_clients, g_max), np.int32)
    ghost_mask = np.zeros((n_clients, g_max), np.float32)

    nbr_idx = np.zeros((n_clients, n_max, max_deg), np.int32)
    nbr_mask = np.zeros((n_clients, n_max, max_deg), np.float32)

    for k in range(n_clients):
        ids = client_nodes[k]
        nk = len(ids)
        if nk:
            feats[k, :nk] = graph.features[ids]
            labels[k, :nk] = graph.labels[ids]
            node_mask[k, :nk] = 1.0
            train_mask[k, :nk] = graph.train_mask[ids]
            val_mask[k, :nk] = graph.val_mask[ids]
            global_ids[k, :nk] = ids
        for gid, slot in ghosts[k].items():
            ghost_owner[k, slot] = assign[gid]
            ghost_row[k, slot] = local_of[gid]
            ghost_mask[k, slot] = 1.0
        for i in range(nk):
            nbrs = adj[k][i]
            if not nbrs:
                continue
            if len(nbrs) > max_deg:
                nbrs = list(rng.choice(nbrs, size=max_deg, replace=False))
            nbr_idx[k, i, : len(nbrs)] = nbrs
            nbr_mask[k, i, : len(nbrs)] = 1.0

    return FederatedGraph(
        name=graph.name, n_clients=n_clients, n_max=n_max, g_max=g_max,
        max_deg=max_deg, features=feats, labels=labels, node_mask=node_mask,
        train_mask=train_mask, val_mask=val_mask, nbr_idx=nbr_idx,
        nbr_mask=nbr_mask, ghost_owner=ghost_owner, ghost_row=ghost_row,
        ghost_mask=ghost_mask, global_ids=global_ids, n_classes=graph.n_classes,
        n_cross_edges=int(len(cross)),
    )
