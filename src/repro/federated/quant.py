"""Quantized wire format for historical-embedding exchanges.

Every float payload the federation moves — the ghost hist1 all-to-all,
the prebuilt ghost-source feature exchange, the cohort-keyed write-back
bucket exchange, and the serving ``h1`` cache — can ride one of three
wire dtypes:

* ``"fp32"`` — bit-inert passthrough. ``encode``/``decode`` return their
  input unchanged at the Python level (no trace ops), so an engine built
  with ``sync_dtype="fp32"`` lowers to the byte-identical jaxpr it did
  before this module existed.
* ``"bf16"`` — truncate to bfloat16 on the wire, widen back to fp32 at
  the receiver. 2x byte cut, ~3 decimal digits of mantissa.
* ``"int8"`` — per-row symmetric quantization over the LAST axis:
  ``scale = amax / 127`` per row, codes rounded half-to-even and clipped
  to [-127, 127], decoded as ``code * scale``. ~4x byte cut on wide rows
  (one fp32 scale rides per row). All-zero rows produce scale 0 and
  decode to exact zeros, so 0/1 mask multiplies commute with the codec.

Merge accumulators stay fp32 everywhere: quantization happens on table
rows at the exchange boundary, never inside the parameter all-reduce.

The int8 round-trip is idempotent in its codes: re-encoding a decoded
row reproduces the same int8 codes exactly (the max-magnitude element
decodes to ``127 * scale``, whose re-derived scale differs from the
original by at most 1 ulp — far below the 0.5 rounding threshold on
integer codes). Executors that round-trip at the semantic site and
additionally quantize a physical collective therefore agree to ~1 ulp.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "SYNC_DTYPES",
    "check_sync_dtype",
    "decode",
    "encode",
    "quant_roundtrip",
    "wire_bytes",
]

SYNC_DTYPES = ("fp32", "bf16", "int8")

# bytes per element on the wire (int8 additionally pays 4 B/row of scale)
_ELEM_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def check_sync_dtype(dtype):
    """Validate a wire dtype string (returns it for chaining)."""
    if dtype not in SYNC_DTYPES:
        raise ValueError(
            f"sync dtype must be one of {SYNC_DTYPES}, got {dtype!r}")
    return dtype


def encode(x, dtype):
    """Encode fp32 ``x`` for the wire -> ``(payload, scale_or_None)``.

    ``scale`` is a fp32 array of shape ``x.shape[:-1] + (1,)`` for int8
    and ``None`` otherwise. For fp32 this is the identity (no trace ops).
    """
    check_sync_dtype(dtype)
    if dtype == "fp32":
        return x, None
    if dtype == "bf16":
        return x.astype(jnp.bfloat16), None
    # degenerate shapes stay exact: a 0-d payload is its own (single) row,
    # and a zero-width last axis reduces with initial=0 instead of erroring
    if jnp.ndim(x) == 0:
        amax = jnp.abs(x)
    else:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True, initial=0.0)
    scale = (amax / 127.0).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def decode(payload, scale, dtype):
    """Widen a wire payload back to fp32 (identity for fp32)."""
    check_sync_dtype(dtype)
    if dtype == "fp32":
        return payload
    if dtype == "bf16":
        return payload.astype(jnp.float32)
    return payload.astype(jnp.float32) * scale


def quant_roundtrip(x, dtype):
    """``decode(encode(x))`` — the value the receiver sees.

    fp32 returns ``x`` itself (same object, zero trace ops), which is
    what makes ``sync_dtype="fp32"`` bit-inert through jit.
    """
    if dtype == "fp32":
        return x
    payload, scale = encode(x, dtype)
    return decode(payload, scale, dtype)


def wire_bytes(shape, dtype):
    """Bytes a fp32 array of ``shape`` occupies on the wire at ``dtype``.

    int8 charges one fp32 scale per row, where a "row" is what ``encode``
    actually emits a scale for: every leading-axes index (``prod(shape[:-1])``
    — so ``(n, 0)`` still pays its n scales), and a 0-d payload is its own
    single row. Exactness against ``encode``'s output ``nbytes`` — scalar,
    zero-width, 1-D and n-D shapes alike — is pinned by tests/test_quant.py.
    """
    check_sync_dtype(dtype)
    shape = tuple(int(s) for s in shape)
    n = int(np.prod(shape)) if shape else 1
    total = n * _ELEM_BYTES[dtype]
    if dtype == "int8":
        rows = int(np.prod(shape[:-1])) if shape else 1
        total += rows * 4
    return int(total)
