"""Fused round executor + aggregation backends + satellite regressions.

The correctness contract of the fused scanned executor is *bit-identical*
history to the stepwise loop (same selections, same PRNG chain, same FP
results), pinned here for every registered method. Backends are equivalent
within FP tolerance (different summation order). Donation is pinned by
asserting the scanned executor updates the big tables in place instead of
growing live device buffers per chunk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BaseCallback,
    EvalCallback,
    FedEngine,
    HistoryCallback,
    LossBiasedSelector,
    PaperCostModel,
    SyncScheduler,
    WeightedFedAvg,
    build_scheduler,
    method_config,
)
from repro.core.importance import quantize_key, stable_rank
from repro.graph.csr import csr_from_padded
from repro.models.gcn import neighbor_aggregate

PAPER_METHODS = ("fedall", "fedrandom", "fedsage+", "fedpns", "fedgraph",
                 "fedlocal", "fedais1", "fedais2", "fedais")

PARITY_KEYS = ("test_acc", "test_loss", "tau", "comm_total", "comm_embed",
               "flops", "wall_clock")


def _histories(g, fed, method, **kw):
    step = FedEngine(g, fed, method_config(method, tau0=4), seed=0,
                     scheduler=SyncScheduler(fused=False), **kw).run()
    fused = FedEngine(g, fed, method_config(method, tau0=4), seed=0,
                      scheduler=SyncScheduler(fused=None), **kw).run()
    return step, fused


def _assert_bit_parity(step, fused):
    for k in PARITY_KEYS:
        assert step.history[k] == fused.history[k], f"history[{k!r}] diverged"
    assert step.final == fused.final


# ---------------------------------------------------------------------------
# fused vs stepwise history bit-parity
# ---------------------------------------------------------------------------

@pytest.mark.sharded       # the CI multi-device lane re-runs this under 8 devices
def test_fused_matches_stepwise_fedais(small_fed):
    """Fast lane: multi-round chunks (eval_every=2) scan bit-identically."""
    g, fed = small_fed
    step, fused = _histories(g, fed, "fedais", rounds=5, clients_per_round=3,
                             eval_every=2)
    _assert_bit_parity(step, fused)


@pytest.mark.slow
@pytest.mark.parametrize("method", PAPER_METHODS)
def test_fused_matches_stepwise_all_methods(small_fed, method):
    """Every registered method: eligible ones scan, ineligible ones (the
    generator/bandit strategies) fall back — history identical either way."""
    g, fed = small_fed
    step, fused = _histories(g, fed, method, rounds=4, clients_per_round=3,
                             eval_every=2)
    _assert_bit_parity(step, fused)


@pytest.mark.slow
def test_fused_matches_stepwise_weighted_and_early_stop(small_fed):
    g, fed = small_fed
    kw = dict(rounds=6, clients_per_round=3, eval_every=3, target_acc=0.2)
    step = FedEngine(g, fed, method_config("fedais", aggregator="weighted"),
                     seed=2, scheduler=SyncScheduler(fused=False), **kw).run()
    fused = FedEngine(g, fed, method_config("fedais", aggregator="weighted"),
                      seed=2, scheduler=SyncScheduler(fused=True), **kw).run()
    _assert_bit_parity(step, fused)


# ---------------------------------------------------------------------------
# eligibility gating
# ---------------------------------------------------------------------------

def test_fused_eligibility(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1)
    ok, why = eng.fused_eligibility()
    assert ok, why
    assert isinstance(eng.aggregator, object) and eng.aggregator.jit_safe

    # per-round host hooks (generator / bandit strategies) are not fusable
    for method in ("fedsage+", "fedgraph"):
        eng = FedEngine(g, fed, method_config(method), rounds=1)
        ok, why = eng.fused_eligibility()
        assert not ok and "strategy" in why

    # a selector that reads per-round state cannot be precomputed
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    selector=LossBiasedSelector())
    ok, why = eng.fused_eligibility()
    assert not ok and "selector" in why

    # custom callbacks may observe per-round state the fused path defers
    class Spy(BaseCallback):
        pass

    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    callbacks=[EvalCallback(1), HistoryCallback(), Spy()])
    ok, why = eng.fused_eligibility()
    assert not ok and "callback" in why
    # ... unless they declare themselves safe
    spy = Spy()
    spy.fused_safe = True
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    callbacks=[EvalCallback(1), HistoryCallback(), spy])
    assert eng.fused_eligibility()[0]


def test_forced_fused_raises_when_ineligible(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedgraph"),
                    rounds=1, clients_per_round=2,
                    scheduler=SyncScheduler(fused=True))
    with pytest.raises(ValueError, match="fused executor unavailable"):
        eng.run()


def test_scheduler_registry_keys():
    assert build_scheduler("sync").fused is None
    assert build_scheduler("sync_fused").fused is True
    assert build_scheduler("sync_stepwise").fused is False


def test_weighted_fedavg_is_jit_safe():
    assert WeightedFedAvg.jit_safe and PaperCostModel.fused_safe


# ---------------------------------------------------------------------------
# donation: the scanned executor must not grow live device buffers per chunk
# ---------------------------------------------------------------------------

def test_fused_chunk_donates_buffers(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), rounds=12,
                    clients_per_round=3, seed=0)
    state = eng.init_state()
    old_hist1 = state.hist.hist1
    eng._run_chunk(state, 0, 3)     # warmup: compile + weak-type constants
    # the donated input table must have been consumed (updated in place),
    # not copied into a fresh allocation
    assert old_hist1.is_deleted()
    n_live = len(jax.live_arrays())
    for t0 in (3, 6, 9):
        eng._run_chunk(state, t0, 3)
        assert len(jax.live_arrays()) == n_live, \
            f"live device buffers grew after chunk at round {t0}"


# ---------------------------------------------------------------------------
# aggregation backends: gather == segment == spmm within tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d", [(64, 8, 16), (200, 16, 32), (33, 5, 7)])
def test_backend_equivalence_random_padded(n, k, d):
    rng = np.random.default_rng(n + k + d)
    idx = rng.integers(0, n, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.5).astype(np.float32)
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    idx_j, mask_j = jnp.asarray(idx), jnp.asarray(mask)
    want = neighbor_aggregate(table, idx_j, mask_j)                  # gather
    csr = {kk: jnp.asarray(v) for kk, v in csr_from_padded(idx, mask).items()}
    seg = neighbor_aggregate(table, idx_j, mask_j, backend="segment", csr=csr)
    spm = neighbor_aggregate(table, idx_j, mask_j, backend="spmm",
                             interpret=True)
    np.testing.assert_allclose(np.asarray(seg), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(spm), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_segment_derives_csr_in_trace_and_rejects_unknown():
    """``backend="segment"`` with ``csr=None`` no longer raises: the
    jit-stable bucketed CSR is derived in-trace from the padded batch (the
    training hot path) and sums segments in the same slot order as the
    host-precomputed form — bit-identical, and allclose to gather."""
    rng = np.random.default_rng(3)
    idx = rng.integers(0, 24, (24, 5)).astype(np.int32)
    mask = (rng.random((24, 5)) < 0.6).astype(np.float32)
    t = jnp.asarray(rng.standard_normal((24, 6)).astype(np.float32))
    idx_j, mask_j = jnp.asarray(idx), jnp.asarray(mask)
    want = neighbor_aggregate(t, idx_j, mask_j)
    got = jax.jit(
        lambda *a: neighbor_aggregate(*a, backend="segment"))(t, idx_j, mask_j)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
    csr = {k: jnp.asarray(v) for k, v in csr_from_padded(idx, mask).items()}
    pre = neighbor_aggregate(t, idx_j, mask_j, backend="segment", csr=csr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pre))
    with pytest.raises(ValueError, match="unknown aggregation backend"):
        neighbor_aggregate(t, idx_j, mask_j, backend="dense")


def test_eval_backends_agree_on_real_graph(small_fed):
    from repro.federated.server import build_eval_graph, evaluate_global
    from repro.models.gcn import gcn_init

    g, _ = small_fed
    params = gcn_init(jax.random.PRNGKey(3), g.n_features, g.n_classes)
    evs = {be: evaluate_global(params, build_eval_graph(g, backend=be), "test")
           for be in ("gather", "segment", "spmm")}
    for be in ("segment", "spmm"):
        assert evs[be]["acc"] == pytest.approx(evs["gather"]["acc"], abs=1e-3)
        assert evs[be]["loss"] == pytest.approx(evs["gather"]["loss"], rel=1e-4)


def test_engine_eval_backend_plumbs_through(small_fed):
    g, fed = small_fed
    res = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3, seed=0,
                    eval_backend="segment").run()
    assert np.isfinite(res.final["loss"])
    with pytest.raises(ValueError, match="unknown eval backend"):
        FedEngine(g, fed, method_config("fedais"), rounds=1,
                  eval_backend="dense")


# ---------------------------------------------------------------------------
# satellite: single-pass stable top-k fanout ranking
# ---------------------------------------------------------------------------

def test_stable_rank_matches_double_argsort():
    """The old per-epoch ranking was argsort(keys).argsort(); the new path is
    one stable top-k over the same mantissa-quantized keys. Keep-masks must
    be bit-identical for every fanout threshold, ties included."""
    rng = np.random.default_rng(0)
    ranks = rng.random((128, 32)).astype(np.float32)
    ranks[:, 24:] = 2.0                       # masked slots (all tie at 2.0)
    ranks[5, 3] = ranks[5, 9]                 # forced exact tie
    keys = quantize_key(jnp.asarray(ranks))   # shared quantized keys
    old_order = jnp.argsort(keys, axis=-1).argsort(axis=-1)
    new_order = stable_rank(jnp.asarray(ranks))
    np.testing.assert_array_equal(np.asarray(old_order), np.asarray(new_order))
    for fanout in (1, 5, 10, 32):
        old_keep = (old_order < fanout).astype(np.float32)
        new_keep = (np.asarray(new_order) < fanout).astype(np.float32)
        np.testing.assert_array_equal(old_keep, new_keep)


# ---------------------------------------------------------------------------
# satellite: merge dedup fast path
# ---------------------------------------------------------------------------

def test_sync_merge_skips_dedup_async_keeps_it(small_fed, monkeypatch):
    import repro.api.engine as engine_mod

    g, fed = small_fed
    # empty callback stack: eval's macro_ovr_auc also calls np.unique and
    # would pollute the spy — merge's dedup scan is the only candidate left
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    clients_per_round=3, seed=0, callbacks=[],
                    scheduler=SyncScheduler(fused=False))
    state = eng.init_state()
    sel = np.asarray([0, 1, 2])
    out = eng.dispatch(state, sel, 0)

    calls = []
    real_unique = np.unique

    def spy(*a, **kw):
        calls.append(a)
        return real_unique(*a, **kw)

    monkeypatch.setattr(engine_mod.np, "unique", spy)
    eng.merge(state, 0, sel, out)                 # sync path: no dedup scan
    assert calls == []
    # async path (staleness given) with a duplicated client still dedups
    from repro.api import StalenessWeightedAggregator

    dup = np.asarray([1, 1, 2])
    out2 = eng.dispatch(state, dup, 1)
    before = np.asarray(state.hist.age[1])
    eng.merge(state, 1, dup, out2, staleness=np.zeros(3, np.int64),
              aggregator=StalenessWeightedAggregator())
    assert calls, "async merge must keep the write-back dedup"
    # freshest (last) duplicate won the write-back: age row actually updated
    assert not np.array_equal(np.asarray(state.hist.age[1]), before)


# ---------------------------------------------------------------------------
# satellite: interpret auto-detection
# ---------------------------------------------------------------------------

def test_resolve_interpret_auto():
    from repro.kernels import resolve_interpret

    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) is (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
