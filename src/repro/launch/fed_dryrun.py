import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run of the PAPER'S OWN workload: one FedAIS round
(Algorithm 1) with K clients sharded across the production mesh.

Each client's LocalUpdate is vmapped over a client axis that shards over the
mesh ("data" x "model" = one client per chip on pod1), so the cross-client
ghost pull inside LocalUpdate lowers to gather/all-to-all collectives across
chips — exactly the embedding-synchronization network phase of the real
deployment — and FedAvg lowers to an all-reduce. This is the FedGCN-scale
companion to launch/dryrun.py's LM cases.

    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh pod1
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.registry import method_config
from repro.core.fedais import MethodConfig, make_local_update
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_label
from repro.models.gcn import HIDDEN, gcn_init, gcn_param_count
from repro.utils.hlo import collective_stats
from repro.utils.roofline import RooflineReport


def build_round_step(mcfg: MethodConfig, K: int, n_max: int, g_max: int,
                     n_feat: int, n_classes: int, mesh):
    """Returns (round_step, abstract args with shardings)."""
    H1 = HIDDEN[0]
    local_update = make_local_update(mcfg, n_max, g_max, H1)
    client_axes = tuple(mesh.shape.keys())  # clients shard over the whole mesh

    def round_step(params, client, hist1, age, ghost_feat, prev_loss, tau, keys):
        out = jax.vmap(
            local_update,
            in_axes=(None, 0, None, None, 0, 0, 0, 0, None, None, None, 0),
        )(params, client, client["features"], hist1, hist1, age, ghost_feat,
          prev_loss, tau, jnp.asarray(mcfg.neighbor_fanout, jnp.int32),
          jnp.asarray(0, jnp.int32), keys)
        new_params, new_hist1, new_age, new_ghost, stats = out
        # FedAvg over every client (all-reduce across the mesh)
        agg = jax.tree_util.tree_map(lambda x: x.mean(axis=0), new_params)
        return agg, new_hist1, new_age, new_ghost, stats["loss_all"]

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))

    c = P(client_axes)            # client-sharded leading axis
    r = P()                       # replicated
    n_tot = n_max + g_max
    params = jax.eval_shape(lambda: gcn_init(jax.random.PRNGKey(0), n_feat, n_classes))
    params = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=NamedSharding(mesh, r)),
        params)
    client = {
        "features": sds((K, n_max, n_feat), jnp.float32, c),
        "labels": sds((K, n_max), jnp.int32, c),
        "node_mask": sds((K, n_max), jnp.float32, c),
        "train_mask": sds((K, n_max), jnp.float32, c),
        "nbr_idx": sds((K, n_max, 16), jnp.int32, c),
        "nbr_mask": sds((K, n_max, 16), jnp.float32, c),
        "ghost_owner": sds((K, g_max), jnp.int32, c),
        "ghost_row": sds((K, g_max), jnp.int32, c),
        "ghost_mask": sds((K, g_max), jnp.float32, c),
    }
    args = (
        params,
        client,
        sds((K, n_tot, HIDDEN[0]), jnp.float32, c),   # hist1 (all clients)
        sds((K, n_tot), jnp.int32, c),                # age
        sds((K, g_max, n_feat), jnp.float32, c),      # ghost features
        sds((K, n_max), jnp.float32, c),              # prev loss
        jax.ShapeDtypeStruct((), jnp.int32),          # tau
        sds((K, 2), jnp.uint32, c),                   # per-client PRNG keys
    )
    return round_step, args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--clients", type=int, default=0, help="default: one per chip")
    ap.add_argument("--n-max", type=int, default=512)
    ap.add_argument("--g-max", type=int, default=256)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--classes", type=int, default=41)   # reddit-like
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    rc = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=mesh_name == "pod2")
        chips = mesh_chips(mesh)
        K = args.clients or chips
        mcfg = method_config("fedais", local_epochs=4, batch_cap=args.n_max)
        step, sargs = build_round_step(mcfg, K, args.n_max, args.g_max,
                                       args.features, args.classes, mesh)
        t0 = time.time()
        try:
            with mesh:
                lowered = jax.jit(step).lower(*sargs)
                compiled = lowered.compile()
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0] if cost else {}
                mem = compiled.memory_analysis()
                hlo = compiled.as_text()
        except Exception as e:
            print(f"[{mesh_name}] ERROR: {type(e).__name__}: {e}")
            rc = 1
            continue
        coll = collective_stats(hlo)
        n_params = gcn_param_count(args.features, args.classes)
        # per-round model flops: J epochs x batch fwd+bwd over K clients
        from repro.models.gcn import gcn_flops_per_node
        flops_model = 3.0 * gcn_flops_per_node(args.features, args.classes, 8.0) \
            * args.n_max * mcfg.local_epochs * K
        rep = RooflineReport(
            arch="fedgcn-graphsage", shape=f"K{K}", mesh=mesh_name, chips=chips,
            hlo_flops=float(cost.get("flops", 0.0)) * chips,
            hlo_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
            collective_bytes=float(coll.total_bytes) * chips,
            model_flops=flops_model,
        )
        result = {
            "status": "ok", "arch": "fedgcn-graphsage", "shape": f"K{K}",
            "mesh": mesh_name, "chips": chips, "clients": K,
            "gcn_params": n_params,
            "compile_s": round(time.time() - t0, 1),
            "collectives": {k: int(v) for k, v in coll.bytes_by_kind.items()},
            "roofline": rep.row(),
            "memory": {"temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
        }
        print(rep.pretty())
        print(f"    [{mesh_name}] K={K} compile={result['compile_s']}s "
              f"collectives: {coll.summary()}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, f"fedgcn_{mesh_name}.json"), "w") as f:
                json.dump(result, f, indent=1)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
