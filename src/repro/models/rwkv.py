"""RWKV6 ("Finch") block: data-dependent-decay time-mix + channel-mix.

arXiv:2404.05892. Pure-JAX reference path uses a sequential ``lax.scan`` over
time with the (B, H, N, N) state held in fp32 — on TPU the same recurrence is
provided as a Pallas kernel (``repro.kernels.wkv6``) that keeps the state in
VMEM across time chunks (HBM traffic O(T*N) instead of O(T*N^2)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, groupnorm, rmsnorm, rmsnorm_init, shard_activation

LORA_MIX = 32     # rank of the ddlerp lora
LORA_DECAY = 64   # rank of the decay lora


def rwkv_block_init(key, cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    N = cfg.rwkv_head_dim
    H = d // N
    dt = cfg.jnp_dtype
    ks = iter(jax.random.split(key, 20))
    nx = lambda a, b: dense_init(next(ks), a, b, dt)
    small = lambda *shape: (jax.random.normal(next(ks), shape, jnp.float32) * 0.02).astype(jnp.float32)
    return {
        "ln1": rmsnorm_init(d, dt),
        "ln2": rmsnorm_init(d, dt),
        # --- time-mix ---
        "mu_x": small(d),
        "mu5": small(5, d),               # w, k, v, r, g
        "mix_w1": nx(d, 5 * LORA_MIX),
        "mix_w2": small(5, LORA_MIX, d),
        "w0": small(d),                   # decay base
        "decay_w1": nx(d, LORA_DECAY),
        "decay_w2": nx(LORA_DECAY, d),
        "u": small(H, N),                 # per-head bonus
        "wr": nx(d, d), "wk": nx(d, d), "wv": nx(d, d), "wg": nx(d, d), "wo": nx(d, d),
        # --- channel-mix ---
        "mu_ck": small(d),
        "mu_cr": small(d),
        "wck": nx(d, ff), "wcv": nx(ff, d), "wcr": nx(d, d),
    }


def _ddlerp(p, x, xx):
    """Data-dependent interpolation producing the 5 mixed inputs (B,T,5,d)."""
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xxx @ p["mix_w1"]).reshape(*x.shape[:-1], 5, LORA_MIX)
    deltas = jnp.einsum("...fr,frd->...fd", lora.astype(jnp.float32), p["mix_w2"])
    mix = p["mu5"] + deltas                                    # (B,T,5,d) fp32
    return x[..., None, :] + xx[..., None, :] * mix.astype(x.dtype)


def _time_mix_inputs(p, cfg, x, x_prev):
    """Compute (r, k, v, g, w_decay) from x and its shifted predecessor."""
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    xx = x_prev - x
    mixed = _ddlerp(p, x, xx)
    xw, xk, xv, xr, xg = [mixed[..., i, :] for i in range(5)]
    logw = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["decay_w1"].astype(jnp.float32)) @ p[
        "decay_w2"
    ].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                                # (B,T,d) in (0,1)
    r = (xr @ p["wr"]).reshape(*x.shape[:-1], H, N)
    k = (xk @ p["wk"]).reshape(*x.shape[:-1], H, N)
    v = (xv @ p["wv"]).reshape(*x.shape[:-1], H, N)
    g = jax.nn.silu(xg @ p["wg"])
    w = w.reshape(*x.shape[:-1], H, N)
    return r, k, v, g, w


def wkv_chunked_scan(r, k, v, w, u, chunk: int = 128, state0=None):
    """WKV via an outer scan over time-chunks with remat at chunk boundaries.

    Reverse-mode through the plain per-step scan saves the (B,H,N,N) state
    for every timestep (~O(T·N²) HBM — §Perf H2.2). Checkpointing each chunk
    keeps only chunk-boundary states and recomputes inside the chunk during
    backward — the pure-JAX analogue of the Pallas kernel's VMEM-resident
    state (kernels/wkv6).
    """
    B, T, H, N = r.shape
    if T % chunk:
        return wkv_scan(r, k, v, w, u, state0)
    n = T // chunk
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    @jax.checkpoint
    def chunk_body(S, inp):
        rc, kc, vc, wc = inp                      # (B, chunk, H, N)
        y, S = wkv_scan(rc, kc, vc, wc, u, state0=S)
        return S, y

    xs = tuple(a.reshape(B, n, chunk, H, N).transpose(1, 0, 2, 3, 4)
               for a in (r, k, v, w))
    state, ys = jax.lax.scan(chunk_body, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N)
    return y, state


def wkv_scan(r, k, v, w, u, state0=None):
    """Sequential WKV recurrence.

    r,k,v,w: (B, T, H, N); u: (H, N). Returns (y (B,T,H,N), final state
    (B,H,N,N)). State S[n,m]: key-dim n x value-dim m, fp32.
    """
    B, T, H, N = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = [a.astype(jnp.float32) for a in inp]   # (B,H,N)
        coef = jnp.sum(rt * u * kt, axis=-1, keepdims=True)     # (B,H,1)
        y = coef * vt + jnp.einsum("bhn,bhnm->bhm", rt, S)
        S = wt[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


def _time_mix_out(p, cfg, y, g, x_shape):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    y = groupnorm(y.reshape(*x_shape[:-1], d), H)
    return (y * g) @ p["wo"]


def _channel_mix(p, x, x_prev):
    xx = x_prev - x
    xk = x + xx * p["mu_ck"].astype(x.dtype)
    xr = x + xx * p["mu_cr"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wck"]))
    k = shard_activation(k, "batch", "seq", "ff")
    return jax.nn.sigmoid(xr @ p["wcr"]) * (k @ p["wcv"])


def _shift(x):
    """Token shift: x_prev[t] = x[t-1], zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv_block_apply(p, cfg, x, use_kernel: bool = False):
    """Full-sequence RWKV6 block. x: (B, T, d)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    r, k, v, g, w = _time_mix_inputs(p, cfg, h, _shift(h))
    chunk = getattr(cfg, "rwkv_chunk", 0)
    if use_kernel:
        from repro.kernels.wkv6 import ops as wkv_ops
        y, _ = wkv_ops.wkv6(r, k, v, w, p["u"])
    elif chunk:
        y, _ = wkv_chunked_scan(r, k, v, w, p["u"], chunk=chunk)
    else:
        y, _ = wkv_scan(r, k, v, w, p["u"])
    x = x + _time_mix_out(p, cfg, y, g, x.shape)
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + _channel_mix(p, h2, _shift(h2))
    return x


# ---------------------------------------------------------------------------
# decode (single token, O(1) state)
# ---------------------------------------------------------------------------

def rwkv_init_state(cfg, batch: int) -> dict:
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    dt = cfg.jnp_dtype
    return {
        "S": jnp.zeros((batch, H, N, N), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dt),   # last input of time-mix
        "x_cm": jnp.zeros((batch, d), dt),   # last input of channel-mix
    }


def rwkv_block_decode(p, cfg, x, state):
    """x: (B, 1, d) -> (out (B,1,d), new state)."""
    B = x.shape[0]
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    r, k, v, g, w = _time_mix_inputs(p, cfg, h, state["x_tm"][:, None])
    rt, kt, vt, wt = [a[:, 0].astype(jnp.float32) for a in (r, k, v, w)]
    S = state["S"]
    coef = jnp.sum(rt * p["u"] * kt, axis=-1, keepdims=True)
    y = coef * vt + jnp.einsum("bhn,bhnm->bhm", rt, S)
    S = wt[..., None] * S + kt[..., None] * vt[..., None, :]
    y = y[:, None].astype(x.dtype)                              # (B,1,H,N)
    x = x + _time_mix_out(p, cfg, y, g, x.shape)
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    out = x + _channel_mix(p, h2, state["x_cm"][:, None])
    new_state = {"S": S, "x_tm": h[:, 0], "x_cm": h2[:, 0]}
    return out, new_state
