"""Shared utilities: pytree helpers, HLO analysis, roofline math."""
