"""Paper Fig. 6: scaling the number of clients (paper: 100..1000; here
scaled to the synthetic graph sizes — the claim is accuracy stays high and
FedAIS's comm advantage persists as K grows)."""
from __future__ import annotations

from repro.api import FedEngine, method_config
from benchmarks.common import fed_setup


def run(quick: bool = True) -> list[dict]:
    ks = [8, 16, 32] if quick else [16, 32, 64, 100]
    rounds = 10 if quick else 30
    rows = []
    for K in ks:
        g, fed = fed_setup("reddit", 96 if quick else 64, K, "iid")
        for m in ("fedall", "fedais"):
            res = FedEngine(g, fed, method_config(m, tau0=4 if m == "fedais" else 1),
                            rounds=rounds, clients_per_round=max(3, K // 4), seed=0).run()
            rows.append({
                "n_clients": K,
                "method": m,
                "final_acc": round(res.final["acc"] * 100, 2),
                "comm_mb": round(res.final["comm_total_bytes"] / 1e6, 2),
            })
    return rows
