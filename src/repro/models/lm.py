"""Generic language model covering every assigned architecture family.

A model = embedding + N blocks + final norm + LM head. Blocks are described
by ``cfg.block_pattern`` (a repeating unit, e.g. 5 local + 1 global for
gemma3, rec/rec/local for recurrentgemma). Weights of repeated units are
stacked on a leading axis and applied with ``lax.scan`` so the HLO stays
compact for 126-layer dry-runs (DESIGN.md §6.4); the remainder partial unit
is applied unrolled.

Entry points:
    init_lm(key, cfg)                          -> params
    lm_forward(params, cfg, tokens, ...)       -> (logits, aux_loss)
    lm_loss(params, cfg, batch)                -> (loss, metrics)
    make_train_step(cfg, lr_schedule)          -> jit-able train_step
    lm_prefill(params, cfg, tokens, max_len)   -> (last_logits, decode_state)
    init_decode_state(params, cfg, B, max_len) -> state
    decode_step(params, cfg, state, token, pos)-> (logits, state)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import griffin, rwkv
from repro.models.attention import attn_init, decode_attn, init_kv_cache, multihead_attn
from repro.models.layers import (
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    shard_activation,
    softmax_xent,
)
from repro.models.moe import moe_apply, moe_init
from repro.optim import AdamState, adamw_init, adamw_update
from repro.utils.tree import global_norm_clip

PyTree = Any

_ATTN_KINDS = {"attn": "causal", "local": "local", "enc": "bidir", "dec": "causal"}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _ffn_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {"ln": rmsnorm_init(cfg.d_model, cfg.jnp_dtype)}
    if cfg.n_experts:
        p["moe"] = moe_init(k1, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.jnp_dtype)
    return p


def init_block(key, cfg, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "rwkv":
        return rwkv.rwkv_block_init(k1, cfg)
    if kind == "rec":
        return {"rec": griffin.rglru_block_init(k1, cfg), "ffn": _ffn_init(k2, cfg)}
    p = {"attn": attn_init(k1, cfg), "ffn": _ffn_init(k2, cfg)}
    if kind == "dec":
        p["xattn"] = attn_init(k3, cfg, cross=True)
    return p


def _init_unit(key, cfg, pattern) -> dict:
    keys = jax.random.split(key, max(1, len(pattern)))
    return {f"b{i}": init_block(keys[i], cfg, kind) for i, kind in enumerate(pattern)}


def init_lm(key, cfg) -> dict:
    keys = iter(jax.random.split(key, 10))
    dt = cfg.jnp_dtype
    params: dict = {"embed": embed_init(next(keys), cfg.vocab_size, cfg.d_model, dt)}

    n_units = cfg.n_units
    unit_keys = jax.random.split(next(keys), n_units)
    params["units"] = jax.vmap(lambda k: _init_unit(k, cfg, cfg.block_pattern))(unit_keys)
    if cfg.remainder_pattern:
        params["rem"] = _init_unit(next(keys), cfg, cfg.remainder_pattern)

    params["final_norm"] = rmsnorm_init(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(next(keys), cfg.d_model, cfg.vocab_size, dt)

    if cfg.pos_embedding == "learned":
        params["pos_emb"] = embed_init(next(keys), cfg.max_seq_len, cfg.d_model, dt)

    if cfg.n_encoder_layers:  # whisper encoder (consumes stub frame embeddings)
        enc_keys = jax.random.split(next(keys), cfg.n_encoder_layers)
        params["enc_units"] = jax.vmap(lambda k: _init_unit(k, cfg, ("enc",)))(enc_keys)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dt)
        params["enc_pos"] = embed_init(next(keys), cfg.encoder_seq_len, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# full-sequence application (train / prefill)
# ---------------------------------------------------------------------------

def _apply_ffn(p, cfg, x):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_apply(p["moe"], cfg, h)
    else:
        y, aux = mlp_apply(p["mlp"], h, cfg.activation), 0.0
    return x + y, aux


def apply_block_full(bp, cfg, kind, x, *, enc_out=None, collect_state=False):
    """Returns (x, aux_loss, state_or_None)."""
    state = None
    if kind == "rwkv":
        if collect_state:
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            r, k, v, g, w = rwkv._time_mix_inputs(bp, cfg, h, rwkv._shift(h))
            y, S = rwkv.wkv_scan(r, k, v, w, bp["u"])
            x = x + rwkv._time_mix_out(bp, cfg, y, g, x.shape)
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            x = x + rwkv._channel_mix(bp, h2, rwkv._shift(h2))
            state = {"S": S, "x_tm": h[:, -1], "x_cm": h2[:, -1]}
        else:
            x = rwkv.rwkv_block_apply(bp, cfg, x)
        return x, 0.0, state
    if kind == "rec":
        if collect_state:
            h = rmsnorm(bp["rec"]["ln"], x, cfg.norm_eps)
            rec_in = h @ bp["rec"]["w_rec_in"]
            conv = griffin._causal_conv1d(rec_in, bp["rec"]["conv_w"], bp["rec"]["conv_b"])
            a, b = griffin._rglru_gates(bp["rec"], cfg, conv)
            y, h_last = griffin.rglru_scan(a, b)
            gate = jax.nn.gelu(h @ bp["rec"]["w_gate_in"])
            x = x + (y.astype(x.dtype) * gate) @ bp["rec"]["w_out"]
            K = cfg.conv1d_width
            pad = jnp.pad(rec_in, ((0, 0), (K - 1, 0), (0, 0)))
            state = {"h": h_last, "conv": pad[:, pad.shape[1] - (K - 1):]}
        else:
            x = griffin.rglru_block_apply(bp["rec"], cfg, x)
        x, aux = _apply_ffn(bp["ffn"], cfg, x)
        return x, aux, state
    # attention kinds
    akind = _ATTN_KINDS[kind]
    if collect_state and kind in ("attn", "local", "dec"):
        out, (kk, vv) = multihead_attn(bp["attn"], cfg, x, kind=akind, return_kv=True)
        state = {"k": kk, "v": vv}
    else:
        out = multihead_attn(bp["attn"], cfg, x, kind=akind)
    x = x + out
    if kind == "dec":
        x = x + multihead_attn(bp["xattn"], cfg, x, kind="bidir", kv_source=enc_out)
    x, aux = _apply_ffn(bp["ffn"], cfg, x)
    return x, aux, state


def _run_encoder(params, cfg, enc_frames):
    """Whisper encoder over stub frame embeddings (B, Se, d)."""
    h = enc_frames.astype(cfg.jnp_dtype) + params["enc_pos"][None, : enc_frames.shape[1]]

    def body(carry, up):
        hh, aux = carry
        hh, a, _ = apply_block_full(up["b0"], cfg, "enc", hh)
        return (hh, aux + a), None

    if cfg.scan_layers:
        (h, aux), _ = jax.lax.scan(body, (h, 0.0), params["enc_units"])
    else:
        aux = 0.0
        for u in range(cfg.n_encoder_layers):
            up = jax.tree_util.tree_map(lambda x: x[u], params["enc_units"])
            (h, aux), _ = body((h, aux), up)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps), aux


def _embed_tokens(params, cfg, tokens, image_embeds=None, position_offset=0):
    h = params["embed"][tokens]
    if image_embeds is not None:
        h = jnp.concatenate([image_embeds.astype(h.dtype), h], axis=1)
    if cfg.pos_embedding == "learned":
        S = h.shape[1]
        h = h + params["pos_emb"][None, position_offset : position_offset + S]
    return h


def lm_forward(params, cfg, tokens, *, image_embeds=None, enc_frames=None, collect_state=False):
    """tokens (B, S) -> (logits (B, S_total, V), aux_loss[, states])."""
    enc_out = None
    aux_total = 0.0
    if cfg.n_encoder_layers:
        enc_out, enc_aux = _run_encoder(params, cfg, enc_frames)
        aux_total += enc_aux
    h = _embed_tokens(params, cfg, tokens, image_embeds)
    h = shard_activation(h, "batch", "seq", None)

    pattern = cfg.block_pattern

    def unit_body(carry, up):
        hh, aux = carry
        states = {}
        for i, kind in enumerate(pattern):
            hh, a, st = apply_block_full(up[f"b{i}"], cfg, kind, hh, enc_out=enc_out,
                                         collect_state=collect_state)
            aux = aux + a
            if collect_state:
                states[f"b{i}"] = st
        # sequence-parallel boundary: the remat-saved carry shards its seq dim
        # over the model axis (Megatron SP; §Perf H3.3 — boundary residuals
        # were the dominant per-device residency, not attention scores)
        hh = shard_activation(hh, "batch", "boundary_seq", None)
        return (hh, aux), (states if collect_state else None)

    body = jax.checkpoint(unit_body) if cfg.remat else unit_body
    if cfg.scan_layers:
        (h, aux_total), unit_states = jax.lax.scan(body, (h, aux_total), params["units"])
    else:
        states_list = []
        for u in range(cfg.n_units):
            up = jax.tree_util.tree_map(lambda x: x[u], params["units"])
            (h, aux_total), st = body((h, aux_total), up)
            states_list.append(st)
        unit_states = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states_list)
            if collect_state else None
        )

    rem_states = {}
    for i, kind in enumerate(cfg.remainder_pattern):
        h, a, st = apply_block_full(params["rem"][f"b{i}"], cfg, kind, h, enc_out=enc_out,
                                    collect_state=collect_state)
        aux_total = aux_total + a
        if collect_state:
            rem_states[f"b{i}"] = st

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = shard_activation(logits, "batch", "seq", "vocab")
    if collect_state:
        return logits, aux_total, (unit_states, rem_states, enc_out)
    return logits, aux_total


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def lm_loss(params, cfg, batch):
    logits, aux = lm_forward(
        params, cfg, batch["tokens"],
        image_embeds=batch.get("image_embeds"),
        enc_frames=batch.get("enc_frames"),
    )
    if cfg.n_image_tokens:
        logits = logits[:, cfg.n_image_tokens :]
    loss = softmax_xent(logits, batch["labels"])
    total = loss + 0.01 * aux
    return total, {"xent": loss, "aux": aux}


def make_train_step(cfg, lr_schedule, *, clip_norm: float = 1.0):
    def train_step(params, opt_state: AdamState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True
        )(params)
        grads, gnorm = global_norm_clip(grads, clip_norm)
        lr = lr_schedule(opt_state.step)
        new_params, new_state = adamw_update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_state, metrics

    return train_step


def init_train_state(key, cfg, state_dtype=jnp.float32):
    params = init_lm(key, cfg)
    return params, adamw_init(params, state_dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _init_block_state(cfg, kind, batch, max_len, dtype=None):
    if kind == "rwkv":
        return rwkv.rwkv_init_state(cfg, batch)
    if kind == "rec":
        return griffin.rglru_init_state(cfg, batch)
    st = init_kv_cache(cfg, batch, max_len, dtype)
    return st


def init_decode_state(params, cfg, batch: int, max_len: int, *, enc_out=None, cache_dtype=None) -> dict:
    """Zero-initialised decode state (pre-prefill)."""

    def one_unit(pattern):
        return {
            f"b{i}": _init_block_state(cfg, kind, batch, max_len, cache_dtype)
            for i, kind in enumerate(pattern)
        }

    U = cfg.n_units
    unit = one_unit(cfg.block_pattern)
    units = jax.tree_util.tree_map(lambda x: jnp.tile(x[None], (U,) + (1,) * x.ndim), unit)
    state = {"units": units}
    if cfg.remainder_pattern:
        state["rem"] = one_unit(cfg.remainder_pattern)
    if cfg.n_encoder_layers and enc_out is not None:
        # precompute cross-attention K/V from the encoder output, per unit
        hd = cfg.resolved_head_dim

        def cross_kv(up):
            k = (enc_out @ up["b0"]["xattn"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, hd)
            v = (enc_out @ up["b0"]["xattn"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, hd)
            return {"xk": k, "xv": v}

        state["cross"] = jax.vmap(cross_kv)(params["units"])
    return state


def apply_block_decode(bp, cfg, kind, x, st, pos, cross=None):
    if kind == "rwkv":
        return rwkv.rwkv_block_decode(bp, cfg, x, st)
    if kind == "rec":
        x, new = griffin.rglru_block_decode(bp["rec"], cfg, x, st)
        x, _ = _apply_ffn(bp["ffn"], cfg, x)
        return x, new
    akind = "local" if kind == "local" else "causal"
    out, new = decode_attn(bp["attn"], cfg, x, st, pos, kind=akind)
    x = x + out
    if kind == "dec" and cross is not None:
        xout, _ = decode_attn(bp["xattn"], cfg, x, st, pos, cross_kv=(cross["xk"], cross["xv"]))
        x = x + xout
    x, _ = _apply_ffn(bp["ffn"], cfg, x)
    return x, new


def decode_step(params, cfg, state, tokens, pos):
    """One decode step. tokens (B, 1) int32; pos scalar int32.

    Returns (logits (B, 1, V), new_state).
    """
    h = params["embed"][tokens]
    if cfg.pos_embedding == "learned":
        h = h + params["pos_emb"][pos][None, None]
    pattern = cfg.block_pattern
    has_cross = "cross" in state

    def unit_body(h, xs):
        if has_cross:
            up, uc, cross = xs
        else:
            up, uc = xs
            cross = None
        new_uc = {}
        for i, kind in enumerate(pattern):
            h, new_uc[f"b{i}"] = apply_block_decode(
                up[f"b{i}"], cfg, kind, h, uc[f"b{i}"], pos, cross=cross)
        return h, new_uc

    xs = (params["units"], state["units"]) + ((state["cross"],) if has_cross else ())
    if cfg.scan_layers:
        h, new_units = jax.lax.scan(unit_body, h, xs)
    else:
        uc_list = []
        for u in range(cfg.n_units):
            xu = jax.tree_util.tree_map(lambda x: x[u], xs)
            h, uc = unit_body(h, xu)
            uc_list.append(uc)
        new_units = jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *uc_list)
    new_state = dict(state, units=new_units)

    if cfg.remainder_pattern:
        new_rem = {}
        for i, kind in enumerate(cfg.remainder_pattern):
            h, new_rem[f"b{i}"] = apply_block_decode(
                params["rem"][f"b{i}"], cfg, kind, h, state["rem"][f"b{i}"], pos)
        new_state["rem"] = new_rem

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head, new_state


def lm_prefill(params, cfg, tokens, max_len, *, image_embeds=None, enc_frames=None):
    """Run the full prompt, returning (last-token logits, decode state)."""
    logits, _aux, (unit_states, rem_states, enc_out) = lm_forward(
        params, cfg, tokens, image_embeds=image_embeds, enc_frames=enc_frames,
        collect_state=True,
    )
    B = tokens.shape[0]
    S = logits.shape[1]
    state = init_decode_state(params, cfg, B, max_len, enc_out=enc_out)

    def merge(init_leafpath, full):
        return full

    # write collected K/V (length S) into the max_len caches; copy rec/rwkv states
    def write_unit(init_st, got_st):
        out = {}
        for bkey, st in got_st.items():
            ini = init_st[bkey]
            if st is None:
                out[bkey] = ini
            elif "k" in st:  # kv cache: (U?, B, S, Hkv, hd) into (..., max_len, ...)
                k = ini["k"].at[..., :S, :, :].set(st["k"].astype(ini["k"].dtype))
                v = ini["v"].at[..., :S, :, :].set(st["v"].astype(ini["v"].dtype))
                out[bkey] = dict(ini, k=k, v=v)
            else:
                out[bkey] = st
        return out

    state["units"] = write_unit(state["units"], unit_states)
    if cfg.remainder_pattern:
        state["rem"] = write_unit(state["rem"], rem_states)
    return logits[:, -1], state


# ---------------------------------------------------------------------------
# serve step (the dry-run decode entry point)
# ---------------------------------------------------------------------------

def make_serve_step(cfg):
    """One-token decode step against a seq_len KV cache (the decode shapes)."""

    def serve_step(params, state, tokens, pos):
        logits, new_state = decode_step(params, cfg, state, tokens, pos)
        return logits, new_state

    return serve_step
