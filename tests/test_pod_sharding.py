"""Pod-sharded historical tables (repro.sharding.tables) — the parity tier.

Contract: with the (K, n_tot, H1) tables sharded over the pod axis and the
ghost pull rebuilt as a bucketed cross-pod all-to-all, history stays
**allclose** to both the client-sharded and the unsharded fused executors
with every discrete column **exact** — the per-client computation is
bit-identical (``pull_ghosts_prefetched`` hands each client the same
round-start snapshot rows the replicated-table gather would), so the only
drift source is the merge's summation order, exactly as in the PR-4
client-sharded tier.

Multi-device tests skip on a single-device host; CI's ``sharded`` lane runs
this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and
covers the 2/4/8-pod splits of the 8-device grid plus the ragged-cohort and
empty-pod (pods owning only padding rows) edge cases.
"""
import jax
import numpy as np
import pytest

from repro.api import (
    FedEngine,
    LossBiasedSelector,
    SyncScheduler,
    available_methods,
    method_config,
)
from repro.federated.partition import partition_graph
from repro.sharding.fed import make_client_mesh
from repro.sharding.tables import make_pod_mesh

pytestmark = pytest.mark.sharded

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs >=8 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

EXACT_KEYS = ("tau", "comm_total", "comm_embed", "flops", "wall_clock")
CLOSE_KEYS = ("test_acc", "test_loss")

# the 8-device grid factored into every pod split the issue names
POD_SPLITS = ((2, 4), (4, 2), (8, 1))


def _run(g, fed, *, mesh=None, m=4, rounds=4, method="fedais", seed=0, **kw):
    eng = FedEngine(g, fed, method_config(method, tau0=4), seed=seed,
                    rounds=rounds, clients_per_round=m, eval_every=2,
                    mesh=mesh, **kw)
    return eng, eng.run()


def _assert_allclose_history(ref, got):
    for k in EXACT_KEYS:
        assert ref.history[k] == got.history[k], f"history[{k!r}] diverged"
    for k in CLOSE_KEYS:
        np.testing.assert_allclose(
            np.asarray(got.history[k], np.float64),
            np.asarray(ref.history[k], np.float64),
            rtol=1e-4, atol=1e-6, err_msg=f"history[{k!r}]")


# ---------------------------------------------------------------------------
# pod-sharded vs client-sharded vs fused parity, across pod splits
# ---------------------------------------------------------------------------

@needs_devices
@pytest.mark.parametrize("pods,clients", POD_SPLITS)
def test_pod_matches_client_sharded_and_fused(small_fed, pods, clients):
    g, fed = small_fed
    eng_f, res_f = _run(g, fed)
    eng_c, res_c = _run(g, fed, mesh=make_client_mesh(8))
    eng_p, res_p = _run(g, fed, mesh=make_pod_mesh(pods, clients))
    assert eng_f.last_executor == "fused"
    assert eng_c.last_executor == "sharded_fused"
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_f, res_p)
    _assert_allclose_history(res_c, res_p)


@needs_devices
@pytest.mark.parametrize("method", sorted(available_methods()))
def test_pod_parity_every_registered_method(small_fed, method):
    """Every registered method whose components clear the pod gates runs
    pod-sharded and must match its own fused run; the rest (generator /
    bandit strategies have per-round host hooks) fall soft down the chain
    and still complete."""
    g, fed = small_fed
    eng_p, res_p = _run(g, fed, m=3, rounds=3, method=method,
                        mesh=make_pod_mesh(4, 2))
    if eng_p.pod_sharded_eligibility(3)[0] and eng_p.fused_eligibility()[0]:
        assert eng_p.last_executor == "pod_sharded"
        _, res_f = _run(g, fed, m=3, rounds=3, method=method)
        _assert_allclose_history(res_f, res_p)
    else:
        assert eng_p.last_executor in ("fused", "stepwise")
        assert np.isfinite(res_p.final["loss"])


@needs_devices
def test_pod_weighted_aggregation_parity(small_fed):
    """WeightedFedAvg: the pod merge must fold the client-size weights."""
    g, fed = small_fed
    kw = dict(aggregator="weighted", scheduler=SyncScheduler(fused=True))
    _, res_f = _run(g, fed, **kw)
    eng_p, res_p = _run(g, fed, mesh=make_pod_mesh(2, 4), **kw)
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_f, res_p)


@needs_devices
def test_pod_pairwise_merge_parity(small_fed):
    """merge_reduce='pairwise' (fixed fp32 tree over gathered partials) is
    a drop-in for the psum within the same allclose contract."""
    g, fed = small_fed
    _, res_f = _run(g, fed)
    eng_p, res_p = _run(g, fed, mesh=make_pod_mesh(4, 2),
                        merge_reduce="pairwise")
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_f, res_p)


@needs_devices
@pytest.mark.parametrize("pods,clients", POD_SPLITS)
def test_sync_gated_ghost_exchange_parity(small_fed, pods, clients):
    """tau0=8 with J=4 local epochs syncs only every other round, so one
    scanned chunk exercises BOTH branches of the gated ghost exchange —
    rounds where the all-to-all runs and rounds where the whole block is
    the zeros branch. History must still match the fused executor, proving
    gating off the exchange on non-sync rounds is lossless."""
    from repro.sharding.tables import sync_round_gates

    g, fed = small_fed
    kw = dict(seed=0, rounds=4, clients_per_round=4, eval_every=2)
    eng_f = FedEngine(g, fed, method_config("fedais", tau0=8), **kw)
    res_f = eng_f.run()
    eng_p = FedEngine(g, fed, method_config("fedais", tau0=8),
                      mesh=make_pod_mesh(pods, clients), **kw)
    res_p = eng_p.run()
    assert eng_p.last_executor == "pod_sharded"
    # the schedule this pins really is mixed: some rounds gated off
    J = eng_p.mcfg.local_epochs
    gates = sync_round_gates(np.arange(4) * J, 8, J)
    assert gates.any() and not gates.all()
    # discrete columns exact (comm bytes prove the gate changed no
    # schedule accounting); losses allclose with a slightly wider rel
    # bound than the tier default — the tau0=8 trajectory's third eval
    # lands near 0.09, where the usual 1e-4 rel bound is tighter than
    # the merge's psum-vs-sequential summation noise (abs ~1e-5)
    for k in EXACT_KEYS:
        assert res_f.history[k] == res_p.history[k], f"history[{k!r}]"
    for k in CLOSE_KEYS:
        np.testing.assert_allclose(
            np.asarray(res_p.history[k], np.float64),
            np.asarray(res_f.history[k], np.float64),
            rtol=5e-4, atol=1e-5, err_msg=f"history[{k!r}]")


# ---------------------------------------------------------------------------
# ragged cohorts + empty pods: padding must be a provable no-op
# ---------------------------------------------------------------------------

def _one_chunk(g, fed, mesh, m, rounds=2):
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), seed=0, rounds=4,
                    clients_per_round=m, eval_every=2, mesh=mesh)
    state = eng.init_state()
    eng._run_chunk(state, 0, rounds)
    return eng, state


@needs_devices
def test_ragged_cohort_padding_is_noop(small_fed):
    """m=3 over the 8-device (2, 4) grid pads 5 dummy clients whose id is
    out of range of even the pod-padded tables. The full client-state
    tables must match the unsharded run — ages (ints) exactly, so a stray
    dummy or wrong-pod write-back to ANY row would be caught."""
    g, fed = small_fed
    _, st_u = _one_chunk(g, fed, None, 3)
    eng_p, st_p = _one_chunk(g, fed, make_pod_mesh(2, 4), 3)
    assert eng_p.last_executor == "pod_sharded"
    np.testing.assert_array_equal(np.asarray(st_p.hist.age),
                                  np.asarray(st_u.hist.age))
    assert st_p.hist.hist1.shape == st_u.hist.hist1.shape   # K rows, unpadded
    np.testing.assert_allclose(np.asarray(st_p.hist.hist1),
                               np.asarray(st_u.hist.hist1),
                               rtol=1e-2, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_p.prev_loss),
                               np.asarray(st_u.prev_loss),
                               rtol=1e-2, atol=1e-3)


@needs_devices
def test_empty_pods_zero_resident_clients(small_fed):
    """K=3 clients over 8 pods: the tables pad to 8 rows and 5 pods own
    only padding — their shards must stay inert (send empty buckets,
    receive nothing, scatter nothing) while history matches the unsharded
    run."""
    g, _ = small_fed
    fed3 = partition_graph(g, 3, alpha=0.5, seed=1)
    _, res_u = _run(g, fed3, m=2, rounds=3)
    eng_p, res_p = _run(g, fed3, m=2, rounds=3, mesh=make_pod_mesh(8, 1))
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_u, res_p)


@needs_devices
def test_divisible_mode_falls_back_on_ragged_cohort(small_fed):
    g, fed = small_fed
    mesh = make_pod_mesh(2, 4)
    eng = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3, mesh=mesh,
                    client_sharding="divisible")
    ok, why = eng.pod_sharded_eligibility(3)
    assert not ok and "divide" in why
    assert eng.pod_sharded_eligibility(8)[0]
    eng, res = _run(g, fed, mesh=mesh, m=3, rounds=2,
                    client_sharding="divisible")
    # cohort 3 does not divide 8 devices: pod AND client sharding both
    # decline, the chunk runs fused
    assert eng.last_executor == "fused"
    assert np.isfinite(res.final["loss"])


# ---------------------------------------------------------------------------
# fallback chain: pod-sharded -> client-sharded -> fused -> stepwise
# ---------------------------------------------------------------------------

@needs_devices
def test_pod_mesh_with_ineligible_fused_runs_stepwise(small_fed):
    g, fed = small_fed
    eng, res = _run(g, fed, m=3, rounds=2, mesh=make_pod_mesh(2, 4),
                    selector=LossBiasedSelector())
    assert eng.last_executor == "stepwise"
    assert np.isfinite(res.final["loss"])


@needs_devices
def test_replicated_tables_use_client_sharded_executor(small_fed):
    g, fed = small_fed
    eng, res_c = _run(g, fed, mesh=make_pod_mesh(2, 4),
                      table_sharding="replicated")
    assert eng.last_executor == "sharded_fused"
    _, res_f = _run(g, fed)
    _assert_allclose_history(res_f, res_c)
