"""Kernel-layer microbenchmarks: us_per_call of the XLA reference paths on
CPU (the Pallas kernels target TPU; interpret-mode timing is not meaningful,
so what we time here is the jnp oracle each kernel must beat on-device) plus
allclose deltas kernel-vs-oracle.

``--autotune-spmm`` instead sweeps block-size candidates for the SpMM
kernel over the shapes ``AUTOTUNE_TABLE`` covers (wall-clock of the full
``block_spmm`` call at each candidate, interpret mode off-TPU) and reports
the winner next to the committed table entry — the measurement the table's
entries come from. Exit status flags stale entries so the table can't
silently rot.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spmm.ops import (
    AUTOTUNE_TABLE,
    _pow2ceil,
    adjacency_block_mask,
    adjacency_from_neighbors,
    block_spmm,
    best_block_sizes,
)
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # spmm oracle timing + kernel correctness
    n, m, d = (256, 256, 128) if quick else (1024, 1024, 256)
    a = jnp.asarray((rng.random((n, m)) < 0.05) * rng.random((n, m)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    ref = jax.jit(spmm_ref)
    us = timed(ref, a, x)
    err = float(jnp.max(jnp.abs(block_spmm(a, x) - ref(a, x))))
    rows.append({"kernel": "spmm", "shape": f"{n}x{m}x{d}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})

    # flash attention
    B, S, H, Hkv, hd = (1, 256, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = timed(ref, q, k, v)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, block_q=64, block_k=64)
                                - ref(q, k, v))))
    rows.append({"kernel": "flash_attention", "shape": f"B{B}S{S}H{H}kv{Hkv}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})

    # wkv6
    B, T, H, N = (1, 128, 4, 32) if quick else (2, 512, 8, 64)
    r_, k_, v_ = [jnp.asarray(rng.standard_normal((B, T, H, N)) * 0.5, jnp.float32)
                  for _ in range(3)]
    w_ = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, H, N)))), jnp.float32)
    u_ = jnp.asarray(rng.standard_normal((H, N)) * 0.1, jnp.float32)
    ref = jax.jit(lambda *args: wkv6_ref(*args)[0])
    us = timed(ref, r_, k_, v_, w_, u_)
    err = float(jnp.max(jnp.abs(wkv6(r_, k_, v_, w_, u_, chunk=32)[0]
                                - ref(r_, k_, v_, w_, u_))))
    rows.append({"kernel": "wkv6", "shape": f"B{B}T{T}H{H}N{N}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})
    return rows


# ---------------------------------------------------------------------------
# SpMM block-size autotune (the sweep AUTOTUNE_TABLE's entries come from)
# ---------------------------------------------------------------------------

def spmm_candidates(n: int, m: int, d: int) -> list[tuple[int, int, int]]:
    """Local search around the current choice: the table/heuristic triple
    plus each dim halved and doubled (clamped to [8, pow2ceil(dim)] — a
    block larger than the padded dim only buys padding waste)."""
    dims = (_pow2ceil(n), _pow2ceil(m), _pow2ceil(d))
    base = best_block_sizes(n, m, d)
    cands = {base}
    for i in range(3):
        for v in (base[i] // 2, base[i] * 2):
            if 8 <= v <= dims[i]:
                c = list(base)
                c[i] = v
                cands.add(tuple(c))
    return sorted(cands)


def _spmm_problem(n: int, m: int, d: int, k: int = 8):
    """A neighbor-aggregation-shaped problem: each of the n rows reads ~k
    of the m table rows (the padded-neighbor-list sparsity the training and
    serve paths feed the kernel)."""
    rng = np.random.default_rng(n * 7 + m * 3 + d)
    idx = jnp.asarray(rng.integers(0, m, (n, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((n, k)) < 0.75).astype(np.float32))
    a = adjacency_from_neighbors(idx, mask, m)
    x = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    return a, x, idx, mask


# a table entry is "stale" only when it is measurably worse than the sweep's
# best — interpret-mode wall clocks jitter by tens of percent run to run, so
# a bitwise best==table gate would ping-pong on noise
STALE_RATIO = 1.3


def autotune_spmm(shapes=None, *, repeats: int = 2,
                  quick: bool = True) -> list[dict]:
    """Sweep ``spmm_candidates`` per shape; returns one row per shape with
    every candidate's us_per_call, the winner, and the committed table
    entry. ``quick`` skips the large eval-graph shapes (interpret mode
    pays per grid cell; CI smoke only needs the serve/train buckets)."""
    shapes = [tuple(s) for s in (shapes if shapes is not None
                                 else sorted(AUTOTUNE_TABLE))]
    if quick:
        shapes = [s for s in shapes if s[0] * s[1] * s[2] <= 256 * 512 * 512]
    rows = []
    for (n, m, d) in shapes:
        a, x, idx, mask = _spmm_problem(n, m, d)
        timings = []
        for (bn, bm, bd) in spmm_candidates(n, m, d):
            grid = adjacency_block_mask(idx, mask, m, bn, bm)
            us = timed(block_spmm, a, x, grid, block_n=bn, block_m=bm,
                       block_d=bd, repeats=repeats)
            timings.append({"blocks": (bn, bm, bd), "us_per_call": round(us, 1)})
        best = min(timings, key=lambda t: t["us_per_call"])
        rows.append({"kernel": "spmm_autotune", "shape": (n, m, d),
                     "best": best["blocks"],
                     "best_us_per_call": best["us_per_call"],
                     "table": AUTOTUNE_TABLE.get((n, m, d)),
                     "candidates": timings})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes (autotune: include the large "
                         "eval-graph shapes)")
    ap.add_argument("--autotune-spmm", action="store_true",
                    help="sweep SpMM block sizes over AUTOTUNE_TABLE's "
                         "shapes instead of running the oracle benchmarks")
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)
    if args.autotune_spmm:
        rows = autotune_spmm(repeats=args.repeats, quick=not args.full)
        stale = []
        for r in rows:
            print(json.dumps(r))
            if r["table"] is None or tuple(r["table"]) == r["best"]:
                continue
            tabled = next(t for t in r["candidates"]
                          if t["blocks"] == tuple(r["table"]))
            if tabled["us_per_call"] > STALE_RATIO * r["best_us_per_call"]:
                stale.append((r, tabled))
        for r, tabled in stale:
            print(f"# stale: {r['shape']} table {r['table']} "
                  f"({tabled['us_per_call']}us) vs measured best {r['best']} "
                  f"({r['best_us_per_call']}us)")
        return 1 if stale else 0
    for r in run(quick=not args.full):
        print(json.dumps(r))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
