"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU MLP (not gated). [arXiv:2402.16819]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=256000,
        source="arXiv:2402.16819",
        block_pattern=("attn",),
        activation="sqrelu",
        gated_mlp=False,
        rope_theta=10_000.0,
        max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("nemotron-4-15b", config, smoke)
