"""Frozen copy of the pre-refactor ``run_federated`` monolith (the seed's
src/repro/federated/simulator.py round loop), kept ONLY as the parity oracle
for tests/test_api.py: the composable FedEngine must reproduce this loop's
per-round history bit-for-bit. Do not "improve" this file — its value is
that it never changes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engine import RunResult
from repro.core.fedais import MethodConfig, batch_size_for, make_local_update
from repro.core.historical import init_historical
from repro.federated import baselines as B
from repro.federated.costs import CostMeter, DelayModel, embed_sync_bytes, model_bytes
from repro.federated.partition import FederatedGraph
from repro.federated.server import (
    build_eval_graph,
    evaluate_global,
    fedavg,
    select_clients,
    update_tau,
)
from repro.graph.data import GraphData
from repro.models.gcn import HIDDEN, gcn_flops_per_node, gcn_init, gcn_param_count


def _client_slice(fed: FederatedGraph, arrays: dict, ids: np.ndarray) -> dict:
    return {k: v[ids] for k, v in arrays.items()}


def legacy_run_federated(
    graph: GraphData,
    fed: FederatedGraph,
    mcfg: MethodConfig,
    *,
    rounds: int = 30,
    clients_per_round: int = 10,
    seed: int = 0,
    target_acc: float | None = None,
    delay: DelayModel = DelayModel(),
    eval_every: int = 1,
    verbose: bool = False,
) -> RunResult:
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    K, n_max, g_max = fed.n_clients, fed.n_max, fed.g_max
    F, H1 = fed.n_features, HIDDEN[0]

    # ---- device-resident stacked client arrays ----
    arrays = {
        "features": jnp.asarray(fed.features),
        "labels": jnp.asarray(fed.labels),
        "node_mask": jnp.asarray(fed.node_mask),
        "train_mask": jnp.asarray(fed.train_mask),
        "nbr_idx": jnp.asarray(fed.nbr_idx),
        "nbr_mask": jnp.asarray(fed.nbr_mask),
        "ghost_owner": jnp.asarray(fed.ghost_owner),
        "ghost_row": jnp.asarray(fed.ghost_row),
        "ghost_mask": jnp.asarray(fed.ghost_mask),
    }

    params = gcn_init(jax.random.PRNGKey(seed + 1), F, fed.n_classes)
    n_params = gcn_param_count(F, fed.n_classes)
    hist = init_historical(K, n_max, g_max, F, H1)
    ghost_feat = jnp.zeros((K, g_max, F), jnp.float32)
    prev_loss = jnp.full((K, n_max), -1.0, jnp.float32)

    local_update = make_local_update(mcfg, n_max, g_max, H1)
    vm = jax.jit(jax.vmap(local_update,
                          in_axes=(None, 0, None, None, 0, 0, 0, 0, None, 0, None, 0)))

    eval_graph = build_eval_graph(graph, max_deg=fed.max_deg, seed=seed)
    result = RunResult(method=mcfg.name, dataset=graph.name)

    # FedSage+ generator / FedGraph bandit state
    gen_params = None
    rev = rev_mask = None
    if mcfg.use_generator:
        gen_params = B.generator_init(jax.random.PRNGKey(seed + 2), F)
        rev_np, rev_mask_np = B.ghost_reverse_map(fed)
        rev, rev_mask = jnp.asarray(rev_np), jnp.asarray(rev_mask_np)
    bandit = B.FanoutBandit(K, seed=seed) if mcfg.bandit_fanout else None
    last_client_loss = np.zeros(K)

    avg_deg = float(fed.nbr_mask.sum() / np.maximum(fed.node_mask.sum(), 1))
    fwd_flops_node = gcn_flops_per_node(F, fed.n_classes, avg_deg)
    bsz = batch_size_for(mcfg, n_max)
    tau = mcfg.tau0
    initial_loss = None

    for t in range(rounds):
        sel = select_clients(rng, K, clients_per_round)
        sel_j = jnp.asarray(sel)
        key, *ks = jax.random.split(key, len(sel) + 1)
        keys = jnp.stack(ks)

        # fanout per client (bandit or fixed)
        if bandit is not None:
            fanouts = jnp.asarray([bandit.choose(int(k)) for k in sel], jnp.int32)
        else:
            fanouts = jnp.full((len(sel),), mcfg.neighbor_fanout, jnp.int32)

        # FedSage+ : impute ghost features + local ghost h1, train generator
        hist1_all, age_all = hist.hist1, hist.age
        if mcfg.use_generator:
            gen_params, gen_loss = B.generator_train_step(
                gen_params,
                arrays["features"].reshape(K * n_max, F),
                jnp.minimum(arrays["nbr_idx"].reshape(K * n_max, -1), n_max * K - 1),
                arrays["nbr_mask"].reshape(K * n_max, -1)
                * (arrays["nbr_idx"].reshape(K * n_max, -1) < n_max),
                arrays["node_mask"].reshape(K * n_max),
            )
            imputed = jax.vmap(B.generator_impute, in_axes=(None, 0, 0, 0, 0))(
                gen_params, arrays["features"], rev, rev_mask, arrays["ghost_mask"])
            ghost_feat = imputed

        client_data = _client_slice(fed, arrays, sel)
        out = vm(
            params, client_data, arrays["features"], hist1_all,
            hist.hist1[sel_j], hist.age[sel_j], ghost_feat[sel_j],
            prev_loss[sel_j], jnp.asarray(tau, jnp.int32), fanouts,
            jnp.asarray(t * mcfg.local_epochs, jnp.int32), keys,
        )
        new_params_stack, new_hist1, new_age, new_ghost_feat, stats = out

        # ---- merge: FedAvg + historical write-back ----
        params = fedavg(new_params_stack)
        hist = hist._replace(
            hist1=hist.hist1.at[sel_j].set(new_hist1),
            age=hist.age.at[sel_j].set(new_age),
        )
        ghost_feat = ghost_feat.at[sel_j].set(new_ghost_feat)
        prev_loss = prev_loss.at[sel_j].set(stats["loss_all"])

        # ---- cost accounting ----
        round_cost = CostMeter()
        n_sync = np.asarray(stats["n_sync"])
        n_pulled = np.asarray(stats["n_ghost_pulled"])
        sizes = fed.client_sizes[sel]
        gen_bytes = model_bytes(B.generator_param_count(F)) if mcfg.use_generator else 0.0
        per_client_compute = []
        for i, k in enumerate(sel):
            comm_model = 2 * model_bytes(n_params) + 2 * gen_bytes
            comm_embed = embed_sync_bytes(n_pulled[i], (F, H1))
            nodes_processed = sizes[i] + mcfg.local_epochs * min(bsz, max(int(sizes[i]), 1))
            flops = 3.0 * fwd_flops_node * nodes_processed          # fwd+bwd ≈ 3x fwd
            if mcfg.use_generator:
                flops += 6.0 * F * 64 * sizes[i]
            round_cost.comm_model_bytes += comm_model
            round_cost.comm_embed_bytes += comm_embed
            round_cost.compute_flops += flops
            per_client_compute.append(delay.compute_time(flops))
        o = delay.comm_time(
            round_cost.comm_embed_bytes / max(len(sel), 1) + 2 * model_bytes(n_params))
        round_cost.wall_clock_s = max(per_client_compute) + o / max(tau, 1)
        round_cost.sync_events = int(n_sync.sum())
        result.costs.add(round_cost)

        # ---- bandit reward ----
        if bandit is not None:
            mean_losses = np.asarray(stats["epoch_losses"]).mean(axis=1)
            for i, k in enumerate(sel):
                reward = last_client_loss[k] - float(mean_losses[i]) if last_client_loss[k] else 0.0
                bandit.update(int(k), reward)
                last_client_loss[k] = float(mean_losses[i])

        # ---- server eval + adaptive tau (Eq. 11) ----
        if t % eval_every == 0 or t == rounds - 1:
            ev = evaluate_global(params, eval_graph, "test")
            if initial_loss is None:
                initial_loss = max(ev["loss"], 1e-6)
            tau = update_tau(mcfg, ev["loss"], initial_loss, mcfg.tau0)
            result.record(
                round=t, test_acc=ev["acc"], test_loss=ev["loss"], f1=ev["f1"],
                auc=ev["auc"], tau=tau,
                comm_total=result.costs.comm_total_bytes,
                comm_embed=result.costs.comm_embed_bytes,
                flops=result.costs.compute_flops,
                wall_clock=result.costs.wall_clock_s,
            )
            if verbose:
                print(f"[{mcfg.name}] round {t:3d} acc={ev['acc']:.4f} "
                      f"loss={ev['loss']:.4f} tau={tau} "
                      f"comm={result.costs.comm_total_bytes/1e6:.1f}MB")
            if target_acc is not None and ev["acc"] >= target_acc:
                break

    final_eval = evaluate_global(params, eval_graph, "test")
    result.final = dict(final_eval, **result.costs.snapshot())
    return result
