"""Quantized embedding sync (repro.federated.quant) — codec + parity tier.

Three layers of contract:

* **codec properties** — per-dtype round-trip error bounds (hypothesis
  property tests over random rows plus hand-built adversarial rows:
  all-zero, single-outlier, denormal), int8 code idempotence, and the
  analytic ``wire_bytes`` accounting the dryrun/bench ledgers charge;
* **fp32 bit-inertness** — ``sync_dtype="fp32"`` is a Python-level
  passthrough, so an engine built with it replays the byte-identical
  history of an engine that never heard of the codec;
* **executor + serve parity** — bf16/int8 histories agree across the
  stepwise/fused/client-sharded/pod-sharded executors (discrete columns
  exact, losses allclose), and the quantized serving ``h1`` cache shrinks
  resident bytes by the advertised factor while still serving the same
  predictions.

CI's ``quant`` lane runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the multi-device
parity tests skip on a single-device host.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import HAVE_HYPOTHESIS, given, settings, st
from repro.api import FedEngine, SyncScheduler, method_config
from repro.federated.quant import (
    SYNC_DTYPES,
    check_sync_dtype,
    decode,
    encode,
    quant_roundtrip,
    wire_bytes,
)

pytestmark = pytest.mark.quant

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs >=8 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

EXACT_KEYS = ("tau", "comm_total", "comm_embed", "flops", "wall_clock")
CLOSE_KEYS = ("test_acc", "test_loss")

LOSSY = ("bf16", "int8")


def rt(x, dtype):
    return np.asarray(quant_roundtrip(jnp.asarray(x, jnp.float32), dtype))


# ---------------------------------------------------------------------------
# codec: dtype registry + fp32 passthrough
# ---------------------------------------------------------------------------

def test_sync_dtype_registry():
    assert SYNC_DTYPES == ("fp32", "bf16", "int8")
    for d in SYNC_DTYPES:
        assert check_sync_dtype(d) == d
    with pytest.raises(ValueError, match="sync dtype"):
        check_sync_dtype("fp8")
    with pytest.raises(ValueError, match="sync dtype"):
        check_sync_dtype(None)


def test_fp32_is_python_level_identity():
    """encode/decode/roundtrip at fp32 return the SAME object — zero trace
    ops, which is what makes sync_dtype='fp32' bit-inert through jit."""
    x = jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4)
    payload, scale = encode(x, "fp32")
    assert payload is x and scale is None
    assert decode(payload, scale, "fp32") is x
    assert quant_roundtrip(x, "fp32") is x


# ---------------------------------------------------------------------------
# codec: wire_bytes — the analytic accounting every ledger charges
# ---------------------------------------------------------------------------

def test_wire_bytes_per_dtype():
    assert wire_bytes((4, 8), "fp32") == 4 * 8 * 4
    assert wire_bytes((4, 8), "bf16") == 4 * 8 * 2
    # int8: one byte per element + one fp32 scale per row (last axis = row)
    assert wire_bytes((4, 8), "int8") == 4 * 8 + 4 * 4
    assert wire_bytes((8,), "int8") == 8 + 4          # 1-d = a single row
    assert wire_bytes((3, 4, 8), "int8") == 3 * 4 * 8 + 3 * 4 * 4
    # wide rows approach the full 4x cut; narrow rows pay the scale tax
    wide = wire_bytes((1, 4096), "fp32") / wire_bytes((1, 4096), "int8")
    narrow = wire_bytes((1, 4), "fp32") / wire_bytes((1, 4), "int8")
    assert wide > 3.99 and narrow == 2.0


def test_wire_bytes_degenerate_shapes_match_encode(rng):
    """wire_bytes must equal the bytes encode actually emits — including
    the shapes that used to mis-account: scalars (one row, one scale),
    1-D rows (one scale, not zero), and zero-width rows ((n, 0) still pays
    its n scales because the keepdims amax reduce emits an (n, 1) scale)."""
    for shape in ((), (1,), (8,), (0,), (3, 0), (0, 5), (4, 8), (2, 3, 5)):
        x = jnp.asarray(np.asarray(rng.standard_normal(shape), np.float32))
        for d in SYNC_DTYPES:
            payload, scale = encode(x, d)
            nbytes = payload.nbytes + (0 if scale is None else scale.nbytes)
            assert wire_bytes(shape, d) == nbytes, (shape, d)
            out = decode(payload, scale, d)
            assert jnp.shape(out) == shape, (shape, d)


def test_wire_bytes_monotone_and_positive():
    # rows of >=4 elements: below that, int8's 4 B/row scale tax can cost
    # more than the narrowing saves (a (1, 1) row is 5 B int8 vs 4 B fp32)
    for shape in ((1, 4), (7, 8), (2, 64), (5, 1, 9)):
        sizes = [wire_bytes(shape, d) for d in SYNC_DTYPES]
        assert sizes == sorted(sizes, reverse=True)   # fp32 >= bf16 >= int8
        assert all(s > 0 for s in sizes)


# ---------------------------------------------------------------------------
# codec: round-trip error bounds
# ---------------------------------------------------------------------------

def test_bf16_roundtrip_relative_error_bound(rng):
    x = rng.standard_normal((64, 32)).astype(np.float32) * 10.0
    err = np.abs(rt(x, "bf16") - x)
    # bfloat16 keeps 8 significand bits: round-to-nearest relative error
    # is at most 2^-9 per element (2^-8 with margin)
    assert (err <= np.abs(x) * 2.0 ** -8 + 1e-30).all()


def test_int8_roundtrip_error_bound(rng):
    x = rng.standard_normal((64, 32)).astype(np.float32) * 5.0
    err = np.abs(rt(x, "int8") - x)
    amax = np.abs(x).max(-1, keepdims=True)
    # symmetric per-row scale = amax/127; round-half-even costs at most
    # scale/2 = amax/254 per element (tiny slack for the fp32 division)
    assert (err <= amax / 254.0 * (1 + 1e-5) + 1e-30).all()


def test_int8_adversarial_rows():
    x = np.zeros((4, 8), np.float32)
    x[1, 3] = 1e6                      # single outlier, rest exact zeros
    x[2] = 1.5e-42                     # denormal row (below FLT_MIN)
    x[3] = np.linspace(-3.0, 3.0, 8)   # plain row
    payload, scale = encode(jnp.asarray(x), "int8")
    out = np.asarray(decode(payload, scale, "int8"))
    assert np.isfinite(out).all()
    # all-zero row: scale 0, decodes to EXACT zeros (masks commute)
    assert (np.asarray(scale)[0] == 0) and (out[0] == 0).all()
    # outlier row: the outlier is the amax -> code ±127, exact round-trip
    # to ~1 ulp of scale; the zero elements stay exactly zero
    assert np.isclose(out[1, 3], 1e6, rtol=1e-6)
    assert (out[1, :3] == 0).all() and (out[1, 4:] == 0).all()
    # denormal row: the scale itself lands in the subnormal range where its
    # own quantization (or an FTZ flush to the zero-row path) dominates —
    # the contract is boundedness, not precision: finite, never amplified
    assert (np.abs(out[2]) <= x[2] * 1.1).all()
    # plain row obeys the scale/2 bound
    assert (np.abs(out[3] - x[3]) <= 3.0 / 254.0 * (1 + 1e-5)).all()


def test_int8_codes_idempotent(rng):
    """Re-encoding a decoded row reproduces the int8 codes exactly — the
    property that lets executors quantize both at the semantic site and on
    a physical collective without compounding error."""
    x = jnp.asarray(rng.standard_normal((16, 24)).astype(np.float32))
    q1, s1 = encode(x, "int8")
    y = decode(q1, s1, "int8")
    q2, s2 = encode(y, "int8")
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-7)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(decode(q2, s2, "int8")))


# ---------------------------------------------------------------------------
# codec: hypothesis property tests (skip cleanly without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _elem = st.floats(min_value=-1e6, max_value=1e6,
                      allow_nan=False, allow_infinity=False, width=32)
    _row = st.lists(_elem, min_size=1, max_size=32)
    # rectangular matrices: draw a width, then rows of exactly that width
    _matrix = st.integers(min_value=1, max_value=16).flatmap(
        lambda w: st.lists(st.lists(_elem, min_size=w, max_size=w),
                           min_size=1, max_size=6))
else:  # stubbed strategies; @given skips each test at run time
    _row = _matrix = None


@settings(max_examples=50, deadline=None)
@given(_matrix)
def test_hyp_int8_per_row_scale_and_bound(rows):
    x = np.asarray(rows, np.float32)
    payload, scale = encode(jnp.asarray(x), "int8")
    scale = np.asarray(scale)
    amax = np.abs(x).max(-1, keepdims=True)
    # the scale is exactly the fp32 quotient amax/127, rows independent
    # (checked away from the subnormal range, where XLA may flush to zero)
    normal = amax[:, 0] > 1e-35
    np.testing.assert_array_equal(scale[normal],
                                  (amax / np.float32(127.0))[normal])
    out = np.asarray(decode(payload, scale, "int8"))
    assert np.isfinite(out).all()
    assert (np.abs(out - x) <= amax / 254.0 * (1 + 1e-5) + 1e-30).all()
    assert (out[amax[:, 0] == 0] == 0).all()


@settings(max_examples=50, deadline=None)
@given(_row)
def test_hyp_bf16_bound_and_fp32_exact(row):
    x = np.asarray([row], np.float32)
    err = np.abs(rt(x, "bf16") - x)
    assert (err <= np.abs(x) * 2.0 ** -8 + 1e-30).all()
    np.testing.assert_array_equal(rt(x, "fp32"), x)


# ---------------------------------------------------------------------------
# engine: fp32 bit-inertness + lossy-dtype parity across executors
# ---------------------------------------------------------------------------

def _run(g, fed, *, mesh=None, m=4, rounds=4, seed=0, **kw):
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), seed=seed,
                    rounds=rounds, clients_per_round=m, eval_every=2,
                    mesh=mesh, **kw)
    return eng, eng.run()


def _assert_allclose_history(ref, got):
    for k in EXACT_KEYS:
        assert ref.history[k] == got.history[k], f"history[{k!r}] diverged"
    for k in CLOSE_KEYS:
        np.testing.assert_allclose(
            np.asarray(got.history[k], np.float64),
            np.asarray(ref.history[k], np.float64),
            rtol=1e-4, atol=1e-6, err_msg=f"history[{k!r}]")


def test_engine_rejects_unknown_sync_dtype(small_fed):
    g, fed = small_fed
    with pytest.raises(ValueError, match="sync dtype"):
        FedEngine(g, fed, method_config("fedais"), sync_dtype="fp16")


def test_fp32_sync_dtype_is_bit_inert(small_fed):
    """sync_dtype='fp32' must replay the history of an engine that never
    passed the argument, bit-for-bit — the codec lowers to nothing."""
    g, fed = small_fed
    _, base = _run(g, fed)
    _, fp32 = _run(g, fed, sync_dtype="fp32")
    assert base.history == fp32.history
    assert base.final == fp32.final


@pytest.mark.parametrize("dtype", LOSSY)
def test_stepwise_matches_fused_per_dtype(small_fed, dtype):
    """The stepwise and fused executors quantize at the same semantic site
    (the write-back rows), so their histories agree within ~1 ulp of the
    re-derived int8 scale (bf16 lands bitwise; int8 may differ in the last
    float of the loss) — discrete columns stay exact either way."""
    g, fed = small_fed
    _, step = _run(g, fed, sync_dtype=dtype,
                   scheduler=SyncScheduler(fused=False))
    _, fused = _run(g, fed, sync_dtype=dtype,
                    scheduler=SyncScheduler(fused=None))
    _assert_allclose_history(step, fused)


def test_int8_perturbs_trajectory_but_converges(small_fed):
    """int8 is genuinely lossy — the loss trajectory must move — while the
    run still trains (finite losses, sane final accuracy)."""
    g, fed = small_fed
    _, fp32 = _run(g, fed, rounds=6)
    _, int8 = _run(g, fed, rounds=6, sync_dtype="int8")
    assert int8.history["test_loss"] != fp32.history["test_loss"]
    assert np.isfinite(int8.history["test_loss"]).all()
    assert abs(int8.final["acc"] - fp32.final["acc"]) < 0.2


@needs_devices
@pytest.mark.parametrize("dtype", LOSSY)
def test_executor_parity_quantized(small_fed, dtype):
    """bf16/int8: fused vs client-sharded vs pod-sharded — same quantized
    rows enter the tables everywhere, so discrete columns stay exact and
    losses allclose, exactly as in the fp32 parity tier."""
    from repro.sharding.fed import make_client_mesh
    from repro.sharding.tables import make_pod_mesh

    g, fed = small_fed
    eng_f, res_f = _run(g, fed, sync_dtype=dtype)
    eng_c, res_c = _run(g, fed, sync_dtype=dtype, mesh=make_client_mesh(8))
    eng_p, res_p = _run(g, fed, sync_dtype=dtype, mesh=make_pod_mesh(4, 2))
    assert eng_f.last_executor == "fused"
    assert eng_c.last_executor == "sharded_fused"
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_f, res_c)
    _assert_allclose_history(res_f, res_p)


@needs_devices
def test_pod_gated_rounds_stay_gated_under_int8(small_fed):
    """tau0=8 gates the ghost exchange off on some rounds; quantizing the
    wire must not change WHICH rounds sync (comm bytes stay exact vs the
    int8 fused run)."""
    from repro.sharding.tables import make_pod_mesh

    g, fed = small_fed
    _, res_f = _run(g, fed, sync_dtype="int8",
                    scheduler=SyncScheduler(fused=None))
    eng_p, res_p = _run(g, fed, sync_dtype="int8", mesh=make_pod_mesh(2, 4))
    assert eng_p.last_executor == "pod_sharded"
    _assert_allclose_history(res_f, res_p)


# ---------------------------------------------------------------------------
# serve: quantized resident h1 cache
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """A tiny trained + checkpointed federation for cache-dtype tests."""
    from repro.federated.partition import partition_graph
    from repro.graph.data import make_dataset
    from repro.serve import save_federation

    g = make_dataset("pubmed", scale=32, seed=0)
    fed = partition_graph(g, 4, alpha=0.5, seed=0)
    eng = FedEngine(g, fed, method_config("fedais", tau0=2), rounds=2,
                    clients_per_round=2, seed=0, eval_every=2)
    state = eng.init_state()
    eng.run(state)
    ckpt = str(tmp_path_factory.mktemp("quant_ckpt"))
    save_federation(ckpt, 2, state)
    return g, fed, ckpt


def _serve_logits(model):
    from repro.serve import QueryEngine

    engine = QueryEngine(model)
    engine.warmup()
    n = model.n_active
    return np.concatenate([
        engine.query(np.arange(i, min(i + 64, n)), policy="historical")
        for i in range(0, n, 64)])


def test_cache_dtype_resident_bytes(served):
    from repro.serve import ServedModel

    g, fed, ckpt = served
    models = {d: ServedModel.restore(ckpt, g, fed, seed=0, cache_dtype=d)
              for d in SYNC_DTYPES}
    cap = models["fp32"].store.capacity
    H1 = models["fp32"].h1.shape[-1]
    assert models["fp32"].cache_resident_bytes() == cap * H1 * 4
    assert models["bf16"].cache_resident_bytes() == cap * H1 * 2
    assert models["int8"].cache_resident_bytes() == cap * H1 + cap * 4
    for d, m in models.items():
        s = m.summary()
        assert s["cache_dtype"] == d
        assert s["cache_resident_bytes"] == m.cache_resident_bytes()
        assert np.isfinite(np.asarray(m.h1_f32())).all()


def test_cache_fp32_restore_is_bit_inert(served):
    from repro.serve import ServedModel

    g, fed, ckpt = served
    base = ServedModel.restore(ckpt, g, fed, seed=0)
    fp32 = ServedModel.restore(ckpt, g, fed, seed=0, cache_dtype="fp32")
    np.testing.assert_array_equal(np.asarray(base.h1), np.asarray(fp32.h1))
    np.testing.assert_array_equal(_serve_logits(base), _serve_logits(fp32))


@pytest.mark.parametrize("dtype", LOSSY)
def test_quantized_cache_serves_same_predictions(served, dtype):
    """Dequant-on-read: the lossy cache may move logits a little but the
    served predictions stay overwhelmingly the ones the fp32 cache serves
    (the BENCH_serve cache column's accuracy is measured the same way)."""
    from repro.serve import ServedModel

    g, fed, ckpt = served
    want = _serve_logits(ServedModel.restore(ckpt, g, fed, seed=0))
    got = _serve_logits(
        ServedModel.restore(ckpt, g, fed, seed=0, cache_dtype=dtype))
    assert got.shape == want.shape and np.isfinite(got).all()
    agree = (got.argmax(-1) == want.argmax(-1)).mean()
    assert agree >= 0.95, f"{dtype}: argmax agreement {agree:.3f}"
