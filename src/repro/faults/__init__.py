"""repro.faults — deterministic fault injection + graceful degradation.

``FaultPlan`` describes seeded faults (dropout / stragglers / corrupt
uploads / torn checkpoint writes); ``UpdateGuard`` + ``guard_mask`` are
the merge-side admission rule; ``FaultCounters`` is the per-run ledger on
``EngineState.fault_events``; ``build_faulty_chunk`` is the fault-aware
fused executor. See ``launch/fed_chaos.py`` for the end-to-end harness.
"""
from repro.faults.fused import build_faulty_chunk
from repro.faults.plan import (
    CORRUPT_MODES,
    FaultCounters,
    FaultPlan,
    UpdateGuard,
    corrupt_params_stack,
    guard_mask,
    tear_file,
)

__all__ = [
    "FaultPlan", "FaultCounters", "UpdateGuard", "guard_mask",
    "corrupt_params_stack", "tear_file", "build_faulty_chunk",
    "CORRUPT_MODES",
]
