"""Cross-process determinism regression: two fresh interpreters running the
same seeded FedAIS config must produce bit-identical histories.

This broke before PR 2 for two stacked reasons: ``make_dataset`` seeded its
RNG from the salted builtin ``hash(name)`` (a different dataset per process),
and ``sample_batch`` ranked raw float keys, letting last-ULP jitter in the
loss pass flip importance-sampled batches. The subprocesses below force
different ``PYTHONHASHSEED`` values so any reintroduced hash-order dependence
fails loudly.
"""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = """
import json, sys
from repro.graph.data import make_dataset
from repro.federated.partition import partition_graph
from repro.api import FedEngine, method_config

g = make_dataset("pubmed", scale=16, seed=0)
fed = partition_graph(g, 4, alpha=0.5, seed=0)
res = FedEngine(g, fed, method_config("fedais", tau0=2), rounds=2,
                clients_per_round=3, seed=0).run()
hist = {k: [float(v) for v in vs] for k, vs in res.history.items()}
print(json.dumps({"history": hist, "final_acc": float(res.final["acc"]),
                  "final_comm": float(res.final["comm_total_bytes"])}))
"""


def _fresh_process_run(hashseed: str) -> dict:
    env = dict(os.environ,
               PYTHONHASHSEED=hashseed,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_seeded_runs_are_bit_identical_across_processes():
    a = _fresh_process_run("0")
    b = _fresh_process_run("4242")
    assert a["history"].keys() == b["history"].keys()
    for k in ("comm_total", "test_acc", "test_loss", "flops", "wall_clock"):
        assert a["history"][k] == b["history"][k], \
            f"history[{k!r}] diverged across processes"
    assert a == b


def test_dataset_generation_is_hash_salt_free():
    """make_dataset must derive its RNG stream from a stable string hash."""
    from repro.graph.data import make_dataset
    from repro.utils.tree import stable_hash

    g1 = make_dataset("pubmed", scale=32, seed=3)
    g2 = make_dataset("pubmed", scale=32, seed=3)
    np.testing.assert_array_equal(g1.features, g2.features)
    np.testing.assert_array_equal(g1.edges, g2.edges)
    # the stream is pinned to the FNV-1a hash, not builtin hash()
    assert stable_hash("pubmed") == 1307698282
