"""Pod-sharded placement: no per-device resident or collective scales with K.

Client sharding (repro.sharding.fed) splits each round's cohort across
devices but replicates all global state. This module places EVERY K-sized
array — the (K, n_tot, H1) ``hist1``/``age`` tables, the (K, g_max, F)
synced-ghost and (K, n_max) prev-loss tables, AND the static client arrays
(features, padded adjacency, labels/masks) — as pod shards over a
``("pods", "clients")`` 2-D mesh: pod p owns the rows of its resident
clients (the K axis block-partitioned with ``NamedSharding``, zero-row
padded to divisibility by the same ``pod_table_padding`` contract), while
each round's cohort still splits over all P×C devices. Four exchanges
replace the replicated dataflow, each sized by what the round touches:

* **owner-keyed cohort fetch** — the m selected clients' table rows AND
  static arrays are pulled from their owner pods by a masked psum (each
  row has exactly one non-zero contributor), O(m·row) bytes. Cohort
  dummies (id Kp) have no owner and fetch zeros — every consumer of
  all-zero client data is NaN-guarded, and the dummy's outputs are
  discarded anyway (weight 0, write-back dropped).
* **gated ghost-bucket all-to-all** — the cross-pod layer-1 embedding
  sync (``federated.partition.ghost_exchange_buckets``), now under a
  ``lax.cond`` on a host-derived per-round predicate
  (``sync_round_gates``): the tau schedule decides on the host whether ANY
  of the round's J local epochs syncs, and non-sync rounds skip the
  exchange entirely — zero bytes, not masked bytes. Bit-parity holds
  because the LocalUpdate never reads the prefetched sources on such
  rounds (its per-epoch ``do_sync`` cond derives from the same eoff/tau).
* **static ghost-feature fetch** — the layer-0 ghost sources come from a
  partition-time bucketed owner exchange
  (``federated.partition.exchange_ghost_features``) that materializes a
  pod-sharded (Kp, g_max, F) source table once; per round the cohort's
  rows ride the same gated owner-keyed fetch.
* **cohort-keyed write-back** — fresh rows all-gather only within the pod
  row (m/P rows), then a host-routed bucket ``all_to_all``
  (``federated.partition.writeback_routing``) delivers each row straight
  to its owner pod — P·cap rows per device, cap ≈ m/P² in expectation,
  instead of the dense m-row cohort all-gather.

Aggregation stays the weighted psum all-reduce of the client-sharded
executor, with ``reduce="pairwise"`` for the deterministic fp32 tree
(``sharding.fed.weighted_merge``).

Parity contract (tests/test_pod_sharding.py): history is allclose to the
client-sharded and unsharded fused runs with every discrete column exact —
the per-client computation is identical (``pull_ghosts_prefetched`` hands
each client the same round-start snapshot rows; skipped exchanges feed
rounds that never read them), only the merge's summation order differs.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.federated.partition import GhostBuckets, pod_table_padding
from repro.federated.quant import check_sync_dtype
from repro.federated.quant import decode as quant_decode
from repro.federated.quant import encode as quant_encode
from repro.sharding.fed import CLIENT_AXIS, pairwise_sum, weighted_merge

__all__ = [
    "POD_AXIS", "make_pod_mesh", "pod_axes_of", "pad_tables_to_pods",
    "shard_tables_to_mesh", "pairwise_sum", "sync_round_gates",
    "build_pod_sharded_chunk", "abstract_pod_chunk_args",
]

POD_AXIS = "pods"

# client-array keys the pod-sharded executor keeps on device. The
# "prefetched" LocalUpdate never reads ghost_owner/ghost_row (the bucketed
# exchanges already routed by them on the host), so those two stay off the
# mesh entirely.
POD_ARRAY_KEYS = ("features", "labels", "node_mask", "train_mask",
                  "nbr_idx", "nbr_mask", "ghost_mask")


def make_pod_mesh(n_pods: int, n_client_shards: Optional[int] = None) -> Mesh:
    """A ``(n_pods, n_client_shards)`` mesh with ``("pods", "clients")``
    axes: tables shard over the first, each round's cohort over both. With
    ``n_client_shards=None`` all visible devices are used (they must split
    evenly). On CPU, force fake devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devs = jax.devices()
    if n_pods < 1:
        raise ValueError(f"need n_pods >= 1, got {n_pods}")
    if n_client_shards is None:
        if len(devs) % n_pods:
            raise ValueError(
                f"{len(devs)} devices do not split into {n_pods} pods; pass "
                "n_client_shards explicitly")
        n_client_shards = len(devs) // n_pods
    n = n_pods * n_client_shards
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_pod_mesh needs 1..{len(devs)} devices, asked for "
            f"{n_pods}x{n_client_shards} (force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n_pods, n_client_shards), (POD_AXIS, CLIENT_AXIS),
                         devices=devs[:n])


def pod_axes_of(mesh: Mesh) -> Optional[tuple[str, str]]:
    """The (table, cohort) axis pair of a pod mesh: ``("pods", "clients")``
    when both axes are present, else None (not a pod mesh)."""
    if POD_AXIS in mesh.shape and CLIENT_AXIS in mesh.shape:
        return (POD_AXIS, CLIENT_AXIS)
    return None


def pad_tables_to_pods(tables, n_pods: int):
    """Pad every (K, ...) leaf of a pytree (tuple of tables, dict of client
    arrays) with zero rows so K splits evenly over the pod axis. Returns
    the same structure (unchanged when already divisible)."""
    leaves = jax.tree_util.tree_leaves(tables)
    K = leaves[0].shape[0]
    pad = pod_table_padding(K, n_pods)      # the bucket builder's Kp rule
    if not pad:
        return tables
    return jax.tree_util.tree_map(
        lambda t: jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1)), tables)


def shard_tables_to_mesh(tables, mesh: Mesh):
    """Commit every (Kp, ...) leaf to the mesh sharded over the pod axis on
    its leading (client) dimension — pod p holds its residents' rows,
    replicated across the ``"clients"`` axis. Works on any pytree (the
    four-table tuple, the static client-array dict, a lone gsrc array)."""
    sh = NamedSharding(mesh, P(POD_AXIS))
    return jax.tree_util.tree_map(lambda t: jax.device_put(t, sh), tables)


def sync_round_gates(eoffs, tau: int, local_epochs: int, *,
                     enabled: bool = True) -> np.ndarray:
    """Host-derived per-round sync predicate: does ANY of the round's J
    local epochs hit the tau schedule? Epoch j of a round with epoch
    offset e syncs iff ``(e + j) % max(tau, 1) == 0`` (the LocalUpdate's
    ``do_sync``, with ``enabled = use_ghosts and not use_generator``
    folding in the method's static toggles). tau is a host int between
    chunks (the sync controller updates it at eval boundaries), so the
    gate is exact — rounds where it is False skip the ghost exchanges
    entirely and contribute zero collective bytes."""
    eoffs = np.asarray(eoffs, np.int64).reshape(-1)
    if not enabled:
        return np.zeros(eoffs.shape, bool)
    t = max(int(tau), 1)
    j = np.arange(int(local_epochs), dtype=np.int64)
    return (((eoffs[:, None] + j) % t) == 0).any(axis=1)


def _pod_step(vm, mesh: Mesh, buckets: GhostBuckets, reduce: str,
              sync_dtype: str = "fp32"):
    """The per-round client half over a ``("pods", "clients")`` mesh:
    owner-keyed cohort fetch of static arrays + table rows, the gated ghost
    exchange, vmapped LocalUpdate on each device's cohort slice, weighted
    merge, and the bucket-routed write-back. Pod-sharded in/out specs are
    P("pods"); cohort specs P(("pods", "clients")); routing replicated.

    ``sync_dtype`` quantizes the two embedding wires (repro.federated.
    quant): the gated ghost all-to-all and the write-back bucket exchange
    physically move codec payloads (int8 codes + per-row fp32 scales, or
    bf16 halves) and decode at the receiver. The int32 ``age`` table and
    the routing metadata always ride unquantized; merge accumulators stay
    fp32. ``"fp32"`` leaves the lowered collectives byte-identical."""
    check_sync_dtype(sync_dtype)
    P_, C = mesh.shape[POD_AXIS], mesh.shape[CLIENT_AXIS]
    rpp = buckets.rows_per_pod
    axes = (POD_AXIS, CLIENT_AXIS)

    def step(params, arrays, gsrc, hist_sh, age_sh, gfeat_sh, pl_sh,
             sel, tau, fanouts, eoff, keys, w, gate, wdst, wpos, wrecv,
             send_client, send_row, send_mask, recv_src, recv_pos, recv_mask):
        p_i = jax.lax.axis_index(POD_AXIS)
        c_i = jax.lax.axis_index(CLIENT_AXIS)
        mL = keys.shape[0]
        msl = C * mL                       # one pod row's cohort slice

        # ---- owner-keyed fetch of the cohort's rows (tables + statics) ----
        # exactly one (pod, clients=0) device contributes each row; the psum
        # broadcasts it (ints stay exact, floats gain only +0.0 terms).
        # Dummies (id Kp) have owner_pod == P_ — nobody contributes, they
        # train on all-zero data and their outputs are discarded anyway.
        owner_pod = sel // rpp
        local_row = jnp.clip(sel - owner_pod * rpp, 0, rpp - 1)
        own = (owner_pod == p_i) & (c_i == 0)

        def fetch(tbl):
            rows = jnp.where(own.reshape((-1,) + (1,) * (tbl.ndim - 1)),
                             tbl[local_row], 0)
            return jax.lax.psum(rows, axes)

        d = p_i * C + c_i

        def cohort_fetch(tbl):
            return jax.lax.dynamic_slice_in_dim(fetch(tbl), d * mL, mL, 0)

        client = {k: cohort_fetch(v) for k, v in arrays.items()}
        hist_l = cohort_fetch(hist_sh)
        age_l = cohort_fetch(age_sh)
        gfeat_l = cohort_fetch(gfeat_sh)
        pl_l = cohort_fetch(pl_sh)

        # ---- gated ghost exchange: only when the tau schedule syncs ----
        # the whole block — bucketed hist1 all-to-all, recv reassembly, and
        # both ghost-source cohort fetches — sits under one lax.cond on the
        # replicated host-derived gate, so non-sync rounds move ZERO bytes.
        # The zeros branch is safe: the LocalUpdate's per-epoch do_sync is
        # False for every epoch of a gated-off round, so it never reads them.
        g_max = recv_src.shape[1]
        H1 = hist_sh.shape[-1]

        def with_sync(_):
            # send_* arrive (1, P, B) — this pod's row of the (P, P, B) plan
            sc, sr, sm = send_client[0], send_row[0], send_mask[0]
            sbuf = hist_sh[sc, sr] * sm[..., None]              # (P, B, H1)
            # the all-to-all moves codec payloads (int8 codes + per-row
            # fp32 scales / bf16 halves) and decodes at the receiver; per-
            # row encoding commutes with the send gather, so the decoded
            # rows equal the "tables"-mode pull's round-trip bit-for-bit
            q, s = quant_encode(sbuf, sync_dtype)
            rq = jax.lax.all_to_all(q, POD_AXIS, 0, 0, tiled=True)
            rs = (jax.lax.all_to_all(s, POD_AXIS, 0, 0, tiled=True)
                  if s is not None else None)
            rbuf = quant_decode(rq, rs, sync_dtype)
            gh_res = rbuf[recv_src, recv_pos] * recv_mask[..., None]
            return cohort_fetch(gh_res), cohort_fetch(gsrc)

        def without_sync(_):
            return (jnp.zeros((mL, g_max, H1), hist_sh.dtype),
                    jnp.zeros((mL, g_max, gsrc.shape[-1]), gsrc.dtype))

        ghs_l, gfs_l = jax.lax.cond(gate, with_sync, without_sync, None)

        out = vm(params, client, gfs_l, ghs_l, hist_l, age_l, gfeat_l, pl_l,
                 tau, fanouts, eoff, keys)
        new_params, new_hist1, new_age, new_gfeat, stats = out

        # ---- aggregation: weighted all-reduce, or fp32 pairwise tree ----
        wmean = weighted_merge(axes, w, reduce)
        agg = jax.tree_util.tree_map(wmean, new_params, params)

        # ---- cohort-keyed bucket write-back ----
        # stage 1: gather the pod row's cohort slice (m/P rows) across the
        # clients axis — device order makes slice index i = global cohort
        # index p_i*msl + i, matching the host routing. stage 2: scatter
        # rows into per-destination send buckets (dummy dst == P_ drops) and
        # swap with one pods all-to-all; each pod lands its received rows at
        # the host-routed local targets (sentinel rpp drops unused slots).
        dst = jax.lax.dynamic_slice_in_dim(wdst, p_i * msl, msl, 0)
        pos = jax.lax.dynamic_slice_in_dim(wpos, p_i * msl, msl, 0)
        tgt = jax.lax.dynamic_slice_in_dim(wrecv, p_i, 1, 0)[0].reshape(-1)
        cap = wrecv.shape[-1]

        def route(x):
            rows = jax.lax.all_gather(x, CLIENT_AXIS, axis=0, tiled=True)
            sbuf = jnp.zeros((P_, cap) + rows.shape[1:], x.dtype)
            sbuf = sbuf.at[dst, pos].set(rows)
            rbuf = jax.lax.all_to_all(sbuf, POD_AXIS, 0, 0, tiled=True)
            return rbuf.reshape((P_ * cap,) + rbuf.shape[2:])

        def write_back(table, fresh):
            # float tables ride the exchange as codec payloads (codes +
            # scales both take the gather/scatter/all-to-all route); the
            # int32 age table and the fp32 passthrough skip the codec
            if sync_dtype != "fp32" and jnp.issubdtype(fresh.dtype, jnp.floating):
                q, s = quant_encode(fresh, sync_dtype)
                rows = quant_decode(route(q),
                                    route(s) if s is not None else None,
                                    sync_dtype)
            else:
                rows = route(fresh)
            return table.at[tgt].set(rows)

        hist_sh = write_back(hist_sh, new_hist1)
        age_sh = write_back(age_sh, new_age)
        gfeat_sh = write_back(gfeat_sh, new_gfeat)
        pl_sh = write_back(pl_sh, stats["loss_all"])
        return agg, hist_sh, age_sh, gfeat_sh, pl_sh, stats

    t, c, r = P(POD_AXIS), P(axes), P()
    return shard_map(
        step, mesh=mesh,
        in_specs=(r, t, t, t, t, t, t, r, r, c, r, c, c, r, r, r, r,
                  t, t, t, t, t, t),
        out_specs=(r, t, t, t, t, c),
        check_rep=False)


def build_pod_sharded_chunk(vm, mesh: Mesh, m_real: int,
                            buckets: GhostBuckets,
                            light_stats: Sequence[str], *,
                            reduce: str = "psum",
                            sync_dtype: str = "fp32"):
    """The pod-sharded twin of ``sharding.fed.build_sharded_chunk``: one
    jitted donated chunk scanning ``round_step`` over S rounds with the
    historical tables AND static client arrays resident as pod shards.

    Signature (vs the client-sharded chunk): ``arrays`` carries only the
    ``POD_ARRAY_KEYS`` leaves padded to ``buckets.n_clients_padded`` rows
    and committed with ``P("pods")`` shardings (``pad_tables_to_pods`` +
    ``shard_tables_to_mesh``), ``gsrc`` is the partition-time (Kp, g_max,
    F) ghost-source feature table, and three host-routed per-round stacks
    follow tau: ``gates`` (S,) bool from ``sync_round_gates``, and the
    ``writeback_routing`` plan's ``wb_dst``/``wb_pos`` (S, m) +
    ``wb_recv`` (S, P, P, cap). ``vm`` must be the
    ``ghost_source="prefetched"`` vmapped LocalUpdate. Cohort padding uses
    dummy id ``n_clients_padded`` (no owner pod: fetches zero, write-backs
    drop). ``reduce`` picks the merge: ``"psum"`` (weighted all-reduce) or
    ``"pairwise"`` (fp32 tree). ``sync_dtype`` quantizes the ghost
    all-to-all and write-back exchanges on the physical wire (``vm`` must
    be built with the same ``sync_dtype`` so all executors agree)."""
    if reduce not in ("psum", "pairwise"):
        raise ValueError(f"unknown reduce {reduce!r}; known: psum | pairwise")
    step = _pod_step(vm, mesh, buckets, reduce, sync_dtype)
    light_stats = tuple(light_stats)
    bkt = tuple(jnp.asarray(a) for a in (
        buckets.send_client, buckets.send_row, buckets.send_mask,
        buckets.recv_src, buckets.recv_pos, buckets.recv_mask))

    def chunk(params, hist1, age, ghost_feat, prev_loss, key, arrays, gsrc,
              sel_stack, fan_stack, w_stack, eoffs, tau, gates,
              wb_dst, wb_pos, wb_recv):
        m_pad = sel_stack.shape[1]
        pad = m_pad - m_real

        def round_step(carry, xs):
            params, hist1, age, ghost_feat, prev_loss, key = carry
            sel, fanouts, w, eoff, gate, wdst, wpos, wrecv = xs
            # the unsharded executor's exact key chain: split for the real
            # cohort only, dummies ride along on a constant zero key
            ks = jax.random.split(key, m_real + 1)
            key, keys = ks[0], ks[1:]
            if pad:
                keys = jnp.concatenate(
                    [keys, jnp.zeros((pad,) + keys.shape[1:], keys.dtype)])
            out = step(params, arrays, gsrc, hist1, age, ghost_feat,
                       prev_loss, sel, tau, fanouts, eoff, keys, w, gate,
                       wdst, wpos, wrecv, *bkt)
            params, hist1, age, ghost_feat, prev_loss, stats = out
            light = {k: stats[k][:m_real] for k in light_stats}
            return (params, hist1, age, ghost_feat, prev_loss, key), light

        return jax.lax.scan(round_step,
                            (params, hist1, age, ghost_feat, prev_loss, key),
                            (sel_stack, fan_stack, w_stack, eoffs, gates,
                             wb_dst, wb_pos, wb_recv))

    return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4, 5))


def abstract_pod_chunk_args(mesh: Mesh, buckets: GhostBuckets, *,
                            n_clients: int, cohort: int, n_max: int,
                            g_max: int, n_feat: int, n_classes: int,
                            max_deg: int = 16, rounds: int = 1,
                            wb_cap: Optional[int] = None):
    """ShapeDtypeStructs matching ``build_pod_sharded_chunk``'s signature:
    the four tables, the static client arrays, and the ghost-source table
    all padded to ``buckets.n_clients_padded`` rows with ``P("pods")``
    NamedShardings; cohort stacks, sync gates, and write-back routing
    replicated. ``wb_cap`` fixes the bucket capacity (default: the
    worst-case pow2(cohort / P) — every slice row owned by one pod). The
    ``--pods`` dry-run path."""
    from repro.models.gcn import HIDDEN, gcn_init

    P_ = mesh.shape[POD_AXIS]
    t = NamedSharding(mesh, P(POD_AXIS))
    r = NamedSharding(mesh, P())
    Kp, n_tot = buckets.n_clients_padded, n_max + g_max
    if wb_cap is None:
        msl = max(1, cohort // P_)
        wb_cap = 1 << (msl - 1).bit_length()

    def ts(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=t)

    def rs(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=r)

    params = jax.eval_shape(
        lambda: gcn_init(jax.random.PRNGKey(0), n_feat, n_classes))
    params = jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=r),
        params)
    arrays = {
        "features": ts((Kp, n_max, n_feat), jnp.float32),
        "labels": ts((Kp, n_max), jnp.int32),
        "node_mask": ts((Kp, n_max), jnp.float32),
        "train_mask": ts((Kp, n_max), jnp.float32),
        "nbr_idx": ts((Kp, n_max, max_deg), jnp.int32),
        "nbr_mask": ts((Kp, n_max, max_deg), jnp.float32),
        "ghost_mask": ts((Kp, g_max), jnp.float32),
    }
    return (
        params,
        ts((Kp, n_tot, HIDDEN[0]), jnp.float32),   # hist1
        ts((Kp, n_tot), jnp.int32),                # age
        ts((Kp, g_max, n_feat), jnp.float32),      # ghost features
        ts((Kp, n_max), jnp.float32),              # prev loss
        rs((2,), jnp.uint32),                      # PRNG key chain head
        arrays,
        ts((Kp, g_max, n_feat), jnp.float32),      # gsrc (static ghost feats)
        rs((rounds, cohort), jnp.int32),           # sel_stack
        rs((rounds, cohort), jnp.int32),           # fan_stack
        rs((rounds, cohort), jnp.float32),         # w_stack
        rs((rounds,), jnp.int32),                  # eoffs
        rs((), jnp.int32),                         # tau
        rs((rounds,), jnp.bool_),                  # sync gates
        rs((rounds, cohort), jnp.int32),           # wb_dst
        rs((rounds, cohort), jnp.int32),           # wb_pos
        rs((rounds, P_, P_, wb_cap), jnp.int32),   # wb_recv
    )
