"""Integration + property tests for the federated runtime (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FedEngine
from repro.federated.baselines import method_config
from repro.federated.partition import partition_graph
from repro.federated.server import fedavg, fedavg_weighted, macro_f1, macro_ovr_auc
from repro.federated.simulator import run_federated  # legacy shim over FedEngine
from repro.graph.data import DATASET_SPECS, downsample_edges, make_dataset
from repro.models.gcn import gcn_batch_forward, gcn_full_forward, gcn_init, per_node_loss


# ---------------------------------------------------------------------------
# graph substrate
# ---------------------------------------------------------------------------

def test_dataset_specs_match_table1():
    assert DATASET_SPECS["reddit"].n_nodes == 232_965
    assert DATASET_SPECS["amazon2m"].n_nodes == 2_449_029
    assert DATASET_SPECS["yelp"].n_classes == 100
    assert DATASET_SPECS["pubmed"].n_features == 500


def test_make_dataset_deterministic():
    a = make_dataset("pubmed", scale=32, seed=3)
    b = make_dataset("pubmed", scale=32, seed=3)
    np.testing.assert_array_equal(a.edges, b.edges)
    np.testing.assert_allclose(a.features, b.features)


def test_downsample_edges():
    g = make_dataset("pubmed", scale=32, seed=0)
    g2 = downsample_edges(g, keep=0.5, seed=0)
    assert 0.35 * len(g.edges) < len(g2.edges) < 0.65 * len(g.edges)


def test_splits_disjoint_and_complete():
    g = make_dataset("coauthor", scale=32, seed=0)
    total = g.train_mask.astype(int) + g.val_mask.astype(int) + g.test_mask.astype(int)
    assert (total == 1).all()


# ---------------------------------------------------------------------------
# partition
# ---------------------------------------------------------------------------

def test_partition_preserves_nodes(small_fed):
    g, fed = small_fed
    assert int(fed.node_mask.sum()) == g.n_nodes
    ids = fed.global_ids[fed.node_mask > 0]
    assert sorted(ids.tolist()) == list(range(g.n_nodes))


def test_partition_ghost_consistency(small_fed):
    """Every ghost points at a real row of its owner, never at self."""
    g, fed = small_fed
    K = fed.n_clients
    for k in range(K):
        live = fed.ghost_mask[k] > 0
        owners = fed.ghost_owner[k][live]
        rows = fed.ghost_row[k][live]
        assert (owners != k).all()
        assert ((owners >= 0) & (owners < K)).all()
        for o, r in zip(owners, rows):
            assert fed.node_mask[o, r] == 1.0


def test_partition_noniid_skew():
    """Dirichlet(0.1) must concentrate labels much more than iid."""
    g = make_dataset("coauthor", scale=32, seed=0)
    iid = partition_graph(g, 8, alpha=None, seed=0)
    non = partition_graph(g, 8, alpha=0.1, seed=0)

    def label_entropy(fed):
        ents = []
        for k in range(fed.n_clients):
            lbl = fed.labels[k][fed.node_mask[k] > 0]
            if len(lbl) < 2:
                continue
            p = np.bincount(lbl, minlength=g.n_classes) / len(lbl)
            p = p[p > 0]
            ents.append(-(p * np.log(p)).sum())
        return np.mean(ents)

    assert label_entropy(non) < label_entropy(iid) - 0.2


def test_cross_edges_counted(small_fed):
    g, fed = small_fed
    assert fed.n_cross_edges > 0
    assert fed.ghost_mask.sum() > 0


# ---------------------------------------------------------------------------
# GCN with historical embeddings
# ---------------------------------------------------------------------------

def test_gcn_batch_vs_full_consistency(key, rng):
    """With ALL nodes in batch and exact ghost tables, the pruned batch
    forward must equal the exact full forward on an isolated client."""
    n, F, C = 20, 8, 3
    params = gcn_init(key, F, C)
    feats = jnp.asarray(rng.standard_normal((n, F)), jnp.float32)
    # within-client-only adjacency
    idx = jnp.asarray(rng.integers(0, n, (n, 4)), jnp.int32)
    mask = jnp.asarray((rng.random((n, 4)) < 0.8), jnp.float32)
    ghost_feat = jnp.zeros((1, F))
    hist1 = jnp.zeros((n + 1, 256))
    logits_b, h1, _ = gcn_batch_forward(params, feats, ghost_feat, hist1,
                                        idx, mask, jnp.arange(n))
    logits_f = gcn_full_forward(params, feats, idx, mask)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_f), atol=1e-5)


def test_historical_gradient_isolation(key, rng):
    """Gradients must not flow through historical (out-of-batch) entries."""
    n, F, C = 10, 4, 2
    params = gcn_init(key, F, C)
    feats = jnp.asarray(rng.standard_normal((n, F)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, 3)), jnp.int32)
    mask = jnp.ones((n, 3), jnp.float32)
    hist1 = jnp.asarray(rng.standard_normal((n + 1, 256)), jnp.float32)
    batch = jnp.asarray([0, 1, 2])

    def loss(h):
        logits, _, _ = gcn_batch_forward(params, feats, jnp.zeros((1, F)), h,
                                         idx, mask, batch)
        return per_node_loss(logits, jnp.zeros(3, jnp.int32)).sum()

    g = jax.grad(loss)(hist1)
    assert float(jnp.abs(g).sum()) == 0.0   # stop_gradient on history


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

def test_fedavg_mean():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    out = fedavg(stacked)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0])


def test_fedavg_weighted():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    out = fedavg_weighted(stacked, jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5])


def test_macro_metrics_perfect():
    labels = np.asarray([0, 1, 2, 0])
    logits = np.eye(3)[labels] * 10.0
    assert macro_f1(labels, labels, 3) == 1.0
    assert macro_ovr_auc(labels, logits) == 1.0


# ---------------------------------------------------------------------------
# end-to-end federated runs (Algorithm 1)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ["fedais", "fedall", "fedrandom", "fedpns",
                                    "fedgraph", "fedsage+", "fedais1", "fedais2"])
def test_methods_run_and_learn(small_fed, method):
    g, fed = small_fed
    res = FedEngine(g, fed, method_config(method), rounds=4,
                    clients_per_round=4, seed=0).run()
    assert res.final["acc"] > 1.5 / g.n_classes   # better than chance
    assert np.isfinite(res.final["loss"])
    assert res.final["comm_total_bytes"] > 0


@pytest.mark.slow
def test_fedais_learns_and_saves_embed_comm(small_fed):
    """FedAIS must beat FedAll on embedding-sync bytes at equal rounds."""
    g, fed = small_fed
    ais = FedEngine(g, fed, method_config("fedais", tau0=4),
                    rounds=6, clients_per_round=4, seed=0).run()
    fall = FedEngine(g, fed, method_config("fedall"),
                     rounds=6, clients_per_round=4, seed=0).run()
    assert ais.final["comm_embed_bytes"] < fall.final["comm_embed_bytes"]
    assert ais.final["acc"] > 0.5 * fall.final["acc"]


@pytest.mark.slow
def test_adaptive_tau_trajectory(small_fed):
    """tau must never increase as test loss decreases (Eq. 11 trajectory)."""
    g, fed = small_fed
    res = FedEngine(g, fed, method_config("fedais", tau0=8),
                    rounds=6, clients_per_round=4, seed=0).run()
    taus = res.history["tau"]
    losses = res.history["test_loss"]
    for i in range(1, len(taus)):
        if losses[i] <= min(losses[:i]):
            assert taus[i] <= max(taus[:i])


@pytest.mark.slow
def test_fedlocal_ignores_ghosts(small_fed):
    g, fed = small_fed
    res = FedEngine(g, fed, method_config("fedlocal"), rounds=3,
                    clients_per_round=4, seed=0).run()
    assert res.final["comm_embed_bytes"] == 0.0


@pytest.mark.slow
def test_simulator_deterministic(small_fed):
    """Same seed -> identical trajectories; also exercises the run_federated
    compatibility shim against a directly constructed FedEngine."""
    g, fed = small_fed
    a = run_federated(g, fed, method_config("fedais"), rounds=3,
                      clients_per_round=3, seed=42)
    b = FedEngine(g, fed, method_config("fedais"), rounds=3,
                  clients_per_round=3, seed=42).run()
    assert a.history["test_acc"] == b.history["test_acc"]
    assert a.final["comm_total_bytes"] == b.final["comm_total_bytes"]
