"""GraphSAGE-style GCN (the paper's model: 2 hidden layers, 256/128) with
historical-embedding support — the JAX realisation of paper Eq. (2)/(6).

The client-side forward prunes the computation graph to the batch nodes plus
their direct 1-hop neighbors; deeper recursion is replaced by table lookups:
layer-0 neighbors read exact own features / synced ghost features, layer-1
neighbors read fresh in-batch values scattered over the historical table.
Gradients flow only through fresh (in-batch) entries — GNNAutoScale
semantics extended across clients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

HIDDEN = (256, 128)


def gcn_init(key, n_features: int, n_classes: int, hidden=HIDDEN, dtype=jnp.float32) -> dict:
    dims = (n_features, *hidden)
    ks = jax.random.split(key, 2 * len(hidden) + 1)
    params: dict = {}
    for l in range(len(hidden)):
        params[f"w_self{l}"] = dense_init(ks[2 * l], dims[l], dims[l + 1], dtype)
        params[f"w_nbr{l}"] = dense_init(ks[2 * l + 1], dims[l], dims[l + 1], dtype)
        params[f"b{l}"] = jnp.zeros((dims[l + 1],), dtype)
    params["w_cls"] = dense_init(ks[-1], hidden[-1], n_classes, dtype)
    params["b_cls"] = jnp.zeros((n_classes,), dtype)
    return params


AGG_BACKENDS = ("gather", "segment", "spmm")


def _aggregate(table: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray) -> jnp.ndarray:
    """Mean-aggregate neighbor rows. table (M, d); nbr_idx/mask (b, K)."""
    gathered = table[nbr_idx] * nbr_mask[..., None]
    deg = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    return gathered.sum(1) / deg


def neighbor_aggregate(
    table: jnp.ndarray,
    nbr_idx: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    *,
    backend: str = "gather",
    csr: dict | None = None,
    adj: jnp.ndarray | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Mean-aggregate neighbor rows through a pluggable backend.

    ``gather``   the dense (b, K, d) gather — the bit-parity default.
    ``segment``  CSR ``segment_sum`` over edge arrays. ``csr`` may be the
                 precomputed form (``graph.csr.csr_from_padded``, eval /
                 serve: only the E real edges) or None, in which case the
                 jit-stable bucketed form is derived in-trace from the
                 (possibly traced) batch rows
                 (``graph.csr.bucketed_csr_from_padded`` — the training hot
                 path). Either way the padded (b, K, d) gather is never
                 materialized; the sum always runs over ``b + 1`` segments
                 (padding slots land in the sliced-off overflow segment).
    ``spmm``     the block-sparse Pallas kernel (kernels/spmm) against a
                 row-normalised adjacency, block mask derived from the
                 neighbor list; differentiable in ``table`` (custom VJP —
                 the training path takes grads through it). ``interpret``
                 auto-detects (compiled on TPU, interpreter elsewhere).
                 Pass a precomputed ``adj`` (build_eval_graph does) so the
                 adjacency is built once per graph, not per layer per call.

    ``segment``/``spmm`` are numerically equivalent to ``gather`` within FP
    tolerance (different summation order), pinned by tests/test_fused.py
    and tests/test_train_backend.py.
    """
    if backend == "gather":
        return _aggregate(table, nbr_idx, nbr_mask)
    if backend == "segment":
        if csr is None:
            from repro.graph.csr import bucketed_csr_from_padded

            csr = bucketed_csr_from_padded(nbr_idx, nbr_mask)
        b = nbr_idx.shape[0]
        seg = jax.ops.segment_sum(table[csr["src"]], csr["dst"],
                                  num_segments=b + 1)
        return seg[:b] * csr["inv_deg"][:, None]
    if backend == "spmm":
        from repro.kernels.spmm.ops import neighbor_spmm

        return neighbor_spmm(table, nbr_idx, nbr_mask, adj=adj,
                             interpret=interpret)
    raise ValueError(f"unknown aggregation backend {backend!r}; known: {AGG_BACKENDS}")


def _sage_layer(params: dict, l: int, h_self: jnp.ndarray, h_agg: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.relu(
        h_self @ params[f"w_self{l}"] + h_agg @ params[f"w_nbr{l}"] + params[f"b{l}"]
    )


def gcn_batch_forward(
    params: dict,
    features: jnp.ndarray,      # (n, F) own features
    ghost_feat: jnp.ndarray,    # (g, F) synced ghost features (historical l=0)
    hist1: jnp.ndarray,         # (n + g, H1) historical layer-1 embeddings
    nbr_idx: jnp.ndarray,       # (n, K) into [own | ghost]
    nbr_mask: jnp.ndarray,      # (n, K)
    batch_idx: jnp.ndarray,     # (b,) rows of this batch
    nbr_keep: jnp.ndarray | None = None,   # optional (b, K) extra neighbor mask
    *,
    backend: str = "gather",
    interpret: bool | None = None,
):
    """Returns (logits (b, C), fresh_h1 (b, H1), h2 (b, H2)).

    ``backend`` picks the batch neighbor aggregation (``neighbor_aggregate``):
    the batch shapes (b, K) are static under jit even when ``batch_idx`` is
    traced, so the segment backend's bucketed CSR and the spmm backend's
    (b, n_tot) adjacency are derived in-trace, once, and shared by both
    layers (layer 0's and layer 1's tables have the same row count).
    """
    table0 = jnp.concatenate([features, ghost_feat], axis=0)
    b_idx = nbr_idx[batch_idx]
    b_mask = nbr_mask[batch_idx]
    if nbr_keep is not None:
        b_mask = b_mask * nbr_keep

    csr = adj = None
    if backend == "segment":
        from repro.graph.csr import bucketed_csr_from_padded

        csr = bucketed_csr_from_padded(b_idx, b_mask)
    elif backend == "spmm":
        from repro.kernels.spmm.ops import adjacency_from_neighbors

        adj = adjacency_from_neighbors(b_idx, b_mask, table0.shape[0])

    def agg(table):
        return neighbor_aggregate(table, b_idx, b_mask, backend=backend,
                                  csr=csr, adj=adj, interpret=interpret)

    h_self0 = features[batch_idx]
    agg0 = agg(table0)
    h1 = _sage_layer(params, 0, h_self0, agg0)                  # (b, 256)

    # fresh in-batch values over the historical table (stop-grad on history)
    table1 = jax.lax.stop_gradient(hist1).at[batch_idx].set(h1)
    agg1 = agg(table1)
    h2 = _sage_layer(params, 1, h1, agg1)                       # (b, 128)

    logits = h2 @ params["w_cls"] + params["b_cls"]
    return logits, h1, h2


def gcn_full_forward(params, features, nbr_idx, nbr_mask, *,
                     backend: str = "gather", csr: dict | None = None,
                     adj: jnp.ndarray | None = None,
                     interpret: bool | None = None):
    """Exact full-graph forward (server-side evaluation; no history).

    This is the per-round O(N·K·F) eval hot spot; ``backend`` selects the
    neighbor-aggregation implementation (see ``neighbor_aggregate``).
    """
    h = features
    for l in range(len(HIDDEN)):
        agg = neighbor_aggregate(h, nbr_idx, nbr_mask, backend=backend,
                                 csr=csr, adj=adj, interpret=interpret)
        h = _sage_layer(params, l, h, agg)
    return h @ params["w_cls"] + params["b_cls"]


def per_node_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """(b, C), (b,) -> (b,) cross-entropy per node (no reduction)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return lse - gold


def gcn_param_count(n_features: int, n_classes: int, hidden=HIDDEN) -> int:
    dims = (n_features, *hidden)
    total = 0
    for l in range(len(hidden)):
        total += 2 * dims[l] * dims[l + 1] + dims[l + 1]
    total += hidden[-1] * n_classes + n_classes
    return total


def gcn_flops_per_node(n_features: int, n_classes: int, avg_deg: float, hidden=HIDDEN) -> float:
    """Forward FLOPs per training node (matmuls + aggregation)."""
    dims = (n_features, *hidden)
    fl = 0.0
    for l in range(len(hidden)):
        fl += 2 * 2 * dims[l] * dims[l + 1]       # self + nbr matmuls
        fl += 2 * avg_deg * dims[l]               # mean aggregation
    fl += 2 * hidden[-1] * n_classes
    return fl
