"""End-to-end federated serving pipeline: train -> checkpoint -> serve.

Trains a small federation with the FedEngine, checkpoints it via
``save_federation``, restores it into a :class:`ServedModel` + warmed
:class:`QueryEngine`, then drives heavy synthetic traffic (queries + live
graph updates) through the :class:`LoadGenerator` and writes the
schema-guarded ``BENCH_serve.json`` latency ledger at the repo root.

    PYTHONPATH=src python -m repro.launch.serve_fed --quick
    PYTHONPATH=src python -m repro.launch.serve_fed --quick --policy fresh \
        --mode closed --backend gather

``--parity-check`` additionally asserts the served "historical" logits over
every node are bit-identical to the training-side eval path before any
traffic runs (the same invariant tests/test_serve.py pins).

``--cache-dtype {fp32,bf16,int8}`` keeps the h1 embedding cache resident in
the quantized wire format (repro.federated.quant) — bf16 halves and int8
nearly quarters the resident bytes, dequantizing on read inside the
bucketed query path. The ledger gains a ``cache`` column (dtype, resident
bytes, test-split accuracy of the served logits) so BENCH_serve.json
records accuracy next to latency for each format. ``--parity-check`` stays
fp32-only: a quantized cache is lossy by design.

Before traffic runs, the pipeline A/Bs the engine's fused single-call
bucket path against the decomposed two-call reference (``fused=False``) on
the same warm model and gates fused p50 <= two-call p50 with zero
post-warmup recompiles; the result lands in the ledger's ``fused`` column.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def build_args(argv=None) -> argparse.Namespace:
    from repro.federated.quant import SYNC_DTYPES
    from repro.serve import CACHE_POLICIES, LOAD_MODES, SERVE_BACKENDS

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny federation + 200 queries / 20 updates (CI)")
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--scale", type=int, default=None,
                    help="synthetic dataset scale (default: 64 quick, 8 full)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None,
                    help="training rounds (default: 3 quick, 30 full)")
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--method", default="fedais")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="segment", choices=SERVE_BACKENDS)
    ap.add_argument("--warm", default="refresh", choices=("refresh", "tables"))
    ap.add_argument("--policy", default="historical", choices=CACHE_POLICIES,
                    help="dominant cache policy in the traffic mix")
    ap.add_argument("--mode", default="open", choices=LOAD_MODES)
    ap.add_argument("--rate", type=float, default=500.0,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--concurrency", type=int, default=8,
                    help="closed-loop client count")
    ap.add_argument("--queries", type=int, default=None,
                    help="query count (default: 200 quick, 2000 full)")
    ap.add_argument("--updates", type=int, default=None,
                    help="streaming update count (default: 20 quick, 200 full)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: a temp dir)")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT, "BENCH_serve.json"))
    ap.add_argument("--cache-dtype", default="fp32",
                    choices=list(SYNC_DTYPES),
                    help="resident wire format of the h1 embedding cache "
                         "(repro.federated.quant): bf16 halves and int8 "
                         "nearly quarters the resident bytes; dequantized "
                         "on read inside the bucketed query path")
    ap.add_argument("--parity-check", action="store_true",
                    help="assert served historical logits == training eval "
                         "logits bit-for-bit before running traffic "
                         "(fp32 cache only — a quantized cache is lossy "
                         "by design)")
    args = ap.parse_args(argv)
    if args.parity_check and args.cache_dtype != "fp32":
        ap.error("--parity-check demands bit-identical logits; a "
                 f"{args.cache_dtype} cache is lossy by design (the "
                 "accuracy column in BENCH_serve.json tracks its effect)")
    args.scale = args.scale if args.scale is not None else (64 if args.quick else 8)
    args.rounds = args.rounds if args.rounds is not None else (3 if args.quick else 30)
    args.queries = args.queries if args.queries is not None else (200 if args.quick else 2000)
    args.updates = args.updates if args.updates is not None else (20 if args.quick else 200)
    return args


def train_and_checkpoint(args, ckpt_dir: str):
    """Run the federation and save the serving checkpoint. Returns
    (graph, fed, state) so the caller can parity-check against it.
    If ``ckpt_dir`` already holds a checkpoint and no parity check is
    requested, training is skipped and the checkpoint reused (state=None)."""
    from repro.api import FedEngine, method_config
    from repro.checkpoint import latest_step
    from repro.graph.data import make_dataset
    from repro.federated.partition import partition_graph
    from repro.serve import save_federation

    g = make_dataset(args.dataset, scale=args.scale, seed=args.seed)
    fed = partition_graph(g, args.clients, alpha=0.5, seed=args.seed)
    have = latest_step(ckpt_dir)
    if have is not None and not args.parity_check:
        print(f"# reusing checkpoint step {have} in {ckpt_dir}")
        return g, fed, None
    mcfg = method_config(args.method, tau0=2)
    engine = FedEngine(g, fed, mcfg, rounds=args.rounds,
                       clients_per_round=args.cohort, seed=args.seed,
                       eval_every=args.rounds)
    state = engine.init_state()
    result = engine.run(state)
    path = save_federation(ckpt_dir, args.rounds, state)
    print(f"# trained {args.method} {args.rounds} rounds on {args.dataset} "
          f"scale={args.scale} K={args.clients}: "
          f"test_acc={result.final.get('acc', float('nan')):.3f}")
    print(f"# checkpoint: {path}")
    return g, fed, state


def parity_check(model, engine, graph, fed, state, seed: int) -> None:
    """Served historical logits must be bit-identical to the training-side
    full-graph eval path (build_eval_graph -> _eval_logits)."""
    from repro.federated.server import _eval_logits, build_eval_graph

    eg = build_eval_graph(graph, max_deg=fed.max_deg, seed=seed,
                          backend=model.backend)
    want = np.asarray(_eval_logits(
        state.params, eg["features"], eg["nbr_idx"], eg["nbr_mask"],
        csr=eg.get("csr"), adj=eg.get("adj"), backend=model.backend))
    n = graph.features.shape[0]
    got = np.concatenate([
        engine.query(np.arange(i, min(i + 128, n)), policy="historical")
        for i in range(0, n, 128)])
    if not np.array_equal(got, want):
        raise AssertionError("served historical logits are not bit-identical "
                             "to the training eval path")
    print(f"# parity-check: {n} nodes bit-identical to build_eval_graph")


def serve_accuracy(engine, graph) -> float:
    """Test-split accuracy of the served historical logits — the accuracy
    half of the accuracy-vs-latency cache column. Runs through the warmed
    bucketed query path, so a quantized cache pays its dequant-on-read and
    its rounding here exactly as production queries would."""
    n = graph.features.shape[0]
    logits = np.concatenate([
        engine.query(np.arange(i, min(i + 128, n)), policy="historical")
        for i in range(0, n, 128)])
    mask = np.asarray(graph.test_mask, bool)
    pred = np.asarray(logits).argmax(-1)
    return float((pred[mask] == np.asarray(graph.labels)[mask]).mean())


def fused_ab(engine, graph, seed: int, reps: int = 200) -> dict:
    """A/B the fused single-call bucket path against the decomposed two-call
    reference on the same warm model (smallest bucket, historical policy,
    interleaved reps). Asserts bit-parity first, then gates fused p50 <=
    two-call p50 with zero fused recompiles — the ``fused`` ledger column."""
    import time

    from repro.serve import QueryEngine

    twin = QueryEngine(engine.model, cache_policy="historical", fused=False)
    b = engine.buckets[0]
    n = graph.features.shape[0]
    rng = np.random.default_rng((seed, 0xAB))
    ids = rng.integers(0, n, size=b).astype(np.int64)
    # warm both paths on the bucket, then parity: both modes decode the same
    # cache bits and sum segments in the same slot order -> bit-identical
    want = engine.query(ids, policy="historical")
    got = twin.query(ids, policy="historical")
    if not np.array_equal(np.asarray(got), np.asarray(want)):
        raise AssertionError("two-call reference logits diverge from the "
                             "fused bucket path")
    fused_ts, two_ts = [], []
    for _ in range(reps):
        qs = rng.integers(0, n, size=b).astype(np.int64)
        t0 = time.perf_counter()
        engine.query(qs, policy="historical")
        fused_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        twin.query(qs, policy="historical")
        two_ts.append(time.perf_counter() - t0)
    p50 = float(np.median(fused_ts) * 1e3)
    two_p50 = float(np.median(two_ts) * 1e3)
    recompiles = engine.trace_count - engine.trace_count_after_warmup
    col = {"bucket": int(b), "p50_ms": p50, "twocall_p50_ms": two_p50,
           "speedup": two_p50 / p50, "recompiles_after_warmup": recompiles}
    print(f"# fused A/B (bucket {b}, {reps} reps): fused p50={p50:.3f}ms vs "
          f"two-call p50={two_p50:.3f}ms ({col['speedup']:.2f}x)")
    if recompiles:
        raise SystemExit(f"fused A/B retraced {recompiles} serve shape(s) "
                         "after warmup")
    if p50 > two_p50:
        raise SystemExit(f"fused bucket path regressed: p50 {p50:.3f}ms > "
                         f"two-call {two_p50:.3f}ms")
    return col


def run_pipeline(args) -> dict:
    """The full train -> checkpoint -> restore -> serve pipeline. Returns the
    validated BENCH payload (and writes it to ``args.out``)."""
    import jax

    from repro.serve import (
        LoadGenerator,
        QueryEngine,
        ServedModel,
        validate_bench_serve,
    )

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_fed_ckpt_")
    g, fed, state = train_and_checkpoint(args, ckpt_dir)

    model = ServedModel.restore(ckpt_dir, g, fed, backend=args.backend,
                                warm=args.warm, seed=args.seed,
                                cache_dtype=args.cache_dtype)
    engine = QueryEngine(model, cache_policy=args.policy)
    n_traces = engine.warmup()
    print(f"# restored step {model.restored_step}; warmup compiled "
          f"{n_traces} programs over buckets {engine.buckets}")

    if args.parity_check:
        parity_check(model, engine, g, fed, state, args.seed)
        # parity queries ran through the warmed buckets: must not retrace
        if engine.trace_count != engine.trace_count_after_warmup:
            raise AssertionError("parity check retraced a serve shape")

    # the accuracy half of the cache column, measured on the warm cache
    # before traffic mutates the graph
    acc = serve_accuracy(engine, g)
    cache_col = {
        "cache_dtype": model.cache_dtype,
        "resident_bytes": model.cache_resident_bytes(),
        "serve_accuracy": acc,
    }
    print(f"# cache: {model.cache_dtype} "
          f"{cache_col['resident_bytes']:,}B resident, "
          f"test accuracy {acc:.4f}")
    if engine.trace_count != engine.trace_count_after_warmup:
        raise AssertionError("accuracy sweep retraced a serve shape")

    # the fused-vs-two-call hot-path column, measured on the warm model
    # before traffic mutates the graph
    fused_col = fused_ab(engine, g, args.seed)

    mix =({"historical": 0.9, "fresh": 0.1} if args.policy == "historical"
           else {"fresh": 0.9, "historical": 0.1})
    gen = LoadGenerator(engine, seed=args.seed, n_queries=args.queries,
                        n_updates=args.updates, mode=args.mode,
                        rate=args.rate, concurrency=args.concurrency,
                        policy_mix=mix)
    ledger = gen.run()

    retraced = engine.trace_count - engine.trace_count_after_warmup
    if retraced:
        raise AssertionError(
            f"{retraced} serve recompiles after warmup — bucket shapes leaked")

    payload = ledger.summary(backend=args.backend, devices=jax.device_count(),
                             quick=bool(args.quick), mode=args.mode,
                             policy_mix=mix, model_summary=model.summary(),
                             cache=cache_col, fused=fused_col)
    problems = validate_bench_serve(payload)
    if problems:
        raise SystemExit("refusing to write invalid BENCH_serve.json:\n  "
                         + "\n  ".join(problems))
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out}")
    print(f"# {payload['n_queries']} queries / {payload['n_updates']} updates "
          f"({args.mode}-loop): {payload['queries_per_s']:.1f} q/s, "
          f"p50={payload['p50_ms']:.2f}ms p99={payload['p99_ms']:.2f}ms, "
          f"occupancy={payload['batch_occupancy']:.2f}, "
          f"hit_rate={payload['cache_hit_rate']:.3f}")
    return payload


def main(argv=None) -> int:
    args = build_args(argv)
    run_pipeline(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
