"""String-keyed registries: method name -> (config preset, strategy kind),
and aggregator name -> Aggregator factory.

Every method in the paper (FedAIS, its ablations, the five baselines) is a
registry entry, so adding a scenario is a ``register_method`` call — not
surgery on the round loop:

    from repro.api import register_method, register_strategy_kind

    register_strategy_kind("my-sampler", MyStrategy)   # optional new hooks
    register_method("fedgrains", strategy="my-sampler",
                    importance_sampling=True, neighbor_fanout=5)
    res = FedEngine(graph, fed, "fedgrains").run()
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

from repro.api.protocols import (
    Aggregator,
    AsyncScheduler,
    FedAvg,
    RoundScheduler,
    StalenessWeightedAggregator,
    SyncScheduler,
    WeightedFedAvg,
)
from repro.api.strategies import build_strategy  # re-exported  # noqa: F401
from repro.core.fedais import MethodConfig


@dataclass(frozen=True)
class MethodSpec:
    name: str
    strategy: str                 # strategy kind key ("auto" = infer)
    defaults: Mapping[str, Any]   # MethodConfig field overrides


_METHODS: dict[str, MethodSpec] = {}


def register_method(name: str, *, strategy: str = "auto",
                    overwrite: bool = False, **defaults) -> MethodSpec:
    """Register a method under ``name`` with MethodConfig field defaults."""
    if name in _METHODS and not overwrite:
        raise KeyError(f"method {name!r} already registered")
    spec = MethodSpec(name=name, strategy=strategy, defaults=dict(defaults))
    _METHODS[name] = spec
    return spec


def unregister_method(name: str) -> None:
    _METHODS.pop(name, None)


def available_methods() -> tuple[str, ...]:
    return tuple(sorted(_METHODS))


def method_config(name: str, **overrides) -> MethodConfig:
    """Resolve a registered method name to its MethodConfig."""
    if name not in _METHODS:
        raise KeyError(f"unknown method {name!r}; known: {sorted(_METHODS)}")
    spec = _METHODS[name]
    kw = dict(spec.defaults)
    kw.update(overrides)
    kw.setdefault("strategy", spec.strategy)
    return MethodConfig(name=name, **kw)


# ---------------------------------------------------------------------------
# aggregator registry (exposed through MethodConfig.aggregator)
# ---------------------------------------------------------------------------

_AGGREGATORS: dict[str, Callable[[], Aggregator]] = {}


def register_aggregator(name: str, factory: Callable[[], Aggregator],
                        *, overwrite: bool = False) -> None:
    if name in _AGGREGATORS and not overwrite:
        raise KeyError(f"aggregator {name!r} already registered")
    _AGGREGATORS[name] = factory


def available_aggregators() -> tuple[str, ...]:
    return tuple(sorted(_AGGREGATORS))


def build_aggregator(name: str) -> Aggregator:
    if name not in _AGGREGATORS:
        raise KeyError(f"unknown aggregator {name!r}; known: {sorted(_AGGREGATORS)}")
    return _AGGREGATORS[name]()


register_aggregator("fedavg", FedAvg)
register_aggregator("weighted", WeightedFedAvg)
register_aggregator("staleness", StalenessWeightedAggregator)


# ---------------------------------------------------------------------------
# scheduler registry (exposed through MethodConfig.scheduler or the
# FedEngine ``scheduler=`` kwarg — a key, a factory product, or an instance)
# ---------------------------------------------------------------------------

_SCHEDULERS: dict[str, Callable[..., RoundScheduler]] = {}


def register_scheduler(name: str, factory: Callable[..., RoundScheduler],
                       *, overwrite: bool = False) -> None:
    if name in _SCHEDULERS and not overwrite:
        raise KeyError(f"scheduler {name!r} already registered")
    _SCHEDULERS[name] = factory


def available_schedulers() -> tuple[str, ...]:
    return tuple(sorted(_SCHEDULERS))


def build_scheduler(name: str, **kwargs) -> RoundScheduler:
    """Resolve a registered scheduler key; kwargs go to the factory
    (e.g. ``build_scheduler("async", quorum=4)``)."""
    if name not in _SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; known: {sorted(_SCHEDULERS)}")
    return _SCHEDULERS[name](**kwargs)


register_scheduler("sync", SyncScheduler)               # auto: fused if eligible
register_scheduler("sync_fused", lambda **kw: SyncScheduler(fused=True, **kw))
register_scheduler("sync_stepwise", lambda **kw: SyncScheduler(fused=False, **kw))
register_scheduler("async", AsyncScheduler)


# ---------------------------------------------------------------------------
# the paper's method-space (Table 2 / Fig. 5 columns)
# ---------------------------------------------------------------------------

register_method("fedall", importance_sampling=False, adaptive_sync=False,
                use_all_samples=True, tau0=1)
register_method("fedrandom", importance_sampling=False, adaptive_sync=False,
                use_all_samples=False, tau0=1)
register_method("fedsage+", strategy="generator",
                importance_sampling=False, adaptive_sync=False,
                use_all_samples=True, tau0=1, use_generator=True)
register_method("fedpns", importance_sampling=False, adaptive_sync=False,
                use_all_samples=True, tau0=2)
register_method("fedgraph", strategy="bandit",
                importance_sampling=False, adaptive_sync=False,
                use_all_samples=True, tau0=1, bandit_fanout=True)
register_method("fedlocal", importance_sampling=False, adaptive_sync=False,
                use_all_samples=True, tau0=1, use_ghosts=False)
register_method("fedais1", importance_sampling=True, adaptive_sync=False)
register_method("fedais2", importance_sampling=False, adaptive_sync=True,
                use_all_samples=True)
register_method("fedais", importance_sampling=True, adaptive_sync=True)
