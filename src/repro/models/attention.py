"""Attention: GQA, causal / sliding-window / bidirectional / cross, with
einsum and chunked (blockwise, flash-style running-softmax) implementations,
plus single-token decode against a KV cache.

The chunked implementation carries a flash-attention-style ``custom_vjp``:
the backward pass RECOMPUTES per-block scores instead of saving scan
residuals, so training HBM traffic is O(S·hd) not O(S²) (§Perf H3 — a plain
``lax.scan`` chunked forward still spills O(S²/chunk) residuals for reverse
mode and saves almost nothing).

Shapes: hidden (B, S, d); q (B, S, H, hd); kv (B, S, Hkv, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init, shard_activation

NEG_INF = -1e30


def attn_init(key, cfg, cross: bool = False) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = cfg.jnp_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(d, dt),
        "wq": dense_init(k1, d, qd, dt),
        "wk": dense_init(k2, d, kvd, dt),
        "wv": dense_init(k3, d, kvd, dt),
        "wo": dense_init(k4, qd, d, dt),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _repeat_kv(k, n_heads):
    """(B,S,Hkv,hd) -> (B,S,H,hd) by repeating groups."""
    b, s, hkv, hd = k.shape
    if hkv == n_heads:
        return k
    rep = n_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def _mask_bias(q_pos, k_pos, kind: str, window: int):
    """(Sq, Sk) additive bias. kind: causal | local | bidir."""
    if kind == "bidir":
        return None
    diff = q_pos[:, None] - k_pos[None, :]
    ok = diff >= 0
    if kind == "local":
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF)


def multihead_attn(
    params: dict,
    cfg,
    x: jnp.ndarray,
    *,
    kind: str = "causal",          # causal | local | bidir
    positions: jnp.ndarray | None = None,
    kv_source: jnp.ndarray | None = None,   # cross-attention source
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    q = _split_heads(h @ params["wq"], cfg.n_heads, hd)
    # cross attention consumes the (already-normalised) encoder output directly
    src = kv_source if kv_source is not None else h
    k = _split_heads(src @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(src @ params["wv"], cfg.n_kv_heads, hd)

    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.pos_embedding == "rope" and kv_source is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_impl != "chunked":
        # explicit head-sharding hints help the einsum path; in the chunked
        # (grouped GQA) path they force a reshard against the (B,G,R,S,hd)
        # layout and GSPMD propagates better from the weight shardings alone
        # (§Perf H3.5)
        q = shard_activation(q, "batch", "seq", "heads", None)
        k = shard_activation(k, "batch", "seq", "kv_heads", None)

    if cfg.attn_impl == "chunked" and kv_source is None and kind != "bidir":
        out = _chunked_attention(q, k, v, kind=kind, window=cfg.window_size,
                                 chunk=cfg.attn_chunk_size)
    else:
        out = _einsum_attention(q, k, v, kind=kind, window=cfg.window_size)
    out = out.reshape(B, S, cfg.q_dim)
    out = out @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


def _einsum_attention(q, k, v, *, kind, window):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    kf = _repeat_kv(k, H)
    vf = _repeat_kv(v, H)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
    bias = _mask_bias(jnp.arange(Sq), jnp.arange(Sk), kind, window)
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vf)


def _chunked_attention(q, k, v, *, kind, window, chunk):
    """Blockwise flash-style attention with a recompute-in-backward vjp.

    HBM traffic is O(S * hd): the forward keeps only running (m, l) softmax
    statistics; the backward recomputes per-block probabilities from the
    saved (q, k, v, out, m, l) instead of spilling O(S²/chunk) residuals.
    """
    B, S, H, hd = q.shape
    orig_S = S
    if S % chunk:
        # pad to a chunk multiple; padded keys sit at positions > any real query
        # so the causal mask removes them, padded query rows are sliced off.
        pad = chunk - S % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    # GQA-native grouped layout: q (B, G, R, S, hd), kv (B, G, S, hd) with
    # G = kv heads, R = queries per kv head — K/V are never repeated, so HBM
    # traffic and the SP gather volume stay at the kv-head size (§Perf H3.4).
    Hkv = k.shape[2]
    R = H // Hkv
    qt = q.transpose(0, 2, 1, 3).reshape(B, Hkv, R, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, kind, window if kind == "local" else None, chunk)
    out = out.reshape(B, H, S, hd)
    return out.transpose(0, 2, 1, 3)[:, :orig_S]


def _block_mask(qi, ki, chunk, kind, window):
    q_pos = qi * chunk + jnp.arange(chunk)
    k_pos = ki * chunk + jnp.arange(chunk)
    diff = q_pos[:, None] - k_pos[None, :]
    ok = diff >= 0
    if window is not None:
        ok &= diff < window
    return ok


def _flash_fwd_impl(q, k, v, kind, window, chunk):
    """q (B,G,R,S,hd); k,v (B,G,S,hd); S % chunk == 0. Returns (out, m, l);
    out (B,G,R,S,hd); m,l (B,G,R,S)."""
    B, G, R, S, hd = q.shape
    n = S // chunk
    scale = hd ** -0.5
    qb = q.reshape(B, G, R, n, chunk, hd).transpose(3, 0, 1, 2, 4, 5)  # (n,B,G,R,c,hd)
    kb = k.reshape(B, G, n, chunk, hd).transpose(2, 0, 1, 3, 4)        # (n,B,G,c,hd)
    vb = v.reshape(B, G, n, chunk, hd).transpose(2, 0, 1, 3, 4)

    def q_block(args):
        qi, q_i = args

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            s = jnp.einsum("bgrqd,bgkd->bgrqk", q_i, k_j).astype(jnp.float32) * scale
            s = jnp.where(_block_mask(qi, ki, chunk, kind, window), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(q_i.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, R, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, chunk), jnp.float32)
        a0 = jnp.zeros((B, G, R, chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(n), kb, vb))
        o = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q_i.dtype)
        return o, m, l

    outs, ms, ls = jax.lax.map(q_block, (jnp.arange(n), qb))     # (n,B,G,R,c,*)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, G, R, S, hd)
    m = ms.transpose(1, 2, 3, 0, 4).reshape(B, G, R, S)
    l = ls.transpose(1, 2, 3, 0, 4).reshape(B, G, R, S)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, kind, window, chunk):
    out, _, _ = _flash_fwd_impl(q, k, v, kind, window, chunk)
    return out


def _flash_fwd(q, k, v, kind, window, chunk):
    out, m, l = _flash_fwd_impl(q, k, v, kind, window, chunk)
    return out, (q, k, v, out, m, l)


def _flash_bwd(kind, window, chunk, res, dout):
    q, k, v, out, m, l = res
    B, G, R, S, hd = q.shape
    n = S // chunk
    scale = hd ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (B,G,R,S)

    def blk(x, i):
        return jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=2)

    qb = q.reshape(B, G, R, n, chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    doutb = dout.reshape(B, G, R, n, chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    mb = m.reshape(B, G, R, n, chunk).transpose(3, 0, 1, 2, 4)
    lb = l.reshape(B, G, R, n, chunk).transpose(3, 0, 1, 2, 4)
    deltab = delta.reshape(B, G, R, n, chunk).transpose(3, 0, 1, 2, 4)

    def q_step(carry, xs):
        dk, dv = carry
        qi, q_i, dout_i, m_i, l_i, delta_i = xs

        def kv_step(inner, ki):
            dq_i, dk, dv = inner
            k_j = blk(k, ki)
            v_j = blk(v, ki)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", q_i, k_j).astype(jnp.float32) * scale
            ok = _block_mask(qi, ki, chunk, kind, window)
            s = jnp.where(ok, s, NEG_INF)
            p = jnp.exp(s - m_i[..., None]) / jnp.maximum(l_i[..., None], 1e-30)
            p = jnp.where(ok, p, 0.0)
            dv_j = jnp.einsum("bgrqk,bgrqd->bgkd", p, dout_i.astype(jnp.float32))
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", dout_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bgrqk,bgkd->bgrqd", ds, k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bgrqk,bgrqd->bgkd", ds, q_i.astype(jnp.float32))
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk, blk(dk, ki) + dk_j, ki * chunk, axis=2)
            dv = jax.lax.dynamic_update_slice_in_dim(
                dv, blk(dv, ki) + dv_j, ki * chunk, axis=2)
            return (dq_i, dk, dv), None

        dq0 = jnp.zeros((B, G, R, chunk, hd), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(kv_step, (dq0, dk, dv), jnp.arange(n))
        return (dk, dv), dq_i

    dk0 = jnp.zeros((B, G, S, hd), jnp.float32)
    dv0 = jnp.zeros((B, G, S, hd), jnp.float32)
    (dk, dv), dq_blocks = jax.lax.scan(
        q_step, (dk0, dv0),
        (jnp.arange(n), qb, doutb, mb, lb, deltab))
    dq = dq_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, G, R, S, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }


def decode_attn(
    params: dict,
    cfg,
    x: jnp.ndarray,             # (B, 1, d)
    cache: dict,
    pos,                        # scalar int — current position
    *,
    kind: str = "causal",
    cross_kv: tuple | None = None,
):
    """One-token attention. Returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    q = _split_heads(h @ params["wq"], cfg.n_heads, hd)  # (B,1,H,hd)

    if cross_kv is not None:
        k_all, v_all = cross_kv
        if cfg.pos_embedding == "rope":
            pass  # no rope on cross attention
        mask = None
    else:
        k_new = _split_heads(h @ params["wk"], cfg.n_kv_heads, hd)
        v_new = _split_heads(h @ params["wv"], cfg.n_kv_heads, hd)
        posv = jnp.full((B, 1), pos)
        if cfg.pos_embedding == "rope":
            q = apply_rope(q, posv, cfg.rope_theta)
            k_new = apply_rope(k_new, posv, cfg.rope_theta)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1),
        }
        k_all, v_all = cache["k"], cache["v"]
        S = k_all.shape[1]
        kpos = jnp.arange(S)
        ok = kpos <= pos
        if kind == "local":
            ok &= kpos > pos - cfg.window_size
        mask = jnp.where(ok, 0.0, NEG_INF)  # (S,)

    kf = _repeat_kv(k_all.astype(q.dtype), cfg.n_heads)
    vf = _repeat_kv(v_all.astype(q.dtype), cfg.n_heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * hd ** -0.5
    if mask is not None:
        scores = scores + mask[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf).reshape(B, 1, cfg.q_dim)
    return out @ params["wo"], cache
