"""Multi-pod dry-run of the PAPER'S OWN workload: one FedAIS round chunk
(Algorithm 1) with the client cohort sharded across the production mesh.

This is now a thin caller of the engine's own sharded executor: it lowers
``repro.sharding.fed.build_sharded_chunk`` — the exact scanned
``round_step`` ``FedEngine`` runs when given a mesh — over abstract
client-sharded arguments, so the dry-run and real training share one
code path. The vmapped client axis shard_maps over a ``("clients",)``
mesh axis: the cross-client ghost pull reads the replicated historical
tables, FedAvg lowers to a weighted all-reduce (psum), and the
historical/ghost write-back all-gathers the cohort's fresh embeddings —
exactly the embedding-synchronization network phase of the real
deployment. This is the FedGCN-scale companion to launch/dryrun.py's LM
cases.

    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh pod1
    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh pod1 --pods 16

``--pods P`` lowers the pod-table mode instead (repro.sharding.tables): a
``("pods", "clients")`` 2-D mesh where EVERY K-sized array — historical
tables AND static client arrays — stays resident as pod shards, the ghost
exchange is a tau-gated bucketed all-to-all, and the write-back a
host-routed cohort-keyed bucket exchange. The report then carries a
``pods`` placement ledger classifying every per-device resident and
per-round collective by what its bytes scale with: ``k_sharded`` (K/P),
``replicated`` (K-independent), ``cohort_scaled`` (m), ``sync_gated``
(ghost cut x the tau schedule's sync fraction; ZERO on non-sync rounds).
``validate_fed_dryrun`` schema-guards the ledger before any write, and
``--assert-k-flat K2`` lowers the chunk at two client counts and fails
unless the replicated/cohort-scaled columns are byte-identical (the CI
smoke proof that nothing scales with K):

    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh host \\
        --force-devices 8 --pods 8 --clients 100000 --assert-k-flat 10000 \\
        --cohort 64 --n-max 64 --g-max 8 --features 32

``--sync-dtype {fp32,bf16,int8}`` lowers the chunk with the quantized
embedding wire (repro.federated.quant) and prices the ghost all-to-all +
write-back exchanges at that dtype in the ledger's ``quant`` section;
``--assert-quant-bytes`` lowers fp32 AND int8 at fixed K and fails unless
int8 at least halves those wires (analytic ledger and measured HLO) while
every per-device resident stays byte-identical:

    PYTHONPATH=src python -m repro.launch.fed_dryrun --mesh host \\
        --force-devices 8 --pods 8 --clients 1024 --assert-quant-bytes \\
        --cohort 64 --n-max 64 --g-max 8 --features 32

Run as a script this forces fake XLA host devices (512 by default, so
both pod chip counts fit on CPU); importing the module never touches
``XLA_FLAGS`` — pass ``--force-devices N`` (0 disables) or use
``--mesh host`` to run on whatever devices already exist.
"""
import argparse
import json
import os
import time

import jax
import numpy as np

from repro.api.engine import _LIGHT_STATS
from repro.api.registry import method_config
from repro.core.fedais import make_vmapped_update
from repro.federated.partition import ghost_exchange_buckets
from repro.federated.quant import SYNC_DTYPES, wire_bytes
from repro.launch.mesh import production_chip_count
from repro.models.gcn import HIDDEN, gcn_flops_per_node, gcn_param_count
from repro.sharding.fed import (
    abstract_chunk_args,
    build_sharded_chunk,
    client_axis_of,
    cohort_padding,
    make_client_mesh,
)
from repro.sharding.tables import (
    abstract_pod_chunk_args,
    build_pod_sharded_chunk,
    make_pod_mesh,
    sync_round_gates,
)
from repro.utils.hlo import collective_stats
from repro.utils.roofline import RooflineReport

# abstract_pod_chunk_args' padded-adjacency width (the synthetic topology
# has no real adjacency; the ledger's nbr_* rows use the same constant)
DRYRUN_MAX_DEG = 16
# horizon for probing the tau schedule's sync fraction
SYNC_PROBE_ROUNDS = 64

# chip counts come from the production mesh definition (launch/mesh.py)
MESH_CHIPS = {
    "pod1": production_chip_count(multi_pod=False),
    "pod2": production_chip_count(multi_pod=True),
}


def _force_host_devices(n: int) -> None:
    """Fake XLA host devices; only effective before the backend initializes
    (caller flags win for duplicates, preserving any prior forced count)."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", ""))


def synthetic_ghost_buckets(n_clients: int, n_max: int, g_max: int,
                            n_pods: int, *, fill: float = 1.0, seed: int = 0):
    """A partition-shaped ghost topology for lowering the pod chunk without
    real data: each client's ghost slots point at uniform random (owner,
    row) pairs, ``fill`` controlling the occupied fraction (the ghost-cut
    knob the write-back bytes should track)."""
    rng = np.random.default_rng(seed)
    mask = (rng.random((n_clients, g_max)) < fill).astype(np.float32)
    owner = rng.integers(0, n_clients, size=(n_clients, g_max)).astype(np.int32)
    owner = np.where(mask > 0, owner, -1)
    row = rng.integers(0, n_max, size=(n_clients, g_max)).astype(np.int32)
    return ghost_exchange_buckets(owner, row, mask, n_pods)


def pod_placement_ledger(buckets, *, n_pods: int, cohort_pad: int,
                         wb_cap: int, n_max: int, g_max: int, n_feat: int,
                         n_classes: int, tau: int, local_epochs: int,
                         max_deg: int = DRYRUN_MAX_DEG,
                         rounds: int = 1, sync_dtype: str = "fp32") -> dict:
    """The analytic placement ledger for the pod-sharded chunk: every
    per-device resident array and per-round collective payload, in bytes,
    grouped by what it scales with. ``k_sharded`` rows are exactly
    ``rows_per_pod`` (= Kp/P) table rows; ``replicated``/``cohort_scaled``
    entries never mention K; ``sync_gated`` entries only move bytes on
    rounds where the tau schedule syncs (``sync_round_gates``), so their
    effective per-round cost is the nominal payload times the schedule's
    sync fraction — and exactly 0 on non-sync rounds.

    The ``quant`` section prices the three embedding wires the codec
    actually quantizes (``repro.federated.quant``) at ``sync_dtype``: the
    ghost hist1 all-to-all and both write-back stages, where the float
    tables ride as payload+scale and the int32 ``age`` rows stay 4-byte.
    Every other ledger entry is dtype-independent — residents and the
    owner-keyed cohort fetch stay fp32 regardless of the wire format."""
    H1 = HIDDEN[0]
    n_tot = n_max + g_max
    P, B = n_pods, buckets.bucket_size
    rpp = buckets.rows_per_pod
    m, S = cohort_pad, rounds
    n_params = gcn_param_count(n_feat, n_classes)
    # bytes of one client's table + static rows (everything the owner-keyed
    # cohort fetch moves per selected client, and the write-back returns)
    table_row = (n_tot * H1 + n_tot + g_max * n_feat + n_max) * 4
    static_row = (n_max * (n_feat + 3 + 2 * max_deg) + g_max) * 4
    k_sharded = {
        "hist1": rpp * n_tot * H1 * 4,
        "age": rpp * n_tot * 4,
        "ghost_feat": rpp * g_max * n_feat * 4,
        "prev_loss": rpp * n_max * 4,
        "features": rpp * n_max * n_feat * 4,
        "labels": rpp * n_max * 4,
        "node_mask": rpp * n_max * 4,
        "train_mask": rpp * n_max * 4,
        "nbr_idx": rpp * n_max * max_deg * 4,
        "nbr_mask": rpp * n_max * max_deg * 4,
        "ghost_mask": rpp * g_max * 4,
        "ghost_src_feat": rpp * g_max * n_feat * 4,
        "recv_buckets": rpp * g_max * 12,
    }
    replicated = {
        "params": n_params * 4,
        "cohort_stacks": S * (m * 12 + 5),     # sel/fan/w + eoff/gate
        "wb_routing": S * (m * 8 + P * P * wb_cap * 4),
    }
    ghost_cut = {"send_buckets": P * B * 12}
    eoffs = np.arange(SYNC_PROBE_ROUNDS) * local_epochs
    frac = float(sync_round_gates(eoffs, tau, local_epochs).mean())
    a2a = P * B * H1 * 4
    gfetch = m * g_max * (H1 + n_feat) * 4

    # the quantized embedding wires: the ghost all-to-all moves the (P, B,
    # H1) hist1 buffer as codec payload (+ per-row scales at int8); the
    # write-back stages route the three float tables as payload+scale while
    # the int32 age rows always stay 4 bytes per element
    def quant_row(d):
        return (wire_bytes((n_tot, H1), d) + n_tot * 4
                + wire_bytes((g_max, n_feat), d) + wire_bytes((n_max,), d))

    def quant_wires(d):
        return {
            "ghost_all_to_all": wire_bytes((P, B, H1), d),
            "wb_stage1_all_gather": (m // P) * quant_row(d),
            "wb_stage2_all_to_all": P * wb_cap * quant_row(d),
        }

    wire, fp32w = quant_wires(sync_dtype), quant_wires("fp32")
    return {
        "schema_version": 2,
        "n_pods": P,
        "table_shard_rows_per_pod": rpp,
        "ghost_cut_entries": buckets.n_entries,
        "bucket_size": B,
        "wb_cap": int(wb_cap),
        "per_device_resident_bytes": {
            "k_sharded": k_sharded,
            "replicated": replicated,
            "ghost_cut_scaled": ghost_cut,
        },
        "per_round_collective_bytes": {
            "cohort_scaled": {
                "fetch_psum_tables": m * table_row,
                "fetch_psum_statics": m * static_row,
                "merge_allreduce": n_params * 4,
                "wb_stage1_all_gather": (m // P) * table_row,
                "wb_stage2_all_to_all": P * wb_cap * table_row,
            },
            "sync_gated": {
                "ghost_all_to_all": a2a,
                "ghost_fetch_psum": gfetch,
            },
        },
        "sync": {
            "tau": int(tau),
            "local_epochs": int(local_epochs),
            "rounds_probed": SYNC_PROBE_ROUNDS,
            "sync_fraction": frac,
            "ghost_all_to_all_effective_bytes": int(round(a2a * frac)),
            "ghost_fetch_effective_bytes": int(round(gfetch * frac)),
            "non_sync_round_ghost_bytes": 0,
        },
        "quant": {
            "sync_dtype": sync_dtype,
            "wire_collective_bytes": wire,
            "fp32_collective_bytes": fp32w,
            "reduction": {k: round(fp32w[k] / wire[k], 2) for k in wire},
        },
    }


_POD_LEDGER_KEYS = ("schema_version", "n_pods", "table_shard_rows_per_pod",
                    "ghost_cut_entries", "bucket_size", "wb_cap",
                    "per_device_resident_bytes",
                    "per_round_collective_bytes", "sync", "quant",
                    "all_to_all_bytes", "all_gather_bytes")
# the fp32 column of the quant section must restate these nominal entries
_QUANT_NOMINAL = {"ghost_all_to_all": ("sync_gated", "ghost_all_to_all"),
                  "wb_stage1_all_gather": ("cohort_scaled",
                                           "wb_stage1_all_gather"),
                  "wb_stage2_all_to_all": ("cohort_scaled",
                                           "wb_stage2_all_to_all")}
_TOP_KEYS = ("status", "arch", "mesh", "chips", "clients", "cohort",
             "collectives", "roofline")


def validate_fed_dryrun(result: dict) -> list[str]:
    """Schema-check a fed_dryrun result row before it is written (the
    ``validate_bench_round`` pattern). Returns a list of problems (empty =
    valid): required keys present and typed, every ledger class a dict of
    non-negative ints, the sync fraction in [0, 1], the non-sync-round
    ghost bytes pinned to 0 (the gated-exchange contract), and the quant
    section's fp32 column restating the nominal collective entries (with
    the wire column never exceeding it, and equal to it at fp32)."""
    errs: list[str] = []
    if not isinstance(result, dict):
        return [f"result is {type(result).__name__}, expected dict"]
    for k in _TOP_KEYS:
        if k not in result:
            errs.append(f"missing key {k!r}")
    if errs:
        return errs
    if not isinstance(result["collectives"], dict):
        errs.append("collectives must be a dict of byte counts")
    if "pods" not in result:
        return errs
    pods = result["pods"]
    if not isinstance(pods, dict):
        return errs + ["pods must be a dict"]
    for k in _POD_LEDGER_KEYS:
        if k not in pods:
            errs.append(f"pods missing key {k!r}")
    if errs:
        return errs
    for section in ("per_device_resident_bytes",
                    "per_round_collective_bytes"):
        for cls, entries in pods[section].items():
            if not isinstance(entries, dict) or not entries:
                errs.append(f"pods.{section}.{cls} must be a non-empty dict")
                continue
            for name, v in entries.items():
                if not isinstance(v, int) or v < 0:
                    errs.append(f"pods.{section}.{cls}.{name} must be a "
                                f"non-negative int, got {v!r}")
    sync = pods["sync"]
    frac = sync.get("sync_fraction")
    if not isinstance(frac, (int, float)) or not 0.0 <= frac <= 1.0:
        errs.append(f"pods.sync.sync_fraction must be in [0, 1], got {frac!r}")
    if sync.get("non_sync_round_ghost_bytes") != 0:
        errs.append("pods.sync.non_sync_round_ghost_bytes must be 0 "
                    "(the ghost exchange is gated off entirely)")
    a2a = sync.get("ghost_all_to_all_effective_bytes")
    nominal = pods["per_round_collective_bytes"]["sync_gated"].get(
        "ghost_all_to_all", 0)
    if not isinstance(a2a, int) or a2a != int(round(nominal * frac)):
        errs.append("pods.sync.ghost_all_to_all_effective_bytes must equal "
                    "ghost_all_to_all x sync_fraction")
    quant = pods["quant"]
    dtype = quant.get("sync_dtype")
    if dtype not in SYNC_DTYPES:
        errs.append(f"pods.quant.sync_dtype must be one of {SYNC_DTYPES}, "
                    f"got {dtype!r}")
    wire = quant.get("wire_collective_bytes", {})
    fp32w = quant.get("fp32_collective_bytes", {})
    for name, (cls, nom_key) in _QUANT_NOMINAL.items():
        w, f = wire.get(name), fp32w.get(name)
        if not isinstance(w, int) or w <= 0:
            errs.append(f"pods.quant.wire_collective_bytes.{name} must be a "
                        f"positive int, got {w!r}")
            continue
        nom = pods["per_round_collective_bytes"][cls].get(nom_key)
        if f != nom:
            errs.append(f"pods.quant.fp32_collective_bytes.{name} must "
                        f"restate {cls}.{nom_key} ({nom}), got {f!r}")
        if w > f:
            errs.append(f"pods.quant.wire_collective_bytes.{name} ({w}) "
                        f"exceeds its fp32 nominal ({f})")
        if dtype == "fp32" and w != f:
            errs.append(f"pods.quant.{name}: fp32 wire must be bit-inert "
                        f"({w} != {f})")
    return errs


def assert_k_flat(res_a: dict, res_b: dict) -> list[str]:
    """The K-flatness contract between two dry-runs that differ ONLY in
    ``--clients``: every replicated resident and every cohort-scaled
    collective must be byte-identical, the k_sharded residents must scale
    exactly with rows_per_pod (= Kp/P), and the HLO's all-gather /
    all-reduce byte totals (write-back stage 1 + cohort fetch psums + merge
    — the only members of those kinds) must not move. Returns a list of
    violations (empty = the placement is K-flat)."""
    errs: list[str] = []
    pa, pb = res_a["pods"], res_b["pods"]
    ka, kb = res_a["clients"], res_b["clients"]
    for section, cls in (("per_device_resident_bytes", "replicated"),
                         ("per_round_collective_bytes", "cohort_scaled")):
        ea, eb = pa[section][cls], pb[section][cls]
        for name in sorted(set(ea) | set(eb)):
            if ea.get(name) != eb.get(name):
                errs.append(
                    f"{cls}.{name}: {ea.get(name)}B at K={ka} vs "
                    f"{eb.get(name)}B at K={kb} — scales with K")
    gf_a = pa["per_round_collective_bytes"]["sync_gated"]["ghost_fetch_psum"]
    gf_b = pb["per_round_collective_bytes"]["sync_gated"]["ghost_fetch_psum"]
    if gf_a != gf_b:
        errs.append(f"sync_gated.ghost_fetch_psum: {gf_a}B vs {gf_b}B — "
                    "scales with K")
    ra, rb = pa["table_shard_rows_per_pod"], pb["table_shard_rows_per_pod"]
    for name, va in pa["per_device_resident_bytes"]["k_sharded"].items():
        vb = pb["per_device_resident_bytes"]["k_sharded"].get(name, -1)
        if va * rb != vb * ra:
            errs.append(f"k_sharded.{name}: {va}B/{ra} rows vs {vb}B/{rb} "
                        "rows — not linear in K/P")
    for kind in ("all-gather", "all-reduce"):
        ba = res_a["collectives"].get(kind, 0)
        bb = res_b["collectives"].get(kind, 0)
        if ba != bb:
            errs.append(f"HLO {kind}: {ba}B at K={ka} vs {bb}B at K={kb} — "
                        "a lowered collective scales with K")
    return errs


def assert_quant_bytes(res_fp32: dict, res_int8: dict) -> list[str]:
    """The quantized-wire contract between two dry-runs that differ ONLY
    in ``--sync-dtype`` (fp32 vs int8): every quantized embedding wire —
    the ghost all-to-all and both write-back stages — must cost at most
    half its fp32 bytes (analytically, per the ledger's quant section, AND
    as measured off the lowered HLO's all-to-all / all-gather totals),
    while the per-device resident ledger stays byte-identical (tables are
    stored fp32; only the wire narrows). Returns violations (empty =
    int8 halves the embedding sync)."""
    errs: list[str] = []
    pa, pb = res_fp32["pods"], res_int8["pods"]
    qa = pa["quant"]["wire_collective_bytes"]
    qb = pb["quant"]["wire_collective_bytes"]
    for name in sorted(qa):
        if qb[name] * 2 > qa[name]:
            errs.append(f"quant.{name}: int8 wire {qb[name]}B is not <= "
                        f"half of fp32 {qa[name]}B")
    for kind in ("all-to-all", "all-gather"):
        ba = res_fp32["collectives"].get(kind, 0)
        bb = res_int8["collectives"].get(kind, 0)
        if bb * 2 > ba:
            errs.append(f"HLO {kind}: int8 lowers to {bb}B, not <= half of "
                        f"fp32's {ba}B — the wire is not quantized")
    if pa["per_device_resident_bytes"] != pb["per_device_resident_bytes"]:
        errs.append("per_device_resident_bytes differ between fp32 and int8 "
                    "— residents must stay fp32 regardless of wire dtype")
    return errs


def dryrun_mesh(mesh_name: str, args) -> dict:
    """Lower one sharded round chunk on ``mesh_name``'s chip count and
    report collectives + roofline. With ``--pods P`` the mesh is the 2-D
    ``("pods", "clients")`` grid and the historical tables shard over the
    pod axis (repro.sharding.tables) — the collectives then include the
    ghost-bucket all-to-all and a cohort-sized (K-independent) write-back
    all-gather instead of replicated-table traffic. Returns the result row
    (status key "ok"/"error")."""
    chips = MESH_CHIPS.get(mesh_name, len(jax.devices()))
    K = args.clients or chips
    m = args.cohort or K
    pods = args.pods
    mcfg = method_config("fedais", local_epochs=4, batch_cap=args.n_max)
    buckets = None
    pad = cohort_padding(m, chips)
    sync_dtype = getattr(args, "sync_dtype", "fp32")
    if pods:
        if chips % pods:
            raise ValueError(f"{chips} chips do not split into {pods} pods")
        mesh = make_pod_mesh(pods, chips // pods)
        buckets = synthetic_ghost_buckets(K, args.n_max, args.g_max, pods,
                                          fill=args.ghost_fill)
        vm = make_vmapped_update(mcfg, args.n_max, args.g_max, HIDDEN[0],
                                 ghost_source="prefetched",
                                 sync_dtype=sync_dtype)
        chunk = build_pod_sharded_chunk(vm, mesh, m, buckets, _LIGHT_STATS,
                                        sync_dtype=sync_dtype)
        sargs = abstract_pod_chunk_args(
            mesh, buckets, n_clients=K, cohort=m + pad, n_max=args.n_max,
            g_max=args.g_max, n_feat=args.features, n_classes=args.classes,
            max_deg=DRYRUN_MAX_DEG)
    else:
        mesh = make_client_mesh(chips)
        axis = client_axis_of(mesh)
        vm = make_vmapped_update(mcfg, args.n_max, args.g_max, HIDDEN[0],
                                 sync_dtype=sync_dtype)
        chunk = build_sharded_chunk(vm, mesh, axis, m_real=m,
                                    light_stats=_LIGHT_STATS,
                                    sync_dtype=sync_dtype)
        sargs = abstract_chunk_args(
            mesh, n_clients=K, cohort=m + pad, n_max=args.n_max,
            g_max=args.g_max, n_feat=args.features, n_classes=args.classes)

    t0 = time.time()
    compiled = chunk.lower(*sargs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = collective_stats(compiled.as_text())

    n_params = gcn_param_count(args.features, args.classes)
    # per-round model flops: J epochs x batch fwd+bwd over the m-cohort
    flops_model = 3.0 * gcn_flops_per_node(args.features, args.classes, 8.0) \
        * args.n_max * mcfg.local_epochs * m
    rep = RooflineReport(
        arch="fedgcn-graphsage", shape=f"K{K}", mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)) * chips,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
        collective_bytes=float(coll.total_bytes) * chips,
        model_flops=flops_model,
    )
    result = {
        "status": "ok", "arch": "fedgcn-graphsage", "shape": f"K{K}",
        "mesh": mesh_name, "chips": chips, "clients": K, "cohort": m,
        "cohort_pad": pad, "sync_dtype": sync_dtype,
        "gcn_params": n_params,
        "compile_s": round(time.time() - t0, 1),
        "collectives": {k: int(v) for k, v in coll.bytes_by_kind.items()},
        "roofline": rep.row(),
        "memory": {"temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
    }
    if pods:
        # the placement ledger the pod mode exists for: classify every
        # resident and collective by what its bytes scale with, and read
        # the write-back bucket capacity off the lowered args themselves
        # (sargs[-1] is wb_recv (S, P, P, cap) — cap depends on m only)
        wb_cap = sargs[-1].shape[-1]
        ledger = pod_placement_ledger(
            buckets, n_pods=pods, cohort_pad=m + pad, wb_cap=wb_cap,
            n_max=args.n_max, g_max=args.g_max, n_feat=args.features,
            n_classes=args.classes, tau=args.tau,
            local_epochs=mcfg.local_epochs, sync_dtype=sync_dtype)
        ledger["all_to_all_bytes"] = int(
            coll.bytes_by_kind.get("all-to-all", 0))
        ledger["all_gather_bytes"] = int(
            coll.bytes_by_kind.get("all-gather", 0))
        result["pods"] = ledger
    print(rep.pretty())
    print(f"    [{mesh_name}] K={K}" + (f" pods={pods}" if pods else "")
          + f" compile={result['compile_s']}s collectives: {coll.summary()}")
    if pods:
        p = result["pods"]
        resid = p["per_device_resident_bytes"]
        print(f"    [{mesh_name}] K/P={p['table_shard_rows_per_pod']} rows/pod "
              f"({sum(resid['k_sharded'].values()):,}B sharded, "
              f"{sum(resid['replicated'].values()):,}B replicated); "
              f"ghost a2a {p['sync']['ghost_all_to_all_effective_bytes']:,}B "
              f"effective at sync fraction {p['sync']['sync_fraction']:.2f} "
              f"(0B on non-sync rounds)")
        q = p["quant"]
        if q["sync_dtype"] != "fp32":
            cuts = ", ".join(
                f"{name} {q['wire_collective_bytes'][name]:,}B "
                f"({q['reduction'][name]}x)"
                for name in sorted(q["wire_collective_bytes"]))
            print(f"    [{mesh_name}] {q['sync_dtype']} wire: {cuts}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1",
                    choices=["pod1", "pod2", "both", "host"],
                    help="pod chip counts, or 'host' = all existing devices")
    ap.add_argument("--clients", type=int, default=0, help="default: one per chip")
    ap.add_argument("--cohort", type=int, default=0,
                    help="clients selected per round (default: all K) — fix "
                         "it while sweeping --clients to see which "
                         "collectives scale with the total client count")
    ap.add_argument("--pods", type=int, default=0,
                    help="shard the historical tables over this many pods "
                         "(a ('pods','clients') 2-D mesh; 0 = replicated "
                         "tables, cohort-only sharding)")
    ap.add_argument("--ghost-fill", type=float, default=0.5,
                    help="occupied fraction of ghost slots in the synthetic "
                         "pod topology — the ghost-cut knob the --pods "
                         "write-back bytes should track")
    ap.add_argument("--tau", type=int, default=8,
                    help="staleness threshold for the --pods ledger's sync "
                         "fraction (the tau schedule gates the ghost "
                         "all-to-all; with J=4 local epochs tau=8 syncs "
                         "every other round)")
    ap.add_argument("--assert-k-flat", type=int, default=0, metavar="K2",
                    help="with --pods: lower the chunk a second time at K2 "
                         "clients and fail unless every replicated resident "
                         "and cohort-scaled collective is byte-identical "
                         "(the CI proof that nothing scales with K)")
    ap.add_argument("--sync-dtype", default="fp32", choices=list(SYNC_DTYPES),
                    help="wire format for the embedding sync (repro."
                         "federated.quant): ghost all-to-all + write-back "
                         "exchange payloads; fp32 is bit-inert")
    ap.add_argument("--assert-quant-bytes", action="store_true",
                    help="with --pods: lower the chunk at fp32 AND int8 and "
                         "fail unless int8 at least halves the ghost "
                         "all-to-all + write-back bytes (ledger and lowered "
                         "HLO) with per-device residents byte-identical "
                         "(the CI proof the codec narrows only the wire)")
    ap.add_argument("--n-max", type=int, default=512)
    ap.add_argument("--g-max", type=int, default=256)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--classes", type=int, default=41)   # reddit-like
    ap.add_argument("--force-devices", type=int, default=None,
                    help="force N fake XLA host devices before the backend "
                         "initializes (default: 512 for pod meshes, off for "
                         "--mesh host; 0 disables)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.force_devices is None and args.mesh != "host":
        args.force_devices = max(MESH_CHIPS.values())
    if args.force_devices:
        _force_host_devices(args.force_devices)

    if args.assert_k_flat and not (args.pods and args.clients):
        ap.error("--assert-k-flat needs --pods and an explicit --clients")
    if args.assert_quant_bytes and not args.pods:
        ap.error("--assert-quant-bytes needs --pods")

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    rc = 0
    for mesh_name in meshes:
        try:
            result = dryrun_mesh(mesh_name, args)
        except Exception as e:
            print(f"[{mesh_name}] ERROR: {type(e).__name__}: {e}")
            rc = 1
            continue
        problems = validate_fed_dryrun(result)
        if problems:
            print(f"[{mesh_name}] INVALID result, not writing:")
            for p in problems:
                print(f"    - {p}")
            rc = 1
            continue
        if args.assert_k_flat:
            args2 = argparse.Namespace(**{**vars(args),
                                          "clients": args.assert_k_flat})
            try:
                result2 = dryrun_mesh(mesh_name, args2)
            except Exception as e:
                print(f"[{mesh_name}] ERROR at K={args.assert_k_flat}: "
                      f"{type(e).__name__}: {e}")
                rc = 1
                continue
            violations = assert_k_flat(result, result2)
            if violations:
                print(f"[{mesh_name}] K-FLATNESS VIOLATED "
                      f"(K={args.clients} vs K={args.assert_k_flat}):")
                for v in violations:
                    print(f"    - {v}")
                rc = 1
                continue
            print(f"    [{mesh_name}] K-flat: replicated residents, "
                  f"cohort-scaled collectives, and lowered all-gather/"
                  f"all-reduce bytes identical at K={args.clients} and "
                  f"K={args.assert_k_flat}; k_sharded exactly linear in K/P")
        if args.assert_quant_bytes:
            variants = {args.sync_dtype: result}
            try:
                for d in ("fp32", "int8"):
                    if d not in variants:
                        args_d = argparse.Namespace(**{**vars(args),
                                                       "sync_dtype": d})
                        variants[d] = dryrun_mesh(mesh_name, args_d)
            except Exception as e:
                print(f"[{mesh_name}] ERROR lowering quant variant: "
                      f"{type(e).__name__}: {e}")
                rc = 1
                continue
            violations = assert_quant_bytes(variants["fp32"],
                                            variants["int8"])
            if violations:
                print(f"[{mesh_name}] QUANT-BYTES CONTRACT VIOLATED "
                      f"(fp32 vs int8):")
                for v in violations:
                    print(f"    - {v}")
                rc = 1
                continue
            c32, c8 = (variants[d]["collectives"] for d in ("fp32", "int8"))
            print(f"    [{mesh_name}] quant-bytes: int8 cuts the lowered "
                  f"all-to-all {c32.get('all-to-all', 0):,}B -> "
                  f"{c8.get('all-to-all', 0):,}B and all-gather "
                  f"{c32.get('all-gather', 0):,}B -> "
                  f"{c8.get('all-gather', 0):,}B (>= 2x each); per-device "
                  f"residents byte-identical")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"_pods{args.pods}" if args.pods else ""
            with open(os.path.join(args.out, f"fedgcn_{mesh_name}{tag}.json"),
                      "w") as f:
                json.dump(result, f, indent=1)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
