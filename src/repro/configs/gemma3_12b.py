"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt family card, scaled per assignment]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_ff=15360,
        vocab_size=262144,
        head_dim=240,                      # derived d_model/n_heads (see DESIGN.md §Perf: MXU pads 240->256)
        source="hf:google/gemma-3-1b-pt",
        block_pattern=("local",) * 5 + ("attn",),   # 5:1 local:global, 48 = 8 units
        window_size=1024,
        rope_theta=1_000_000.0,
        max_seq_len=131072,
        activation="gelu",
        gated_mlp=True,
        tie_embeddings=True,               # gemma family ties embeddings
        # long_500k runs with global layers degraded to sliding window
        long_context_local=True,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config(), block_pattern=("local", "attn"), window_size=8)


register("gemma3-12b", config, smoke)
