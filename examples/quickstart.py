"""Quickstart: FedAIS vs FedAll on a synthetic Pubmed-like graph.

Runs the paper's Algorithm 1 end to end on CPU in ~1 minute and prints the
accuracy / communication trade-off the paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import FedEngine, method_config
from repro.federated.partition import partition_graph
from repro.graph.data import make_dataset


def main():
    # 1. a synthetic stand-in for Pubmed (Table 1 statistics, 1/32 scale)
    graph = make_dataset("pubmed", scale=32, seed=0)
    print(f"graph: {graph.n_nodes} nodes, {len(graph.edges)} edges, "
          f"{graph.n_classes} classes")

    # 2. intra-graph federated partition: 16 clients, Dirichlet(0.5) non-iid
    fed = partition_graph(graph, n_clients=16, alpha=0.5, seed=0)
    print(f"partition: {fed.n_clients} clients, n_max={fed.n_max}, "
          f"cross-client edges={fed.n_cross_edges}")

    # 3. train with FedAIS (importance sampling + adaptive sync) and FedAll
    for method in ("fedais", "fedall"):
        mcfg = method_config(method, tau0=4 if method == "fedais" else 1)
        res = FedEngine(graph, fed, mcfg, rounds=10, clients_per_round=5,
                        seed=0, verbose=False).run()
        f = res.final
        print(f"{method:8s} acc={f['acc']*100:5.1f}%  f1={f['f1']*100:5.1f}%  "
              f"comm={f['comm_total_bytes']/1e6:7.1f} MB "
              f"(embeddings {f['comm_embed_bytes']/1e6:6.1f} MB)  "
              f"est. wall-clock={f['wall_clock_s']:.1f}s")
    print("\nFedAIS should match or beat FedAll's accuracy at a fraction of "
          "the embedding-synchronization traffic (paper Fig. 3/4).")


if __name__ == "__main__":
    main()
