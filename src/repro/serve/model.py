"""ServedModel: the checkpoint-backed inference face of a trained federation.

The paper's core artifact — historical layer-1 embedding tables synchronized
cheaply across clients — is exactly a warm inference cache. ``ServedModel``
restores the federation checkpoint (params + the (K, n_tot, H1) tables,
written by ``save_federation``) and turns it into a *global-graph* serving
state:

* ``params`` — the aggregated GCN weights;
* a device-resident warm layer-1 embedding cache ``h1`` (capacity, H1),
  initialised either by one full layer-0 pass over the graph
  (``warm="refresh"``, the serving-parity basis: rows are bit-identical to
  the training-side eval path) or by scattering the checkpointed per-client
  ``hist1`` rows into global node ids (``warm="tables"``, the paper's
  cheap-but-stale start);
* per-row freshness bookkeeping: ``valid`` (invalidated by streaming graph
  updates until re-embedded), ``cache_age`` (serve steps since the row was
  last written), and ``table_age`` (the checkpointed training-time staleness
  counters, scattered to global ids — the paper's Eq. 6 diagnostics carried
  into serving).

Queries run through ``repro.serve.engine.QueryEngine``; streaming updates
mutate the underlying ``repro.serve.updates.GraphStore``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, load_latest, save_checkpoint
from repro.federated.quant import check_sync_dtype
from repro.federated.quant import decode as quant_decode
from repro.federated.quant import encode as quant_encode
from repro.graph.csr import build_padded_neighbors, csr_from_padded
from repro.models.gcn import HIDDEN, _sage_layer, gcn_init, neighbor_aggregate
from repro.serve.updates import GraphStore

SERVE_BACKENDS = ("gather", "segment", "spmm")
WARM_MODES = ("refresh", "tables", "cold")


# ---------------------------------------------------------------------------
# federation checkpoint layout
# ---------------------------------------------------------------------------

def federation_tree(state: Any) -> dict:
    """The canonical checkpoint pytree of a federation: global params plus
    the synchronized table state. Accepts a ``repro.api.EngineState`` (or
    anything with ``.params/.hist/.ghost_feat/.prev_loss``) or an
    already-flat dict with these keys."""
    if hasattr(state, "hist"):
        return {
            "params": state.params,
            "hist1": state.hist.hist1,
            "age": state.hist.age,
            "ghost_feat": state.ghost_feat,
            "prev_loss": state.prev_loss,
        }
    return dict(state)


def federation_template(fed) -> dict:
    """Shape/dtype template for ``load_checkpoint`` built from the
    partition's static geometry (no training state needed)."""
    n_tot = fed.n_max + fed.g_max
    return {
        "params": gcn_init(jax.random.PRNGKey(0), fed.n_features, fed.n_classes),
        "hist1": jnp.zeros((fed.n_clients, n_tot, HIDDEN[0]), jnp.float32),
        "age": jnp.zeros((fed.n_clients, n_tot), jnp.int32),
        "ghost_feat": jnp.zeros((fed.n_clients, fed.g_max, fed.n_features),
                                jnp.float32),
        "prev_loss": jnp.zeros((fed.n_clients, fed.n_max), jnp.float32),
    }


def save_federation(directory: str, step: int, state: Any) -> str:
    """Checkpoint a trained federation (params + tables) for serving."""
    return save_checkpoint(directory, step, federation_tree(state))


# ---------------------------------------------------------------------------
# layer-1 embedding compute (the cache fill / refresh kernel)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def _layer1_full(params, features, nbr_idx, nbr_mask, csr=None, adj=None,
                 backend: str = "segment"):
    """Layer-1 embeddings for every row — exactly the first layer of
    ``gcn_full_forward`` (same backend, same operands), so cache rows are
    bit-identical to the training-side eval path."""
    agg = neighbor_aggregate(features, nbr_idx, nbr_mask, backend=backend,
                             csr=csr, adj=adj)
    return _sage_layer(params, 0, features, agg)


def _scatter_tables(fed, table_k, fill=0.0):
    """Scatter a per-client (K, n_max[, d]) own-row table into global node
    order (every global node belongs to exactly one client)."""
    own = np.asarray(fed.node_mask) > 0                      # (K, n_max)
    gids = np.asarray(fed.global_ids)[own]
    vals = np.asarray(table_k)[:, : fed.n_max][own]
    n = int(own.sum())
    out = np.full((n,) + vals.shape[1:], fill, vals.dtype)
    out[gids] = vals
    return out


class ServedModel:
    """Device-resident serving state: params + warm embedding cache.

    Built via :meth:`restore` (from a ``save_federation`` checkpoint) or
    directly from params + a :class:`GraphStore` for tests.
    """

    def __init__(self, params, store: GraphStore, *, backend: str = "segment",
                 warm: str = "refresh", table_h1: np.ndarray | None = None,
                 table_age: np.ndarray | None = None,
                 restored_step: int | None = None,
                 cache_dtype: str = "fp32"):
        if backend not in SERVE_BACKENDS:
            raise ValueError(f"unknown serve backend {backend!r}; "
                             f"known: {SERVE_BACKENDS}")
        if warm not in WARM_MODES:
            raise ValueError(f"unknown warm mode {warm!r}; known: {WARM_MODES}")
        self.params = jax.tree_util.tree_map(jnp.asarray, params)
        self.store = store
        self.backend = backend
        self.warm = warm
        self.restored_step = restored_step
        # wire/residency format of the h1 cache (repro.federated.quant):
        # `h1` holds the encoded payload (fp32 passthrough / bf16 / int8
        # codes) and `h1_scale` the int8 per-row fp32 scales (else None).
        # The query engine dequantizes on read inside its traced bodies.
        self.cache_dtype = check_sync_dtype(cache_dtype)
        cap = store.capacity
        self.feat = jnp.asarray(store.features)              # (cap, F) device
        self.valid = np.zeros(cap, bool)
        self.step = 0                                        # serve-step clock
        self.row_version = np.zeros(cap, np.int64)           # step of last write
        # training-time staleness of the checkpointed tables, global order
        self.table_age = table_age
        self.n_invalidated = 0
        self.n_refreshed = 0

        if warm == "refresh":
            self.h1, self.h1_scale = self.encode_cache(self.compute_layer1_full())
            self.valid[: store.n_active] = True
        elif warm == "tables":
            if table_h1 is None:
                raise ValueError("warm='tables' needs the scattered table_h1")
            h = np.zeros((cap, HIDDEN[0]), np.float32)
            h[: len(table_h1)] = table_h1
            self.h1, self.h1_scale = self.encode_cache(jnp.asarray(h))
            self.valid[: store.n_active] = True
        else:                                                # cold
            self.h1, self.h1_scale = self.encode_cache(
                jnp.zeros((cap, HIDDEN[0]), jnp.float32))

    # -- construction ----------------------------------------------------

    def encode_cache(self, h):
        """Encode a fp32 (cap, H1) table into the resident cache format —
        ``(payload, scale_or_None)`` per ``cache_dtype``."""
        return quant_encode(h, self.cache_dtype)

    def h1_f32(self) -> jnp.ndarray:
        """The dequantized (cap, H1) cache — what the traced query bodies
        read (identity for fp32)."""
        return quant_decode(self.h1, self.h1_scale, self.cache_dtype)

    @classmethod
    def restore(cls, directory: str, graph, fed, *, step: int | None = None,
                backend: str = "segment", warm: str = "refresh",
                capacity: int | None = None, seed: int = 0,
                headroom: float = 0.25,
                cache_dtype: str = "fp32") -> "ServedModel":
        """Load a federation checkpoint and build the serving state.

        ``seed`` must match the training engine's seed so the padded
        neighbor arrays equal the training eval graph's (bit-parity).
        ``step=None`` auto-picks the newest checkpoint (``load_latest``).
        """
        template = federation_template(fed)
        if step is None:
            step, tree = load_latest(directory, template)
        else:
            tree = load_checkpoint(directory, step, template)
        idx, mask = build_padded_neighbors(graph.adjacency_lists(),
                                           fed.max_deg, seed=seed)
        store = GraphStore(graph.features, idx, mask, capacity=capacity,
                           seed=seed, headroom=headroom)
        table_h1 = _scatter_tables(fed, tree["hist1"])
        table_age = _scatter_tables(fed, tree["age"]).astype(np.int64)
        return cls(tree["params"], store, backend=backend, warm=warm,
                   table_h1=table_h1, table_age=table_age, restored_step=step,
                   cache_dtype=cache_dtype)

    # -- cache compute / bookkeeping -------------------------------------

    @property
    def n_active(self) -> int:
        return self.store.n_active

    @property
    def cache_age(self) -> np.ndarray:
        """Serve steps since each row was last written (active rows)."""
        return (self.step - self.row_version)[: self.n_active]

    def aggregation_operands(self, nbr_idx: np.ndarray,
                             nbr_mask: np.ndarray) -> dict:
        """Backend-specific static operands for ``neighbor_aggregate`` over
        the given padded rows (CSR edge arrays / dense adjacency)."""
        if self.backend == "segment":
            c = csr_from_padded(nbr_idx, nbr_mask)
            return {"csr": {k: jnp.asarray(v) for k, v in c.items()}}
        if self.backend == "spmm":
            from repro.kernels.spmm.ops import adjacency_from_neighbors

            return {"adj": adjacency_from_neighbors(
                jnp.asarray(nbr_idx), jnp.asarray(nbr_mask), self.store.capacity)}
        return {}

    def compute_layer1_full(self) -> jnp.ndarray:
        """One full layer-0 pass over the (capacity-padded) graph — the warm
        cache fill. Rows < n_active are bit-identical to the eval path's
        internal h1 (same backend, same padded-neighbor operands)."""
        s = self.store
        kw = self.aggregation_operands(s.nbr_idx, s.nbr_mask)
        return _layer1_full(self.params, self.feat, jnp.asarray(s.nbr_idx),
                            jnp.asarray(s.nbr_mask), backend=self.backend, **kw)

    def ensure_capacity(self) -> bool:
        """Mirror a :class:`GraphStore` capacity growth into the device
        state: re-pull the feature mirror (the store already holds every
        row), zero-extend the h1 cache (old rows copied bit-for-bit — the
        warm cache survives the growth), and pad the host bookkeeping.
        Returns True if anything was re-allocated (the caller must then
        re-warm its compiled shapes, since (capacity, ·) operands changed)."""
        cap = self.store.capacity
        old = self.h1.shape[0]
        if cap == old:
            return False
        self.feat = jnp.asarray(self.store.features)
        self.h1 = jnp.zeros((cap, self.h1.shape[1]),
                            self.h1.dtype).at[:old].set(self.h1)
        if self.h1_scale is not None:
            self.h1_scale = jnp.zeros(
                (cap, 1), self.h1_scale.dtype).at[:old].set(self.h1_scale)
        self.valid = np.concatenate([self.valid, np.zeros(cap - old, bool)])
        self.row_version = np.concatenate(
            [self.row_version, np.full(cap - old, self.step, np.int64)])
        return True

    def invalidate(self, rows: np.ndarray) -> int:
        rows = np.asarray(rows, np.int64)
        n_new = int(self.valid[rows].sum())
        self.valid[rows] = False
        self.n_invalidated += len(rows)
        return n_new

    def mark_written(self, rows: np.ndarray) -> None:
        self.valid[rows] = True
        self.row_version[rows] = self.step
        self.n_refreshed += len(rows)

    def set_features(self, rows: np.ndarray, feats: np.ndarray) -> None:
        """Mirror a GraphStore feature write into the device copy."""
        self.feat = self.feat.at[jnp.asarray(rows)].set(
            jnp.asarray(feats, jnp.float32))

    def invalid_rows(self) -> np.ndarray:
        return np.flatnonzero(~self.valid[: self.n_active])

    def nonfinite_rows(self) -> np.ndarray:
        """Active cache rows holding any non-finite embedding — the health
        probe chaos runs watch to prove poisoned refreshes never land.
        Quantized caches are checked on their decoded values (a poisoned
        int8 row surfaces through its NaN scale)."""
        h = np.asarray(self.h1_f32()[: self.n_active], np.float32)
        return np.flatnonzero(~np.isfinite(h).all(axis=1))

    def cache_resident_bytes(self) -> int:
        """Device bytes the h1 cache actually holds resident (payload +
        int8 scales) — the serve half of the quantized-sync ledger."""
        total = int(self.h1.nbytes)
        if self.h1_scale is not None:
            total += int(self.h1_scale.nbytes)
        return total

    def summary(self) -> dict:
        age = self.cache_age
        out = {
            "n_active": self.n_active,
            "capacity": self.store.capacity,
            "restored_step": self.restored_step,
            "backend": self.backend,
            "warm": self.warm,
            "valid_frac": float(self.valid[: self.n_active].mean())
            if self.n_active else 1.0,
            "cache_age_mean": float(age.mean()) if len(age) else 0.0,
            "cache_age_max": int(age.max()) if len(age) else 0,
            "rows_invalidated": self.n_invalidated,
            "rows_refreshed": self.n_refreshed,
            "h1_finite_frac": (1.0 - len(self.nonfinite_rows()) / self.n_active)
            if self.n_active else 1.0,
            "cache_dtype": self.cache_dtype,
            "cache_resident_bytes": self.cache_resident_bytes(),
        }
        if self.table_age is not None:
            out["table_age_mean"] = float(self.table_age.mean())
            out["table_age_max"] = int(self.table_age.max())
        return out
