"""BENCH_round.json schema guard (benchmarks.perf_round.validate_bench_round).

perf_round.py rewrites BENCH_round.json from three different run modes
(plain, --sharded, --sharded-only merge), each preserving parts of the
previous payload — so a malformed file would propagate forward silently
and surface only as an undiagnosable perf-smoke failure. The validator
refuses to write such payloads; these tests pin what it catches.
"""
import copy
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)           # benchmarks/ is a repo-root package

from benchmarks.perf_round import validate_bench_round  # noqa: E402


def good_payload():
    return {
        "bench": "round_throughput",
        "backend": "cpu",
        "devices": 1,
        "quick": True,
        "fused_speedup": 3.5,
        "sharded_rounds_per_s": 4.5,
        "sharded_devices": 8,
        "rows": [
            {"variant": "stepwise", "rounds_per_s": 3.0},
            {"variant": "fused", "rounds_per_s": 10.5,
             "speedup_vs_stepwise": 3.5},
            {"variant": "sharded_fused", "rounds_per_s": 4.5, "devices": 8},
            {"variant": "eval_gather", "ms_per_eval": 2.0},
        ],
    }


def test_good_payload_validates():
    assert validate_bench_round(good_payload()) == []


def test_checked_in_bench_file_validates():
    with open(os.path.join(REPO_ROOT, "BENCH_round.json")) as f:
        assert validate_bench_round(json.load(f)) == []


def test_non_dict_and_missing_keys():
    assert validate_bench_round([1, 2]) != []
    for key in ("bench", "devices", "fused_speedup", "sharded_rounds_per_s",
                "sharded_devices", "rows"):
        p = good_payload()
        del p[key]
        assert any(key in e for e in validate_bench_round(p)), key


def test_gated_rows_must_not_be_silently_nulled():
    # dropping the stepwise row (a bad merge) is an error...
    p = good_payload()
    p["rows"] = [r for r in p["rows"] if r["variant"] != "stepwise"]
    assert any("stepwise" in e for e in validate_bench_round(p))
    # ...unless explicitly permitted (fresh --sharded-only run, no prev)
    assert validate_bench_round(p, require_gated=False) == []

    # a gated row whose throughput got nulled is never OK
    p2 = good_payload()
    p2["rows"][1]["rounds_per_s"] = None
    assert any("fused" in e for e in validate_bench_round(p2))
    p3 = good_payload()
    p3["rows"][0]["rounds_per_s"] = 0.0
    assert any("stepwise" in e for e in validate_bench_round(p3))

    # gated rows present but the speedup column nulled: the gate's input
    # vanished even though both measurements exist
    p4 = good_payload()
    p4["fused_speedup"] = None
    assert any("fused_speedup" in e for e in validate_bench_round(p4))


def test_row_and_type_errors():
    p = good_payload()
    p["rows"].append({"rounds_per_s": 1.0})        # no variant label
    assert any("variant" in e for e in validate_bench_round(p))
    p = good_payload()
    p["rows"] = []
    assert any("rows" in e for e in validate_bench_round(p))
    p = good_payload()
    p["devices"] = "one"
    assert any("devices" in e for e in validate_bench_round(p))
    p = good_payload()
    p["quick"] = "yes"
    assert any("quick" in e for e in validate_bench_round(p))
    p = good_payload()
    p["bench"] = "something_else"
    assert any("bench" in e for e in validate_bench_round(p))


def test_sharded_column_consistency():
    # value and device count must null together (the carry-forward logic
    # moves them as a pair)
    p = good_payload()
    p["sharded_devices"] = None
    assert any("together" in e for e in validate_bench_round(p))
    p = good_payload()
    p["sharded_rounds_per_s"] = None
    p["sharded_devices"] = None
    assert validate_bench_round(p) == []
    p = good_payload()
    p["sharded_rounds_per_s"] = -1.0
    assert any("sharded_rounds_per_s" in e for e in validate_bench_round(p))


def test_validator_is_pure():
    p = good_payload()
    snapshot = copy.deepcopy(p)
    validate_bench_round(p)
    assert p == snapshot


def train_rows():
    return [
        {"variant": "train_segment", "rounds": 20, "rounds_per_s": 11.0,
         "ms_per_round": 90.9, "speedup_vs_gather": 1.05},
        {"variant": "train_spmm", "rounds": 2, "rounds_per_s": 0.2,
         "ms_per_round": 5000.0},
    ]


def test_train_backend_rows_validate():
    p = good_payload()
    p["rows"] += train_rows()
    assert validate_bench_round(p) == []


def test_train_backend_row_errors():
    # train_segment without its gate input (the speedup-vs-gather column)
    p = good_payload()
    p["rows"] += train_rows()
    del p["rows"][-2]["speedup_vs_gather"]
    assert any("speedup_vs_gather" in e for e in validate_bench_round(p))
    p = good_payload()
    p["rows"] += train_rows()
    p["rows"][-2]["speedup_vs_gather"] = 0.0
    assert any("speedup_vs_gather" in e for e in validate_bench_round(p))
    # nulled throughput on either training row
    for i in (-2, -1):
        p = good_payload()
        p["rows"] += train_rows()
        p["rows"][i]["rounds_per_s"] = None
        assert any("rounds_per_s" in e for e in validate_bench_round(p)), i


def test_checked_in_bench_round_carries_train_segment():
    """The committed ledger must keep the gated training-backend row — the
    CI perf-smoke gate reads its speedup_vs_gather column."""
    with open(os.path.join(REPO_ROOT, "BENCH_round.json")) as f:
        rows = [r for r in json.load(f)["rows"]
                if r.get("variant") == "train_segment"]
    assert rows, "BENCH_round.json lost its train_segment row"
    assert rows[0]["speedup_vs_gather"] > 0


# ---------------------------------------------------------------------------
# BENCH_serve.json schema guard (repro.serve.loadgen.validate_bench_serve)
# ---------------------------------------------------------------------------

from repro.serve import validate_bench_serve  # noqa: E402


def good_serve_payload():
    return {
        "bench": "serve_latency",
        "backend": "segment",
        "devices": 1,
        "quick": True,
        "mode": "open",
        "policy_mix": {"historical": 0.9, "fresh": 0.1},
        "n_queries": 10,
        "n_updates": 2,
        "queries_per_s": 120.0,
        "p50_ms": 1.5,
        "p99_ms": 9.0,
        "batch_occupancy": 0.6,
        "cache_hit_rate": 0.97,
        "invalidation_rate": 0.05,
        "rows_invalidated": 4,
        "rows_refreshed": 4,
        "buckets": [
            {"bucket": 8, "n": 7, "p50_ms": 1.2, "p99_ms": 3.0},
            {"bucket": 32, "n": 3, "p50_ms": 4.0, "p99_ms": 9.0},
        ],
    }


def test_good_serve_payload_validates():
    assert validate_bench_serve(good_serve_payload()) == []


def test_checked_in_serve_bench_validates():
    path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("no BENCH_serve.json checked in")
    with open(path) as f:
        assert validate_bench_serve(json.load(f)) == []


def test_serve_missing_keys_and_types():
    assert validate_bench_serve("nope") != []
    for key in ("bench", "mode", "policy_mix", "n_queries", "queries_per_s",
                "p50_ms", "cache_hit_rate", "buckets"):
        p = good_serve_payload()
        del p[key]
        assert any(key in e for e in validate_bench_serve(p)), key
    p = good_serve_payload()
    p["bench"] = "round_throughput"
    assert any("bench" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["mode"] = "sideways"
    assert any("mode" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["policy_mix"] = {"psychic": 1.0}
    assert any("policy_mix" in e for e in validate_bench_serve(p))


def test_serve_percentiles_and_rates():
    p = good_serve_payload()
    p["p99_ms"] = 0.1                       # below p50: impossible
    assert any("p99_ms" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["queries_per_s"] = 0.0
    assert any("queries_per_s" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["cache_hit_rate"] = 1.2
    assert any("cache_hit_rate" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["batch_occupancy"] = 0.0              # served queries imply occupancy
    assert any("batch_occupancy" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["rows_refreshed"] = -1
    assert any("rows_refreshed" in e for e in validate_bench_serve(p))


def test_serve_bucket_rows_must_account_for_all_queries():
    p = good_serve_payload()
    p["buckets"][1]["n"] = 2                # 7 + 2 != 10
    assert any("account" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["buckets"] = []
    assert any("buckets" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    del p["buckets"][0]["p50_ms"]
    assert any("buckets[0]" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["buckets"][0]["p99_ms"] = 0.5         # below its p50
    assert any("buckets[0]" in e for e in validate_bench_serve(p))


def test_serve_validator_is_pure():
    p = good_serve_payload()
    snapshot = copy.deepcopy(p)
    validate_bench_serve(p)
    assert p == snapshot


# ---------------------------------------------------------------------------
# fed_dryrun placement-ledger schema guard (repro.launch.fed_dryrun)
# ---------------------------------------------------------------------------

from repro.launch.fed_dryrun import (  # noqa: E402
    assert_k_flat,
    pod_placement_ledger,
    synthetic_ghost_buckets,
    validate_fed_dryrun,
)


def dryrun_result(clients=16, rpp_scale=1, sync_dtype="fp32"):
    """A --pods dry-run result row built from the real ledger function over
    a synthetic topology (no XLA lowering needed)."""
    b = synthetic_ghost_buckets(clients, 8, 4, 2)
    ledger = pod_placement_ledger(b, n_pods=2, cohort_pad=8, wb_cap=4,
                                  n_max=8, g_max=4, n_feat=8, n_classes=3,
                                  tau=8, local_epochs=4,
                                  sync_dtype=sync_dtype)
    ledger["all_to_all_bytes"] = 1000
    ledger["all_gather_bytes"] = 500
    return {
        "status": "ok", "arch": "fedgcn-graphsage", "mesh": "host",
        "chips": 8, "clients": clients, "cohort": 8,
        "collectives": {"all-gather": 500, "all-reduce": 2000},
        "roofline": {}, "pods": ledger,
    }


def test_good_dryrun_result_validates():
    assert validate_fed_dryrun(dryrun_result()) == []
    # non-pods rows (client-sharded mode) validate without a ledger
    r = dryrun_result()
    del r["pods"]
    assert validate_fed_dryrun(r) == []


def test_dryrun_missing_keys_and_types():
    assert validate_fed_dryrun([]) != []
    r = dryrun_result()
    del r["collectives"]
    assert any("collectives" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    del r["pods"]["sync"]
    assert any("sync" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    r["pods"]["per_device_resident_bytes"]["k_sharded"]["hist1"] = -1
    assert any("hist1" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    r["pods"]["per_round_collective_bytes"]["cohort_scaled"] = {}
    assert any("cohort_scaled" in e for e in validate_fed_dryrun(r))


def test_dryrun_sync_contract_enforced():
    r = dryrun_result()
    r["pods"]["sync"]["sync_fraction"] = 1.5
    assert any("sync_fraction" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    r["pods"]["sync"]["non_sync_round_ghost_bytes"] = 8
    assert any("non_sync" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    r["pods"]["sync"]["ghost_all_to_all_effective_bytes"] += 1
    assert any("effective" in e for e in validate_fed_dryrun(r))


def test_dryrun_validator_is_pure():
    r = dryrun_result()
    snapshot = copy.deepcopy(r)
    validate_fed_dryrun(r)
    assert r == snapshot


def test_assert_k_flat_passes_on_scaled_ledgers():
    """Two ledgers that differ only in K: replicated/cohort columns are
    byte-identical by construction and k_sharded is linear in K/P."""
    a, b = dryrun_result(clients=16), dryrun_result(clients=64)
    assert a["pods"]["table_shard_rows_per_pod"] \
        != b["pods"]["table_shard_rows_per_pod"]
    assert assert_k_flat(a, b) == []


# ---------------------------------------------------------------------------
# BENCH_faults.json schema guard (repro.launch.fed_chaos)
# ---------------------------------------------------------------------------

from repro.launch.fed_chaos import validate_bench_faults  # noqa: E402


def good_faults_payload():
    row = {
        "scenario": "drop0.3", "scheduler": "sync_fused",
        "executor": "fused_faulty", "dropout": 0.3, "straggler_frac": 0.0,
        "corrupt": 0.0, "corrupt_mode": "nan", "baseline_acc": 0.8,
        "final_acc": 0.75, "acc_delta": 0.05, "rounds_completed": 6,
        "params_finite": True, "crashed": False,
        "faults": {"n_dropped": 7, "n_quarantined": 0, "n_empty_merges": 0},
    }
    base = dict(row, scenario="none", dropout=0.0, final_acc=0.8,
                acc_delta=0.0, faults={})
    return {
        "bench": "fault_tolerance", "devices": 8, "quick": True, "seed": 0,
        "dataset": "pubmed", "scale": 32, "clients": 8, "rounds": 6,
        "cohort": 4, "method": "fedais", "acc_bound": 0.3,
        "max_acc_delta": 0.05, "crashes": 0, "all_finite": True,
        "rows": [base, row],
        "serve": {"n_fallbacks": 1, "n_degraded": 0, "n_rejected": 3,
                  "n_shed": 3, "fresh_fell_back": True,
                  "fallback_finite": True, "fallback_matches_warm": True,
                  "h1_finite_frac": 1.0},
        "ckpt": {"torn_step": 2, "recovered_step": 1, "recovered": True},
    }


def test_good_faults_payload_validates():
    assert validate_bench_faults(good_faults_payload()) == []


def test_checked_in_faults_bench_validates():
    path = os.path.join(REPO_ROOT, "BENCH_faults.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("no BENCH_faults.json checked in")
    with open(path) as f:
        assert validate_bench_faults(json.load(f)) == []


def test_faults_missing_keys_and_types():
    assert validate_bench_faults(None) != []
    for key in ("bench", "devices", "crashes", "all_finite", "rows",
                "serve", "ckpt", "max_acc_delta", "acc_bound"):
        p = good_faults_payload()
        del p[key]
        assert any(key in e for e in validate_bench_faults(p)), key
    p = good_faults_payload()
    p["bench"] = "serve_latency"
    assert any("bench" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["crashes"] = -1
    assert any("crashes" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["rows"] = []
    assert any("rows" in e for e in validate_bench_faults(p))


def test_faults_row_errors():
    p = good_faults_payload()
    del p["rows"][1]["executor"]
    assert any("rows[1]" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["rows"][1]["dropout"] = 1.5
    assert any("dropout" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["rows"][1]["rounds_completed"] = -2
    assert any("rounds_completed" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["rows"][1]["crashed"] = "no"
    assert any("crashed" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["rows"][1]["faults"] = None
    assert any("faults" in e for e in validate_bench_faults(p))


def test_faults_aggregates_must_match_rows():
    # a crashed row the top-level counter doesn't admit to
    p = good_faults_payload()
    p["rows"][1]["crashed"] = True
    assert any("crashed" in e for e in validate_bench_faults(p))
    p["crashes"] = 1
    assert validate_bench_faults(p) == []
    # a max_acc_delta that understates the worst row
    p = good_faults_payload()
    p["max_acc_delta"] = 0.0
    assert any("max_acc_delta" in e for e in validate_bench_faults(p))


def test_faults_serve_and_ckpt_sections():
    p = good_faults_payload()
    del p["serve"]["n_fallbacks"]
    assert any("n_fallbacks" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["serve"]["h1_finite_frac"] = 1.5
    assert any("h1_finite_frac" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    del p["ckpt"]["recovered_step"]
    assert any("recovered_step" in e for e in validate_bench_faults(p))
    p = good_faults_payload()
    p["ckpt"]["recovered"] = 1
    assert any("recovered" in e for e in validate_bench_faults(p))


def test_faults_validator_is_pure():
    p = good_faults_payload()
    snapshot = copy.deepcopy(p)
    validate_bench_faults(p)
    assert p == snapshot


def test_assert_k_flat_catches_k_scaling():
    a, b = dryrun_result(clients=16), dryrun_result(clients=64)
    b["pods"]["per_device_resident_bytes"]["replicated"]["params"] += 4
    assert any("replicated.params" in e for e in assert_k_flat(a, b))
    a, b = dryrun_result(clients=16), dryrun_result(clients=64)
    b["pods"]["per_round_collective_bytes"]["cohort_scaled"][
        "fetch_psum_tables"] *= 2
    assert any("fetch_psum_tables" in e for e in assert_k_flat(a, b))
    a, b = dryrun_result(clients=16), dryrun_result(clients=64)
    b["pods"]["per_device_resident_bytes"]["k_sharded"]["hist1"] += 4
    assert any("k_sharded.hist1" in e for e in assert_k_flat(a, b))
    a, b = dryrun_result(clients=16), dryrun_result(clients=64)
    b["collectives"]["all-gather"] *= 3
    assert any("all-gather" in e for e in assert_k_flat(a, b))


# ---------------------------------------------------------------------------
# quantized-sync columns: BENCH_round quant_ablation rows, the BENCH_serve
# cache column, the dry-run ledger's quant section + assert_quant_bytes
# ---------------------------------------------------------------------------

from repro.federated.quant import SYNC_DTYPES  # noqa: E402
from repro.launch.fed_dryrun import assert_quant_bytes  # noqa: E402


def quant_rows(tau=2):
    """A minimal valid quant_ablation pair: the fp32 baseline + one lossy
    dtype at the same tau (what --quant-ablation writes per grid point)."""
    base = {"variant": "quant_ablation", "tau": tau, "rounds": 20,
            "clients": 256, "cohort": 4, "test_acc": 0.97}
    return [
        dict(base, sync_dtype="fp32", embed_wire_bytes=1000.0,
             embed_fp32_bytes=1000.0, wire_reduction=1.0),
        dict(base, sync_dtype="int8", embed_wire_bytes=255.0,
             embed_fp32_bytes=1000.0, wire_reduction=3.92),
    ]


def test_quant_ablation_rows_validate():
    p = good_payload()
    p["rows"] += quant_rows()
    assert validate_bench_round(p) == []


def test_quant_ablation_row_errors():
    p = good_payload()
    p["rows"] += quant_rows()
    p["rows"][-1]["sync_dtype"] = "fp8"
    assert any("sync_dtype" in e for e in validate_bench_round(p))
    p = good_payload()
    p["rows"] += quant_rows()
    p["rows"][-1]["tau"] = 0
    assert any("tau" in e for e in validate_bench_round(p))
    p = good_payload()
    p["rows"] += quant_rows()
    p["rows"][-1]["test_acc"] = 1.2
    assert any("test_acc" in e for e in validate_bench_round(p))
    # wire bytes above the fp32 nominal: quantization cannot cost bytes
    p = good_payload()
    p["rows"] += quant_rows()
    p["rows"][-1]["embed_wire_bytes"] = 2000.0
    assert any("embed_wire_bytes" in e for e in validate_bench_round(p))
    # the fp32 baseline row must be bit-inert on the wire
    p = good_payload()
    p["rows"] += quant_rows()
    p["rows"][-2]["embed_wire_bytes"] = 999.0
    assert any("fp32" in e for e in validate_bench_round(p))


def test_quant_ablation_requires_fp32_baseline_per_tau():
    # an int8 row at tau=8 with no fp32 companion: the reduction column
    # has nothing to be relative to
    p = good_payload()
    p["rows"] += quant_rows() + [quant_rows(tau=8)[1]]
    assert any("tau=8" in e and "fp32" in e for e in validate_bench_round(p))
    p["rows"].append(quant_rows(tau=8)[0])
    assert validate_bench_round(p) == []


def test_checked_in_bench_round_carries_quant_ablation():
    """The committed ledger must keep its accuracy-vs-bytes rows — a merge
    that drops them would pass the validator (they are optional rows) but
    silently lose the ablation; this pin and CI's bench-schema job refuse."""
    with open(os.path.join(REPO_ROOT, "BENCH_round.json")) as f:
        rows = [r for r in json.load(f)["rows"]
                if r.get("variant") == "quant_ablation"]
    assert rows, "BENCH_round.json lost its quant_ablation rows"
    assert {r["sync_dtype"] for r in rows} == set(SYNC_DTYPES)


def serve_cache_col(dtype="int8"):
    return {"cache_dtype": dtype, "resident_bytes": 100100,
            "serve_accuracy": 0.94}


def test_serve_cache_column_validates():
    for d in SYNC_DTYPES:
        p = good_serve_payload()
        p["cache"] = serve_cache_col(d)
        assert validate_bench_serve(p) == [], d


def test_serve_cache_column_errors():
    p = good_serve_payload()
    p["cache"] = "int8"
    assert any("cache" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["cache"] = serve_cache_col("fp16")
    assert any("cache_dtype" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["cache"] = serve_cache_col()
    p["cache"]["resident_bytes"] = 0
    assert any("resident_bytes" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["cache"] = serve_cache_col()
    p["cache"]["serve_accuracy"] = 1.01
    assert any("serve_accuracy" in e for e in validate_bench_serve(p))
    p = good_serve_payload()
    p["cache"] = serve_cache_col()
    del p["cache"]["serve_accuracy"]
    assert any("serve_accuracy" in e for e in validate_bench_serve(p))


def test_checked_in_bench_serve_carries_cache_column():
    path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("no BENCH_serve.json checked in")
    with open(path) as f:
        cache = json.load(f).get("cache")
    assert isinstance(cache, dict), "BENCH_serve.json lost its cache column"
    assert cache["cache_dtype"] in SYNC_DTYPES


def test_dryrun_quant_section_validates_per_dtype():
    for d in SYNC_DTYPES:
        r = dryrun_result(sync_dtype=d)
        assert validate_fed_dryrun(r) == [], d
        wire = r["pods"]["quant"]["wire_collective_bytes"]
        fp32w = r["pods"]["quant"]["fp32_collective_bytes"]
        if d == "fp32":
            assert wire == fp32w
        else:
            assert all(wire[k] < fp32w[k] for k in wire)


def test_dryrun_quant_section_errors():
    r = dryrun_result()
    del r["pods"]["quant"]
    assert any("quant" in e for e in validate_fed_dryrun(r))
    r = dryrun_result()
    r["pods"]["quant"]["sync_dtype"] = "fp8"
    assert any("sync_dtype" in e for e in validate_fed_dryrun(r))
    # a wire entry above its fp32 nominal
    r = dryrun_result(sync_dtype="int8")
    ga = r["pods"]["quant"]["fp32_collective_bytes"]["ghost_all_to_all"]
    r["pods"]["quant"]["wire_collective_bytes"]["ghost_all_to_all"] = ga + 1
    assert any("exceeds" in e for e in validate_fed_dryrun(r))
    # the fp32 column drifting from the nominal ledger entry
    r = dryrun_result()
    r["pods"]["quant"]["fp32_collective_bytes"]["wb_stage1_all_gather"] += 8
    assert any("restate" in e for e in validate_fed_dryrun(r))
    # at fp32 the wire must be bit-inert (wire == fp32 column)
    r = dryrun_result()
    r["pods"]["quant"]["wire_collective_bytes"]["ghost_all_to_all"] //= 2
    assert any("bit-inert" in e for e in validate_fed_dryrun(r))


def _quant_pair():
    """fp32/int8 dry-run results satisfying the assert_quant_bytes contract
    (the real ledgers provide the analytic halving; the fake HLO collectives
    are scaled by hand)."""
    a = dryrun_result()
    b = dryrun_result(sync_dtype="int8")
    b["collectives"] = {"all-gather": 125, "all-reduce": 2000}
    return a, b


def test_assert_quant_bytes_passes_on_halved_wires():
    a, b = _quant_pair()
    assert assert_quant_bytes(a, b) == []


def test_assert_quant_bytes_catches_violations():
    # an analytic wire entry that did not halve
    a, b = _quant_pair()
    b["pods"]["quant"]["wire_collective_bytes"]["ghost_all_to_all"] = \
        a["pods"]["quant"]["wire_collective_bytes"]["ghost_all_to_all"]
    assert any("ghost_all_to_all" in e for e in assert_quant_bytes(a, b))
    # lowered HLO bytes that did not halve (the codec never reached XLA)
    a, b = _quant_pair()
    b["collectives"]["all-gather"] = 300
    assert any("all-gather" in e for e in assert_quant_bytes(a, b))
    # residents must stay fp32: a narrowed table shard is a contract breach
    a, b = _quant_pair()
    b["pods"]["per_device_resident_bytes"]["k_sharded"]["hist1"] //= 4
    assert any("resident" in e for e in assert_quant_bytes(a, b))
