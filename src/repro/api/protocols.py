"""Pluggable component protocols for the FedEngine, plus default impls.

Each protocol isolates one axis of the method-space that the paper's
Algorithm 1 fixes to a single choice:

    ClientSelector  which clients participate in a round
    Aggregator      how client models merge on the server
    SyncController  how the embedding-sync interval tau evolves (Eq. 11)
    CostModel       what a round costs (bytes / FLOPs / wall-clock)
    RoundCallback   side effects at round boundaries (eval, logging, ...)

Default implementations reproduce the legacy ``run_federated`` loop
bit-for-bit (see tests/test_api.py parity tests). Custom components are
plain objects satisfying the protocol — no registration required, pass
them to ``FedEngine(..., selector=..., aggregator=...)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.federated.costs import CostMeter, DelayModel, embed_sync_bytes, model_bytes
from repro.federated.server import fedavg, fedavg_weighted, select_clients, update_tau

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.engine import EngineState, FedEngine
    from repro.core.fedais import MethodConfig


# ---------------------------------------------------------------------------
# client selection
# ---------------------------------------------------------------------------

@runtime_checkable
class ClientSelector(Protocol):
    def select(self, engine: "FedEngine", state: "EngineState") -> np.ndarray:
        """Return the ids of the clients participating this round."""
        ...


class UniformSelector:
    """Uniform without replacement — the paper's (and legacy loop's) choice."""

    def select(self, engine, state):
        return select_clients(state.rng, engine.fed.n_clients,
                              engine.clients_per_round)


class SizeBiasedSelector:
    """Sample clients with probability proportional to local dataset size.
    Empty clients (a skewed Dirichlet partition can produce them) are never
    selected; the round shrinks if fewer non-empty clients exist than m."""

    def select(self, engine, state):
        sizes = engine.fed.client_sizes.astype(np.float64)
        p = sizes / max(sizes.sum(), 1.0)
        m = min(engine.clients_per_round, engine.fed.n_clients,
                int(np.count_nonzero(p)))
        return state.rng.choice(engine.fed.n_clients, size=m, replace=False, p=p)


class LossBiasedSelector:
    """Prefer clients whose last-seen mean local loss is highest (never-seen
    clients rank first) — the round-level analogue of Eq. 7's node scores."""

    def select(self, engine, state):
        pl = np.asarray(state.prev_loss)
        # padded slots of a visited client hold 0.0 (loss_all is node-masked),
        # so average only over real nodes with an observed loss
        node_mask = np.asarray(engine.fed.node_mask) > 0
        real = (pl >= 0) & node_mask
        mean_loss = (pl * real).sum(axis=1) / np.maximum(real.sum(axis=1), 1)
        # unseen (but non-empty) clients rank first; clients with no nodes at
        # all can never produce a loss and must rank last, not first forever
        scores = np.where(real.any(axis=1), mean_loss, np.inf)
        scores = np.where(node_mask.any(axis=1), scores, -np.inf)
        # random tie-break keeps unseen clients in shuffled order
        tie = state.rng.random(engine.fed.n_clients)
        order = np.lexsort((tie, -scores))
        m = min(engine.clients_per_round, engine.fed.n_clients)
        return order[:m]


# ---------------------------------------------------------------------------
# server-side aggregation
# ---------------------------------------------------------------------------

@runtime_checkable
class Aggregator(Protocol):
    def aggregate(self, stacked_params, weights=None):
        """Merge a (m, ...) stacked client pytree into one global pytree."""
        ...


class FedAvg:
    """Unweighted mean over the selected clients — Algorithm 1 line 7."""

    def aggregate(self, stacked_params, weights=None):
        return fedavg(stacked_params)


class WeightedFedAvg:
    """Dataset-size-weighted FedAvg (McMahan et al.); the engine passes
    ``fed.client_sizes[sel]`` as the weights."""

    def aggregate(self, stacked_params, weights=None):
        if weights is None:
            raise ValueError("WeightedFedAvg needs per-client weights")
        return fedavg_weighted(stacked_params, jnp.asarray(weights, jnp.float32))


# ---------------------------------------------------------------------------
# sync-interval control
# ---------------------------------------------------------------------------

@runtime_checkable
class SyncController(Protocol):
    def initial(self, mcfg: "MethodConfig") -> int:
        ...

    def update(self, mcfg: "MethodConfig", test_loss: float,
               initial_loss: float) -> int:
        ...


class AdaptiveSyncController:
    """Wraps server.update_tau: Eq. 11 when ``mcfg.adaptive_sync``, else the
    fixed interval tau0 (FedPNS-style)."""

    def initial(self, mcfg):
        return mcfg.tau0

    def update(self, mcfg, test_loss, initial_loss):
        return update_tau(mcfg, test_loss, initial_loss, mcfg.tau0)


class FixedSyncController:
    """Always tau0, regardless of the loss trajectory."""

    def initial(self, mcfg):
        return mcfg.tau0

    def update(self, mcfg, test_loss, initial_loss):
        return mcfg.tau0


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------

@runtime_checkable
class CostModel(Protocol):
    def round_cost(self, engine: "FedEngine", state: "EngineState",
                   sel: np.ndarray, stats: dict) -> CostMeter:
        ...


@dataclass
class PaperCostModel:
    """The paper's analytic byte/FLOP/delay accounting (Fig. 3/4 axes),
    lifted verbatim from the legacy loop. Method-specific extras (FedSage+
    generator traffic/compute) come from the strategy's cost hooks, keeping
    this model branch-free."""

    delay: DelayModel = field(default_factory=DelayModel)

    def round_cost(self, engine, state, sel, stats):
        fed, mcfg = engine.fed, engine.mcfg
        cost = CostMeter()
        n_sync = np.asarray(stats["n_sync"])
        n_pulled = np.asarray(stats["n_ghost_pulled"])
        sizes = fed.client_sizes[sel]
        extra_bytes = engine.strategy.round_model_bytes(engine)
        per_client_compute = []
        for i, _k in enumerate(sel):
            comm_model = 2 * model_bytes(engine.n_params) + extra_bytes
            comm_embed = embed_sync_bytes(n_pulled[i], (engine.F, engine.H1))
            nodes_processed = sizes[i] + mcfg.local_epochs * min(
                engine.bsz, max(int(sizes[i]), 1))
            flops = 3.0 * engine.fwd_flops_node * nodes_processed \
                + engine.strategy.extra_flops(engine, sizes[i])
            cost.comm_model_bytes += comm_model
            cost.comm_embed_bytes += comm_embed
            cost.compute_flops += flops
            per_client_compute.append(self.delay.compute_time(flops))
        o = self.delay.comm_time(
            cost.comm_embed_bytes / max(len(sel), 1)
            + 2 * model_bytes(engine.n_params))
        cost.wall_clock_s = max(per_client_compute) + o / max(state.tau, 1)
        cost.sync_events = int(n_sync.sum())
        return cost


# ---------------------------------------------------------------------------
# round callbacks
# ---------------------------------------------------------------------------

@runtime_checkable
class RoundCallback(Protocol):
    """Side-effect hooks; see repro.api.callbacks for the default stack."""

    def on_run_start(self, engine: "FedEngine", state: "EngineState") -> None:
        ...

    def on_round_end(self, ctx) -> None:
        ...

    def on_run_end(self, engine: "FedEngine", state: "EngineState") -> None:
        ...
