"""Round-throughput benchmark: fused scanned executor vs stepwise loop.

The figure of merit is training-round throughput (rounds/s) of the
SyncScheduler hot path — the number every selector/method sweep pays per
grid point. The fused executor runs every round between eval boundaries as
one donated ``lax.scan`` XLA call; the stepwise loop pays per-round
dispatch, eager aggregation/write-back copies of the (K, n_tot, H1) tables,
and a host sync for cost accounting. The eval-side hot spot (full-graph
forward, O(N*K*F) per eval) is timed per aggregation backend alongside.

Writes ``BENCH_round.json`` at the repo root (the perf trajectory seed) and
``benchmarks/results/perf_round.json``. Exits non-zero from the CLI if the
fused executor is not faster than stepwise — the CI perf-smoke gate.
``--sharded`` additionally times the client-sharded fused executor over all
visible devices and records ``sharded_rounds_per_s`` (no gate: CPU shard_map
collective overhead may not win at quick shapes; the column tracks it).
``--sharded-only`` measures just that and merges it into the existing
BENCH_round.json without touching the gated single-device rows — so a
forced-multi-device rerun never overwrites the gate's own trajectory.

    PYTHONPATH=src python -m benchmarks.perf_round --quick
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.perf_round --quick --sharded-only
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit_csv, fed_setup, save_rows

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# BENCH_round.json schema: the perf-smoke gate and the forward-merge logic
# (plain runs carry the sharded column, sharded-only runs keep the gated
# rows) both rewrite the file, so malformed payloads would otherwise
# propagate silently until a CI failure nobody can diagnose.
_TOP_KEYS = ("bench", "backend", "devices", "quick", "fused_speedup",
             "sharded_rounds_per_s", "sharded_devices", "rows")
_GATED_VARIANTS = ("stepwise", "fused")


def validate_bench_round(payload, *, require_gated: bool = True) -> list[str]:
    """Schema-check a BENCH_round.json payload. Returns a list of problems
    (empty = valid): required keys present and typed, every row labelled
    with a variant, the gated single-device rows not silently nulled or
    dropped, and the sharded column's value/device-count consistent.
    ``require_gated=False`` permits a payload without the stepwise/fused
    rows — only legitimate for a fresh ``--sharded-only`` run with no
    previous BENCH_round.json to merge the gated rows from."""
    errs: list[str] = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected dict"]
    for k in _TOP_KEYS:
        if k not in payload:
            errs.append(f"missing key {k!r}")
    if errs:
        return errs
    if payload["bench"] != "round_throughput":
        errs.append(f"bench is {payload['bench']!r}, "
                    "expected 'round_throughput'")
    if not isinstance(payload["devices"], int) or payload["devices"] < 1:
        errs.append(f"devices must be a positive int, got {payload['devices']!r}")
    if not isinstance(payload["quick"], bool):
        errs.append(f"quick must be a bool, got {payload['quick']!r}")
    rows = payload["rows"]
    if not isinstance(rows, list) or not rows:
        return errs + ["rows must be a non-empty list"]
    by_variant: dict = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or not isinstance(row.get("variant"), str):
            errs.append(f"rows[{i}] has no string 'variant'")
            continue
        by_variant[row["variant"]] = row
    # the gated payload: stepwise + fused rows with real throughput numbers
    # and a non-null speedup — a merge that nulls any of these broke the gate
    for v in _GATED_VARIANTS:
        row = by_variant.get(v)
        if row is None:
            if require_gated:
                errs.append(f"gated row {v!r} missing")
        elif not isinstance(row.get("rounds_per_s"), (int, float)) \
                or not row["rounds_per_s"] > 0:
            errs.append(f"gated row {v!r} has no positive rounds_per_s "
                        f"(got {row.get('rounds_per_s')!r})")
    if all(v in by_variant for v in _GATED_VARIANTS):
        sp = payload["fused_speedup"]
        if not isinstance(sp, (int, float)) or not sp > 0:
            errs.append("fused_speedup nulled while gated rows exist "
                        f"(got {sp!r})")
    srps, sdev = payload["sharded_rounds_per_s"], payload["sharded_devices"]
    if srps is not None and (not isinstance(srps, (int, float)) or not srps > 0):
        errs.append(f"sharded_rounds_per_s must be None or positive, got {srps!r}")
    if (srps is None) != (sdev is None):
        errs.append("sharded_rounds_per_s and sharded_devices must be "
                    f"nulled together (got {srps!r} / {sdev!r})")
    if sdev is not None and (not isinstance(sdev, int) or sdev < 1):
        errs.append(f"sharded_devices must be None or a positive int, got {sdev!r}")
    return errs


def _time_run(make_engine, repeats: int = 3) -> float:
    """Median wall-clock of a full engine.run() after compile warmups."""
    eng = make_engine()
    eng.run()                                   # warmup 1: compiles
    eng.run()                                   # warmup 2: allocator settles
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        eng.run()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def run(quick: bool = True, sharded: bool = False,
        sharded_only: bool = False) -> list[dict]:
    from repro.api import FedEngine, SyncScheduler, method_config
    from repro.federated.server import build_eval_graph, evaluate_global
    from repro.models.gcn import AGG_BACKENDS, gcn_init

    # Cross-device regime: many clients, small sampled cohort. The stepwise
    # loop's per-round cost is dominated by the eager full-table copies
    # (hist1/age/ghost_feat scale with K, not with the cohort), which is
    # exactly what the donated scanned executor eliminates.
    ds = "pubmed"
    scale = 16 if quick else 8
    n_clients = 256
    m = 4 if quick else 8
    rounds = 20 if quick else 40
    g, fed = fed_setup(ds, scale, n_clients, "0.5")
    mcfg = method_config("fedais", tau0=4)

    # eval only at the scan boundaries (round 0 + last): both variants pay
    # the same two server evals, so the delta is pure round-loop overhead
    def make(fused):
        return FedEngine(g, fed, mcfg, rounds=rounds, clients_per_round=m,
                         seed=0, eval_every=rounds,
                         scheduler=SyncScheduler(fused=fused))

    # sharded-only mode (the CI multi-device step) measures just the sharded
    # variant plus an in-env fused reference, and merges the sharded column
    # into BENCH_round.json without touching the gated single-device
    # stepwise/fused rows — a forced-8-device rerun must not overwrite the
    # perf trajectory the gate actually ran in.
    sharded = sharded or sharded_only
    rows = []
    secs = {}
    variants = [("fused", True)] if sharded_only else \
        [("stepwise", False), ("fused", True)]
    for name, fused in variants:
        dt = _time_run(lambda: make(fused))
        secs[name] = dt
        rows.append({
            "variant": name,
            "rounds": rounds,
            "clients": n_clients,
            "cohort": m,
            "rounds_per_s": rounds / dt,
            "ms_per_round": dt / rounds * 1e3,
        })
    if sharded_only:
        speedup = None          # no stepwise baseline measured: nothing to gate
    else:
        speedup = secs["stepwise"] / secs["fused"]
        rows[1]["speedup_vs_stepwise"] = speedup

    # ---- client-sharded fused executor (the multi-device scale-out path) ----
    # Recorded, never gated: CPU shard_map pays per-round collective overhead
    # that quick shapes don't amortize — the column tracks the trend.
    sharded_rps = None
    if sharded:
        n_dev = jax.device_count()
        if n_dev < 2:
            print("# sharded: skipped (one device; force more with "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        else:
            from repro.sharding.fed import make_client_mesh

            mesh = make_client_mesh()

            def make_sharded():
                return FedEngine(g, fed, mcfg, rounds=rounds,
                                 clients_per_round=m, seed=0,
                                 eval_every=rounds, mesh=mesh,
                                 scheduler=SyncScheduler(fused=True))

            probe = make_sharded()
            probe.run()
            assert probe.last_executor == "sharded_fused", probe.last_executor
            dt = _time_run(make_sharded)
            sharded_rps = rounds / dt
            rows.append({
                "variant": "sharded_fused",
                "devices": n_dev,
                "rounds": rounds,
                "clients": n_clients,
                "cohort": m,
                "rounds_per_s": sharded_rps,
                "ms_per_round": dt / rounds * 1e3,
                "speedup_vs_fused": secs["fused"] / dt,
            })

    # ---- eval aggregation backends (the per-round server-side hot spot) ----
    params = gcn_init(jax.random.PRNGKey(0), g.n_features, g.n_classes)
    for be in AGG_BACKENDS if not sharded_only else ():
        eg = build_eval_graph(g, backend=be)
        evaluate_global(params, eg, "test")     # warmup/compile
        t0 = time.perf_counter()
        n_reps = 5
        for _ in range(n_reps):
            evaluate_global(params, eg, "test")
        rows.append({
            "variant": f"eval_{be}",
            "ms_per_eval": (time.perf_counter() - t0) / n_reps * 1e3,
        })

    bench_path = os.path.join(REPO_ROOT, "BENCH_round.json")
    sharded_devices = jax.device_count() if sharded_rps is not None else None
    prev = None
    try:
        with open(bench_path) as f:
            prev = json.load(f)
    except (OSError, ValueError):
        pass
    if sharded_rps is None and prev is not None:
        # a non-sharded run must not erase the recorded sharded column —
        # carry the previous measurement forward (scalar, device count, AND
        # its sharded_fused row, so the ms_per_round/device provenance
        # travels with the number) instead of nulling it
        sharded_rps = prev.get("sharded_rounds_per_s")
        sharded_devices = prev.get("sharded_devices")
        rows += [r for r in prev.get("rows", [])
                 if isinstance(r, dict) and r.get("variant") == "sharded_fused"]
    if sharded_only and prev is not None:
        # merge: update only the sharded column + row, keep the gated
        # single-device payload (fused_speedup, stepwise/fused/eval rows)
        payload = dict(prev,
                       sharded_rounds_per_s=sharded_rps,
                       sharded_devices=sharded_devices)
        payload["rows"] = (
            [r for r in prev.get("rows", []) if r.get("variant") != "sharded_fused"]
            + [r for r in rows if r["variant"] == "sharded_fused"])
    else:
        payload = {
            "bench": "round_throughput",
            "backend": jax.default_backend(),
            "devices": jax.device_count(),
            "quick": quick,
            "fused_speedup": speedup,
            "sharded_rounds_per_s": sharded_rps,
            "sharded_devices": sharded_devices,
            "rows": rows,
        }
    # gated rows are demanded whenever this run produced them (any plain
    # run) or the previous payload carried them (a merge must not drop
    # them) — but not for sharded-only runs stacked on a gate-less file
    prev_gated = prev is not None and any(
        isinstance(r, dict) and r.get("variant") in _GATED_VARIANTS
        for r in prev.get("rows", []))
    problems = validate_bench_round(
        payload, require_gated=not sharded_only or prev_gated)
    if problems:
        raise ValueError(
            "refusing to write a malformed BENCH_round.json:\n  "
            + "\n  ".join(problems))
    with open(bench_path, "w") as f:
        json.dump(payload, f, indent=1)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    ap.add_argument("--sharded", action="store_true",
                    help="also time the client-sharded fused executor over "
                         "all devices (recorded in BENCH_round.json, no gate)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="time ONLY the sharded executor (+ an in-env fused "
                         "reference) and merge the sharded column into "
                         "BENCH_round.json, leaving the gated single-device "
                         "rows untouched — the CI multi-device step")
    ap.add_argument("--no-gate", action="store_true",
                    help="record only, never fail on fused < stepwise (for "
                         "runs in environments the gate was not calibrated "
                         "for, e.g. forced multi-device CPU)")
    args = ap.parse_args()
    rows = run(quick=args.quick, sharded=args.sharded,
               sharded_only=args.sharded_only)
    emit_csv("perf_round", rows)
    save_rows("perf_round", rows)
    speedup = next((r["speedup_vs_stepwise"] for r in rows
                    if r.get("speedup_vs_stepwise") is not None), None)
    if speedup is None:
        return 0                # sharded-only: nothing measured to gate
    print(f"# fused speedup vs stepwise: {speedup:.2f}x")
    if speedup < 1.0 and not args.no_gate:
        print("# FAIL: fused executor slower than the step-by-step loop")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
