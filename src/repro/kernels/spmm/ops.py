"""Public wrapper for the block-sparse SpMM kernel.

``block_spmm(a, x)`` pads to tile multiples, computes (or takes) the block
mask, runs the Pallas kernel and slices the padding off. Block sizes
default to an autotuned choice keyed on the (padded) problem shape — see
``best_block_sizes`` / ``AUTOTUNE_TABLE``. ``neighbor_mean`` expresses the
paper's padded neighbor-list aggregation as an SpMM against a normalised
adjacency built from (idx, mask) — the form the FedGCN layer uses — and
derives the block mask directly from the neighbor list
(``adjacency_block_mask``), skipping the O(N·M) tile max-reduce.

The wrapper carries a ``jax.custom_vjp``: gradients flow to ``x`` as
``dx = Aᵀ @ dy`` through the same kernel (the adjacency is built from
non-differentiable neighbor indices/masks, so its cotangent is zero by
construction). This is what lets the ``spmm`` backend serve the *training*
forward, where ``value_and_grad`` differentiates through the aggregation —
Pallas interpret mode has no transpose rule of its own.

``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.spmm.spmm import spmm_pallas

# Autotune table: pow2-bucketed (N, M, D) -> (block_n, block_m, block_d).
# Measured with benchmarks/kernel_bench.py --autotune-spmm (wall-clock of
# the full block_spmm call, interpret mode on CPU; compiled TPU entries
# must keep the lane dim a multiple of 128 — pallas_guide: fp32 min tile
# (8, 128), MXU 128x128). Interpret mode pays per grid cell, so the best
# blocks cover a whole padded dim where VMEM would allow it; block
# skipping argues for smaller row/col tiles only once the adjacency is
# sparse at tile granularity.
AUTOTUNE_TABLE: dict[tuple[int, int, int], tuple[int, int, int]] = {
    # eval full-graph aggregation (quick perf shape, pubmed/16)
    (2048, 2048, 512): (256, 512, 512),
    (2048, 2048, 256): (256, 512, 256),
    (2048, 2048, 128): (256, 512, 128),
    # serve buckets: (bucket, store capacity, H1/F)
    (8, 512, 128): (8, 512, 128),
    (32, 512, 128): (32, 512, 128),
    (128, 512, 128): (128, 512, 128),
    (8, 512, 512): (8, 512, 512),
    (32, 512, 512): (32, 512, 512),
    (128, 512, 512): (128, 512, 512),
    # training batch aggregation: (batch_cap, n_tot, F/H1)
    (256, 256, 512): (256, 256, 512),
    (256, 256, 256): (256, 256, 256),
    (128, 256, 512): (128, 256, 512),
    (64, 128, 512): (64, 128, 512),
}


def _pow2ceil(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def best_block_sizes(n: int, m: int, d: int) -> tuple[int, int, int]:
    """Block sizes for an (n, m) @ (m, d) SpMM: exact table hit on the
    pow2-bucketed shape, else a padding-waste-minimising heuristic (cover
    small dims with one block, cap at the MXU-friendly 128/256)."""
    key = (_pow2ceil(n), _pow2ceil(m), _pow2ceil(d))
    if key in AUTOTUNE_TABLE:
        return AUTOTUNE_TABLE[key]
    bn = min(128, key[0])
    bm = min(128, key[1])
    bd = min(256, key[2])
    return bn, bm, bd


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _spmm(block_n, block_m, block_d, interpret, a, x, mask):
    return _spmm_run(block_n, block_m, block_d, interpret, a, x, mask)


def _spmm_run(block_n, block_m, block_d, interpret, a, x, mask):
    N, D = a.shape[0], x.shape[1]
    ap = _pad_to(a, block_n, block_m)
    xp = _pad_to(x, block_m, block_d)
    if mask is None:
        nb_n, nb_m = ap.shape[0] // block_n, ap.shape[1] // block_m
        tiles = ap.reshape(nb_n, block_n, nb_m, block_m)
        mask = (jnp.abs(tiles).max(axis=(1, 3)) > 0).astype(jnp.int32)
    y = spmm_pallas(
        ap, xp, mask,
        block_n=block_n, block_m=block_m, block_d=block_d, interpret=interpret,
    )
    return y[:N, :D]


def _spmm_fwd(block_n, block_m, block_d, interpret, a, x, mask):
    y = _spmm_run(block_n, block_m, block_d, interpret, a, x, mask)
    return y, (a, mask)


def _spmm_bwd(block_n, block_m, block_d, interpret, res, dy):
    a, mask = res
    # dx = Aᵀ @ dy through the same kernel (transposed tiling + mask);
    # the adjacency/mask are index-derived constants -> zero cotangents
    mask_t = None if mask is None else mask.T
    dx = _spmm_run(block_m, block_n, block_d, interpret, a.T, dy, mask_t)
    return jnp.zeros_like(a), dx, (None if mask is None
                                   else jnp.zeros_like(mask))


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "block_d",
                                             "interpret"))
def block_spmm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    *,
    block_n: int | None = None,
    block_m: int | None = None,
    block_d: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Y = A @ X via the block-skipping Pallas kernel. a (N, M), x (M, D).

    ``mask`` is an optional precomputed (N/bn, M/bm) int32 block-liveness
    grid (``adjacency_block_mask``); None computes it from the A tiles (a
    max-reduce over the dense A every call). Unset block sizes come from
    ``best_block_sizes``. Differentiable in ``x`` (see module docstring).
    """
    bn, bm, bd = best_block_sizes(a.shape[0], a.shape[1], x.shape[1])
    block_n = bn if block_n is None else block_n
    block_m = bm if block_m is None else block_m
    block_d = bd if block_d is None else block_d
    interpret = resolve_interpret(interpret)
    return _spmm(block_n, block_m, block_d, interpret, a, x, mask)


def adjacency_from_neighbors(nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray, m: int) -> jnp.ndarray:
    """Dense row-normalised adjacency (N, m) from a padded neighbor list."""
    N, K = nbr_idx.shape
    deg = jnp.maximum(nbr_mask.sum(-1, keepdims=True), 1.0)
    w = nbr_mask / deg                                               # (N, K)
    a = jnp.zeros((N, m), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(N)[:, None], (N, K))
    return a.at[rows, nbr_idx].add(w)


def adjacency_block_mask(nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray, m: int,
                         block_n: int, block_m: int) -> jnp.ndarray:
    """Block-liveness grid of ``adjacency_from_neighbors``' (N, m) matrix,
    scattered straight from the neighbor list in O(N·K) — equal to the
    O(N·m) tile max-reduce ``block_spmm`` would otherwise pay, since the
    adjacency is nonzero exactly at the real (row, nbr) edges."""
    N, K = nbr_idx.shape
    nb_n = -(-N // block_n)
    nb_m = -(-m // block_m)
    rows = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None], (N, K))
    live = (nbr_mask > 0).reshape(-1).astype(jnp.int32)
    grid = jnp.zeros((nb_n, nb_m), jnp.int32)
    return grid.at[(rows // block_n).reshape(-1),
                   (nbr_idx // block_m).reshape(-1)].max(live)


def neighbor_spmm(table: jnp.ndarray, nbr_idx: jnp.ndarray,
                  nbr_mask: jnp.ndarray, *,
                  adj: jnp.ndarray | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Mean-aggregate ``table`` rows for a padded neighbor batch via the
    kernel, with the block mask derived from the neighbor list (no dense
    tile reduce). ``adj`` optionally reuses a precomputed adjacency."""
    m = table.shape[0]
    if adj is None:
        adj = adjacency_from_neighbors(nbr_idx, nbr_mask, m)
    bn, bm, _ = best_block_sizes(adj.shape[0], m, table.shape[1])
    mask = adjacency_block_mask(nbr_idx, nbr_mask, m, bn, bm)
    return block_spmm(adj, table, mask, block_n=bn, block_m=bm,
                      interpret=interpret).astype(table.dtype)


def neighbor_mean(
    features: jnp.ndarray, nbr_idx: jnp.ndarray, nbr_mask: jnp.ndarray, *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Mean-aggregate neighbor features via the SpMM kernel."""
    return neighbor_spmm(features, nbr_idx, nbr_mask, interpret=interpret)
