"""internvl2-2b [vlm] — InternViT + InternLM2: 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92553. Vision encoder + projector are a STUB: input_specs
provides (B, 256, d_model) projected patch embeddings, per the assignment
carve-out; the InternLM2-style GQA decoder is fully implemented.
[arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        source="arXiv:2404.16821",
        block_pattern=("attn",),
        n_image_tokens=256,
        activation="silu",
        gated_mlp=True,
        rope_theta=1_000_000.0,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("internvl2-2b", config, smoke)
