"""Pure-jnp oracle for flash attention (GQA, causal / sliding-window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,   # (B, S, H, hd)
    k: jnp.ndarray,   # (B, S, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * hd ** -0.5
    if causal:
        diff = jnp.arange(Sq)[:, None] - jnp.arange(Sk)[None, :]
        ok = diff >= 0
        if window is not None:
            ok &= diff < window
        s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
