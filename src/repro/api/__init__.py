"""repro.api — the composable federated training surface.

Quick tour::

    from repro.api import FedEngine, method_config

    res = FedEngine(graph, fed, "fedais", rounds=10, clients_per_round=5).run()
    res = FedEngine(graph, fed, method_config("fedall", aggregator="weighted"),
                    rounds=10).run()

Extension points (all string-keyed registries):

    register_method(name, strategy=..., **config_defaults)
    register_strategy_kind(kind, MethodStrategySubclass)
    register_aggregator(name, factory)

plus direct component injection on the engine:

    FedEngine(graph, fed, "fedais",
              selector=LossBiasedSelector(),
              aggregator=WeightedFedAvg(),
              callbacks=[EvalCallback(), HistoryCallback(), MyCallback()])
"""
from repro.api.callbacks import (
    BaseCallback,
    EarlyStopCallback,
    EvalCallback,
    HistoryCallback,
    RoundContext,
    VerboseCallback,
    default_callbacks,
)
from repro.api.engine import EngineState, FedEngine, RunResult
from repro.api.protocols import (
    AdaptiveSyncController,
    Aggregator,
    ClientSelector,
    CostModel,
    FedAvg,
    FixedSyncController,
    LossBiasedSelector,
    PaperCostModel,
    RoundCallback,
    SizeBiasedSelector,
    SyncController,
    UniformSelector,
    WeightedFedAvg,
)
from repro.api.registry import (
    available_aggregators,
    available_methods,
    build_aggregator,
    build_strategy,
    method_config,
    register_aggregator,
    register_method,
    unregister_method,
)
from repro.api.strategies import (
    BanditStrategy,
    GeneratorStrategy,
    MethodStrategy,
    register_strategy_kind,
    strategy_kind_for,
)

__all__ = [
    "AdaptiveSyncController", "Aggregator", "BanditStrategy", "BaseCallback",
    "ClientSelector", "CostModel", "EarlyStopCallback", "EngineState",
    "EvalCallback", "FedAvg", "FedEngine", "FixedSyncController",
    "GeneratorStrategy", "HistoryCallback", "LossBiasedSelector",
    "MethodStrategy", "PaperCostModel", "RoundCallback", "RoundContext",
    "RunResult", "SizeBiasedSelector", "SyncController", "UniformSelector",
    "VerboseCallback", "WeightedFedAvg", "available_aggregators",
    "available_methods", "build_aggregator", "build_strategy",
    "default_callbacks", "method_config", "register_aggregator",
    "register_method", "register_strategy_kind", "strategy_kind_for",
    "unregister_method",
]
