"""rwkv6-1.6b [ssm] — "Finch": 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay. [arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=0,               # attention-free
        n_kv_heads=0,
        d_ff=7168,               # channel-mix hidden (3.5x)
        vocab_size=65536,
        source="arXiv:2404.05892",
        block_pattern=("rwkv",),
        rwkv_head_dim=64,        # 32 heads
        pos_embedding="none",
        max_seq_len=1 << 20,     # O(1) state: unbounded context
    )


def smoke() -> ModelConfig:
    return smoke_variant(config(), n_heads=0, n_kv_heads=0)


register("rwkv6-1.6b", config, smoke)
