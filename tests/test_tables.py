"""Pod-sharded table machinery: the partition-time ghost-bucket builder
(federated.partition.ghost_exchange_buckets), its simulated all-to-all
round-trip against pull_ghosts, the prefetched pull, the pairwise merge
reduction, and the engine's pod-mode wiring/validation.

Everything here runs on a single device (the pod chunk itself is exercised
by the (1, 1) mesh parity test below and by tests/test_pod_sharding.py on
the multi-device CI lane). Property tests go through tests/hypcompat.py so
they skip — not error — when hypothesis is missing.
"""
import jax
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.api import FedAvg, FedEngine, method_config
from repro.core.historical import pull_ghosts, pull_ghosts_prefetched
from repro.federated.partition import (
    exchange_ghost_features,
    ghost_exchange_buckets,
    pod_table_padding,
    simulate_ghost_exchange,
    simulate_writeback_exchange,
    writeback_routing,
)
from repro.sharding.fed import CLIENT_AXIS, cohort_padding, make_client_mesh
from repro.sharding.tables import (
    POD_AXIS,
    make_pod_mesh,
    pad_tables_to_pods,
    pairwise_sum,
    pod_axes_of,
    sync_round_gates,
)

pytestmark = pytest.mark.sharded


def random_topology(seed: int, K: int, g_max: int, n_max: int, fill=0.7):
    """A random partition-shaped ghost topology (owner/row/mask triplet)."""
    rng = np.random.default_rng(seed)
    gm = (rng.random((K, g_max)) < fill).astype(np.float32)
    go = np.where(gm > 0, rng.integers(0, K, (K, g_max)), -1).astype(np.int32)
    gr = rng.integers(0, n_max, (K, g_max)).astype(np.int32)
    return go, gr, gm


def bucket_entries(b):
    """Decode the send buckets back into {(src, dst): [(owner, row), ...]}."""
    out = {}
    for p in range(b.n_pods):
        for q in range(b.n_pods):
            rows = []
            for pos in range(b.bucket_size):
                if b.send_mask[p, q, pos] > 0:
                    rows.append((int(b.send_client[p, q, pos]) + p * b.rows_per_pod,
                                 int(b.send_row[p, q, pos])))
            out[(p, q)] = rows
    return out


# ---------------------------------------------------------------------------
# ghost-bucket builder properties (satellite: hypothesis via hypcompat)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 12), st.integers(1, 6),
       st.integers(1, 5))
def test_every_needed_pair_in_exactly_one_send_bucket(seed, K, g_max, n_pods):
    """For every destination pod, each (owner, row) source pair referenced
    by one of its residents appears exactly once — in the OWNER pod's
    bucket for that destination and nowhere else."""
    go, gr, gm = random_topology(seed, K, g_max, n_max=8)
    b = ghost_exchange_buckets(go, gr, gm, n_pods)
    ent = bucket_entries(b)
    for (p, q), rows in ent.items():
        # no duplicates within a bucket, and only rows pod p actually owns
        assert len(rows) == len(set(rows))
        assert all(o // b.rows_per_pod == p for o, _ in rows)
    for q in range(n_pods):
        needed = {(int(go[k, s]), int(gr[k, s]))
                  for k in range(K) if k // b.rows_per_pod == q
                  for s in range(g_max) if gm[k, s] > 0}
        got = [pair for p in range(n_pods) for pair in ent[(p, q)]]
        assert sorted(got) == sorted(needed)   # exactly once each
    assert b.n_entries == sum(len(rows) for rows in ent.values())


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 10), st.integers(1, 5),
       st.integers(1, 4))
def test_bucket_roundtrip_reproduces_pull_ghosts(seed, K, g_max, n_pods):
    """Send buckets -> simulated all-to-all -> recv maps must reproduce the
    gh half of pull_ghosts (the replicated-table gather) bit-for-bit for
    every client, including masked slots (0) and padded residents."""
    n_max = 6
    go, gr, gm = random_topology(seed, K, g_max, n_max)
    b = ghost_exchange_buckets(go, gr, gm, n_pods)
    rng = np.random.default_rng(seed + 1)
    hist1_all = rng.normal(size=(K, n_max + g_max, 3)).astype(np.float32)
    feats_all = rng.normal(size=(K, n_max, 2)).astype(np.float32)
    sim = simulate_ghost_exchange(b, hist1_all)
    assert sim.shape == (b.n_clients_padded, g_max, 3)
    for k in range(K):
        _, gh = pull_ghosts(hist1_all, feats_all, go[k], gr[k], gm[k])
        np.testing.assert_array_equal(sim[k], np.asarray(gh))
    # padded resident rows received nothing
    np.testing.assert_array_equal(sim[K:], 0.0)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 512), st.integers(1, 64))
def test_cohort_and_table_padding_invariants(m, n_shards):
    for pad_fn in (cohort_padding, pod_table_padding):
        pad = pad_fn(m, n_shards)
        assert 0 <= pad < n_shards
        assert (m + pad) % n_shards == 0
        if m % n_shards == 0:
            assert pad == 0


# ---------------------------------------------------------------------------
# write-back routing properties (satellite: hypothesis via hypcompat)
# ---------------------------------------------------------------------------

def random_cohorts(seed, S, n_pods, n_shards, mL, rpp, dummy_frac=0.3):
    """(S, m) padded cohorts: duplicate-free real ids in [0, Kp) plus a
    trailing block of out-of-range dummies (the cohort-padding contract)."""
    rng = np.random.default_rng(seed)
    m = n_pods * n_shards * mL
    Kp = n_pods * rpp
    sel = np.zeros((S, m), np.int32)
    for s in range(S):
        n_real = max(1, int(m * (1 - dummy_frac)))
        n_real = min(n_real, Kp)              # without-replacement sampling
        sel[s, :n_real] = rng.permutation(Kp)[:n_real]
        sel[s, n_real:] = Kp + rng.integers(0, 3, m - n_real)
    return sel, Kp


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 3), st.integers(1, 4), st.integers(1, 6))
def test_writeback_every_real_entry_in_exactly_one_bucket(
        seed, S, n_pods, n_shards, mL, rpp):
    """Every real (src-slice, owner-row) cohort entry lands in exactly one
    send-bucket slot — in its SOURCE pod's bucket for the OWNER pod — with
    positions forming a gap-free prefix; dummies get the sentinel dst and
    every unused recv slot keeps the drop sentinel."""
    sel, Kp = random_cohorts(seed, S, n_pods, n_shards, mL, rpp)
    plan = writeback_routing(sel, n_pods, n_shards, rpp)
    m = sel.shape[1]
    src = np.arange(m) // (m // n_pods)
    real_slots = 0
    for s in range(S):
        occupied = set()
        occ = np.zeros((n_pods, n_pods), np.int64)
        for i in range(m):
            k = int(sel[s, i])
            if k >= Kp:
                assert plan.dst[s, i] == n_pods      # dummy: sentinel dst
                continue
            q = int(plan.dst[s, i])
            assert q == k // rpp                      # routed to the owner
            slot = (int(src[i]), q, int(plan.pos[s, i]))
            assert slot not in occupied               # exactly one slot each
            occupied.add(slot)
            occ[src[i], q] += 1
            # the recv side inverts to the owner-local table row
            assert plan.recv[s, q, src[i], plan.pos[s, i]] == k - q * rpp
        # positions are a gap-free prefix of each (src, dst) bucket
        for p in range(n_pods):
            for q in range(n_pods):
                got = sorted(pos for (sp, dq, pos) in occupied
                             if (sp, dq) == (p, q))
                assert got == list(range(occ[p, q]))
        real_slots += len(occupied)
    assert plan.max_occupancy <= plan.cap
    assert plan.cap & (plan.cap - 1) == 0             # pow2 shape stability
    assert int((plan.recv < rpp).sum()) == real_slots  # all else = sentinel


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 3), st.integers(1, 4), st.integers(1, 6))
def test_writeback_roundtrip_matches_dense_scatter(
        seed, S, n_pods, n_shards, mL, rpp):
    """Bucket scatter -> simulated all-to-all -> shard scatter must equal
    the dense ``table[sel[i]] = values[i]`` bit-for-bit for every real id,
    leaving rows dummies point past (and untouched rows) inert."""
    sel, Kp = random_cohorts(seed, S, n_pods, n_shards, mL, rpp)
    plan = writeback_routing(sel, n_pods, n_shards, rpp)
    rng = np.random.default_rng(seed + 1)
    m = sel.shape[1]
    for s in range(S):
        table = rng.normal(size=(Kp, 3)).astype(np.float32)
        values = rng.normal(size=(m, 3)).astype(np.float32)
        ref = table.copy()
        for i in range(m):
            if sel[s, i] < Kp:
                ref[sel[s, i]] = values[i]
        got = simulate_writeback_exchange(plan, s, values, table)
        np.testing.assert_array_equal(got, ref)


def test_writeback_routing_validation():
    sel = np.zeros((1, 6), np.int32)
    with pytest.raises(ValueError, match="split"):
        writeback_routing(sel, 4, 1, 2)               # 6 % 4 != 0
    # contiguous ids: each pod-row's slice routes entirely within-pod
    plan = writeback_routing(np.arange(8, dtype=np.int32)[None], 2, 1, 4)
    assert plan.max_occupancy == 4 and plan.cap == 4
    # interleaved ids split every slice across both pods
    inter = np.arange(8, dtype=np.int32).reshape(4, 2).T.reshape(-1)
    plan = writeback_routing(inter[None], 2, 1, 4)
    assert plan.max_occupancy == 2 and plan.cap == 2
    with pytest.raises(ValueError, match="cap"):
        writeback_routing(np.arange(8, dtype=np.int32)[None], 2, 1, 4, cap=2)


def test_exchange_ghost_features_matches_pull_gf():
    """The static layer-0 owner exchange equals the gf half of pull_ghosts
    for every real client, zeros on pod-padding rows."""
    K, n_max, g_max, n_pods = 7, 5, 3, 3
    go, gr, gm = random_topology(4, K, g_max, n_max)
    b = ghost_exchange_buckets(go, gr, gm, n_pods)
    feats_all = np.random.default_rng(5).normal(
        size=(K, n_max, 2)).astype(np.float32)
    gsrc = exchange_ghost_features(b, feats_all)
    assert gsrc.shape == (b.n_clients_padded, g_max, 2)
    assert gsrc.dtype == np.float32
    ref = np.where(gm[..., None] > 0, feats_all[np.maximum(go, 0), gr], 0.0)
    np.testing.assert_array_equal(gsrc[:K], ref)
    np.testing.assert_array_equal(gsrc[K:], 0.0)


# ---------------------------------------------------------------------------
# sync-round gating (the tau-schedule predicate the ghost a2a hangs on)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(0, 12),
       st.integers(1, 6))
def test_sync_round_gates_matches_per_epoch_do_sync(seed, S, tau, J):
    """A round's gate is True iff ANY of its J local epochs satisfies
    LocalUpdate's per-epoch predicate (epoch_offset + j) % tau == 0 — the
    host-derivable condition under which gating off the ghost exchange is
    lossless."""
    rng = np.random.default_rng(seed)
    eoffs = rng.integers(0, 64, size=S).astype(np.int64)
    gates = sync_round_gates(eoffs, tau, J)
    assert gates.shape == (S,) and gates.dtype == np.bool_
    for s in range(S):
        want = any((int(eoffs[s]) + j) % max(tau, 1) == 0 for j in range(J))
        assert bool(gates[s]) == want
    assert not sync_round_gates(eoffs, tau, J, enabled=False).any()


def test_sync_round_gates_tau8_alternates():
    """The README ledger's headline schedule: tau=8 with J=4 local epochs
    syncs on every other round (fraction exactly 0.5)."""
    eoffs = np.arange(16) * 4                         # consecutive rounds
    gates = sync_round_gates(eoffs, 8, 4)
    np.testing.assert_array_equal(gates, np.arange(16) % 2 == 0)
    assert float(gates.mean()) == 0.5
    # tau <= 1 syncs every epoch of every round
    assert sync_round_gates(eoffs, 1, 4).all()
    assert sync_round_gates(eoffs, 0, 4).all()


# ---------------------------------------------------------------------------
# plain unit coverage of the same invariants (runs without hypothesis too)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,K,g_max,n_pods", [(0, 5, 4, 2), (1, 8, 3, 3),
                                                 (2, 3, 2, 8), (3, 1, 1, 1)])
def test_bucket_roundtrip_cases(seed, K, g_max, n_pods):
    n_max = 5
    go, gr, gm = random_topology(seed, K, g_max, n_max)
    b = ghost_exchange_buckets(go, gr, gm, n_pods)
    assert b.n_clients_padded == K + pod_table_padding(K, n_pods)
    hist1_all = np.random.default_rng(seed).normal(
        size=(K, n_max + g_max, 2)).astype(np.float32)
    sim = simulate_ghost_exchange(b, hist1_all)
    ref = np.where(gm[..., None] > 0, hist1_all[np.maximum(go, 0), gr], 0.0)
    np.testing.assert_array_equal(sim[:K], ref)


@pytest.mark.parametrize("seed,n_pods,n_shards,mL,rpp",
                         [(0, 2, 1, 2, 3), (1, 3, 2, 1, 4),
                          (2, 1, 1, 4, 2), (3, 4, 1, 2, 1)])
def test_writeback_roundtrip_cases(seed, n_pods, n_shards, mL, rpp):
    sel, Kp = random_cohorts(seed, 2, n_pods, n_shards, mL, rpp)
    plan = writeback_routing(sel, n_pods, n_shards, rpp)
    rng = np.random.default_rng(seed + 1)
    m = sel.shape[1]
    for s in range(2):
        table = rng.normal(size=(Kp, 2)).astype(np.float32)
        values = rng.normal(size=(m, 2)).astype(np.float32)
        ref = table.copy()
        for i in range(m):
            if sel[s, i] < Kp:
                ref[sel[s, i]] = values[i]
        np.testing.assert_array_equal(
            simulate_writeback_exchange(plan, s, values, table), ref)


def test_ghost_buckets_validate_pod_count():
    go, gr, gm = random_topology(0, 4, 2, 4)
    with pytest.raises(ValueError, match="n_pods"):
        ghost_exchange_buckets(go, gr, gm, 0)


def test_pull_ghosts_prefetched_matches_tables_pull():
    """Given the pre-gathered source rows, the prefetched pull is the
    replicated-table pull bit-for-bit."""
    K, n_max, g_max = 4, 5, 3
    rng = np.random.default_rng(0)
    hist1_all = rng.normal(size=(K, n_max + g_max, 4)).astype(np.float32)
    feats_all = rng.normal(size=(K, n_max, 2)).astype(np.float32)
    go, gr, gm = random_topology(1, K, g_max, n_max)
    for k in range(K):
        gf_ref, gh_ref = pull_ghosts(hist1_all, feats_all, go[k], gr[k], gm[k])
        src_f = feats_all[np.maximum(go[k], 0), gr[k]]
        src_h = hist1_all[np.maximum(go[k], 0), gr[k]]
        gf, gh = pull_ghosts_prefetched(src_f, src_h, gm[k])
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(gf_ref))
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(gh_ref))


def test_pairwise_sum_matches_flat_sum():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 5, 8, 13):
        x = rng.normal(size=(n, 4)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(pairwise_sum(jax.numpy.asarray(x))),
                                   x.astype(np.float64).sum(axis=0),
                                   rtol=1e-6, atol=1e-6)
    # association is fixed by length alone: ((a+b)+(c+d)) for n=4
    a, b, c, d = (np.float32(v) for v in (1e8, -1e8, 3.25, 4.75))
    got = float(pairwise_sum(jax.numpy.asarray([a, b, c, d])))
    assert got == float((a + b) + (c + d))


def test_pad_tables_to_pods():
    t1 = jax.numpy.ones((5, 3))
    t2 = jax.numpy.ones((5,), jax.numpy.int32)
    p1, p2 = pad_tables_to_pods((t1, t2), 4)
    assert p1.shape == (8, 3) and p2.shape == (8,)
    np.testing.assert_array_equal(np.asarray(p1[5:]), 0.0)
    same = pad_tables_to_pods((t1,), 5)
    assert same[0] is t1    # divisible: no copy


# ---------------------------------------------------------------------------
# mesh helpers + engine wiring/validation
# ---------------------------------------------------------------------------

def test_make_pod_mesh_and_axis_resolution():
    mesh = make_pod_mesh(1, 1)
    assert dict(mesh.shape) == {POD_AXIS: 1, CLIENT_AXIS: 1}
    assert pod_axes_of(mesh) == (POD_AXIS, CLIENT_AXIS)
    assert pod_axes_of(make_client_mesh(1)) is None
    with pytest.raises(ValueError, match="n_pods"):
        make_pod_mesh(0, 1)
    with pytest.raises(ValueError, match="devices"):
        make_pod_mesh(len(jax.devices()) + 1, 1)
    if len(jax.devices()) % 3:
        with pytest.raises(ValueError, match="split"):
            make_pod_mesh(3)


def test_engine_validates_pod_options(small_fed):
    g, fed = small_fed
    with pytest.raises(ValueError, match="table_sharding"):
        FedEngine(g, fed, method_config("fedais"), rounds=1,
                  table_sharding="sometimes")
    with pytest.raises(ValueError, match="merge_reduce"):
        FedEngine(g, fed, method_config("fedais"), rounds=1,
                  merge_reduce="magic")
    # explicit pod mode demands a pod mesh
    with pytest.raises(ValueError, match="pods"):
        FedEngine(g, fed, method_config("fedais"), rounds=1,
                  mesh=make_client_mesh(1), table_sharding="pods")


def test_pod_eligibility_reasons(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1)
    ok, why = eng.pod_sharded_eligibility()
    assert not ok and "no mesh" in why
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    mesh=make_client_mesh(1))
    ok, why = eng.pod_sharded_eligibility()
    assert not ok and "pods" in why
    mesh = make_pod_mesh(1, 1)
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=mesh,
                    table_sharding="replicated")
    ok, why = eng.pod_sharded_eligibility()
    assert not ok and "replicated" in why
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=mesh,
                    client_sharding="off")
    ok, why = eng.pod_sharded_eligibility()
    assert not ok and "off" in why

    class Trimmed(FedAvg):          # overrides aggregate, inherits the flag
        def aggregate(self, stacked_params, weights=None):
            return super().aggregate(stacked_params, weights)

    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=mesh,
                    aggregator=Trimmed())
    ok, why = eng.pod_sharded_eligibility()
    assert not ok and "allreduce_safe" in why
    # divisible mode: cohort must split over ALL pods x clients devices
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=mesh,
                    client_sharding="divisible")
    assert eng.pod_sharded_eligibility(3)[0]    # 3 % 1 == 0
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=mesh)
    assert eng.pod_sharded_eligibility(3)[0]


EXACT_KEYS = ("tau", "comm_total", "comm_embed", "flops", "wall_clock")
CLOSE_KEYS = ("test_acc", "test_loss")


def assert_allclose_history(ref, got):
    for k in EXACT_KEYS:
        assert ref.history[k] == got.history[k], f"history[{k!r}] diverged"
    for k in CLOSE_KEYS:
        np.testing.assert_allclose(
            np.asarray(got.history[k], np.float64),
            np.asarray(ref.history[k], np.float64),
            rtol=1e-4, atol=1e-6, err_msg=f"history[{k!r}]")


def test_single_device_pod_mesh_matches_fused(small_fed):
    """A (1, 1) pod mesh routes the whole pod-sharded dataflow (ghost
    all-to-all, owner fetch, pod-local scatter) on one device — everyday
    fast-lane coverage of the chunk the multi-device lane scales out."""
    g, fed = small_fed
    kw = dict(seed=0, rounds=4, clients_per_round=3, eval_every=2)
    res_u = FedEngine(g, fed, method_config("fedais", tau0=4), **kw).run()
    eng = FedEngine(g, fed, method_config("fedais", tau0=4),
                    mesh=make_pod_mesh(1, 1), **kw)
    res_p = eng.run()
    assert eng.last_executor == "pod_sharded"
    assert_allclose_history(res_u, res_p)


def test_replicated_table_mode_falls_back_to_client_sharding(small_fed):
    """table_sharding='replicated' on a pod mesh keeps the PR-4 executor:
    cohort sharded over the 'clients' axis, tables replicated."""
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), seed=0, rounds=2,
                    clients_per_round=3, mesh=make_pod_mesh(1, 1),
                    table_sharding="replicated")
    eng.run()
    assert eng.last_executor == "sharded_fused"
