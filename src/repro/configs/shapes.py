"""The four assigned input shapes + ShapeDtypeStruct builders for the dry-run.

Decode shapes lower ``serve_step`` — ONE new token against a KV cache / recurrent
state of ``seq_len`` — not ``train_step``. ``long_500k`` requires sub-quadratic
attention; applicability is decided by ``shape_applicable`` (skips recorded in
DESIGN.md / EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skip)."""
    if shape.name == "long_500k":
        if not cfg.supports_long_context:
            return False, (
                f"{cfg.arch_id}: pure full-attention family — 500k decode would need "
                "a quadratic-cost full cache; skipped per assignment rules"
            )
    if shape.kind == "decode" and not cfg.has_decode:
        return False, f"{cfg.arch_id}: encoder-only, no decode step"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model *data* input of the step.

    train:   {tokens (B,S) i32, labels (B,S) i32 [, image_embeds, enc_frames]}
    prefill: {tokens (B,S) i32 [, image_embeds, enc_frames]}
    decode:  {tokens (B,1) i32, pos () i32}  (the state is built by the caller
             via jax.eval_shape over init_decode_state)
    """
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    if shape.kind == "train":
        specs = {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32)}
    else:  # decode
        specs = {"tokens": _sds((B, 1), jnp.int32), "pos": _sds((), jnp.int32)}
    if cfg.n_image_tokens and shape.kind != "decode":
        specs["image_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_model), dt)
    if cfg.n_encoder_layers and shape.kind != "decode":
        specs["enc_frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), dt)
    return specs


def concrete_inputs(cfg, shape: InputShape, key=None) -> dict:
    """Small-scale concrete inputs for smoke tests (use with smoke configs)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32)
        if shape.kind == "train":
            out["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size, jnp.int32)
    else:
        out["tokens"] = jax.random.randint(ks[0], (B, 1), 0, cfg.vocab_size, jnp.int32)
        out["pos"] = jnp.asarray(S - 1, jnp.int32)
    if cfg.n_image_tokens and shape.kind != "decode":
        out["image_embeds"] = jax.random.normal(ks[2], (B, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype) * 0.02
    if cfg.n_encoder_layers and shape.kind != "decode":
        out["enc_frames"] = jax.random.normal(ks[3], (B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype) * 0.02
    return out
