"""Variance decomposition diagnostics (paper Eq. 3-5, Theorem 1).

Total gradient-estimator variance splits into (a) embedding-approximation
variance from historical/stale inner-layer embeddings and (b) minibatch
sampling variance (Eq. 3). Theorem 1 bounds the layer-L output error by a
geometric sum over layers scaled by neighborhood size (Eq. 4), which via
lambda-smoothness bounds (a) (Eq. 5). These functions compute the bounds and
empirical estimates; tests assert the empirical quantities respect them.
"""
from __future__ import annotations

import jax.numpy as jnp


def theorem1_bound(alpha1: float, alpha2: float, n_neighbors: float, n_layers: int) -> float:
    """Eq. (4): sum_{l=1}^{L-1} (a1 a2 |N(v)|)^(L-l)."""
    total = 0.0
    for l in range(1, n_layers):
        total += (alpha1 * alpha2 * n_neighbors) ** (n_layers - l)
    return total


def gradient_error_bound(lam: float, embedding_error: float) -> float:
    """Eq. (5): E||g_tilde - g|| <= lambda * ||h_tilde - h||."""
    return lam * embedding_error


def embedding_error(h_tilde: jnp.ndarray, h_exact: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean L2 error of approximate vs exact embeddings over valid nodes."""
    err = jnp.linalg.norm((h_tilde - h_exact) * mask[..., None], axis=-1)
    return err.sum() / jnp.maximum(mask.sum(), 1.0)


def minibatch_variance(per_node_grad_proxy: jnp.ndarray, probs: jnp.ndarray, mask: jnp.ndarray):
    """Empirical Eq.-7 objective value for a given sampling distribution —
    lower is better; importance probs should beat uniform on skewed data."""
    p = jnp.maximum(probs, 1e-30)
    return jnp.sum(mask * jnp.square(per_node_grad_proxy) / p) / jnp.maximum(mask.sum(), 1.0)


def estimator_variance(samples: jnp.ndarray) -> jnp.ndarray:
    """Variance of a stochastic estimator across repeated draws (axis 0)."""
    mean = samples.mean(0)
    return jnp.mean(jnp.sum(jnp.square(samples - mean), axis=-1))
