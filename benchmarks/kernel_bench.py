"""Kernel-layer microbenchmarks: us_per_call of the XLA reference paths on
CPU (the Pallas kernels target TPU; interpret-mode timing is not meaningful,
so what we time here is the jnp oracle each kernel must beat on-device) plus
allclose deltas kernel-vs-oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.spmm.ops import block_spmm
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # spmm oracle timing + kernel correctness
    n, m, d = (256, 256, 128) if quick else (1024, 1024, 256)
    a = jnp.asarray((rng.random((n, m)) < 0.05) * rng.random((n, m)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    ref = jax.jit(spmm_ref)
    us = timed(ref, a, x)
    err = float(jnp.max(jnp.abs(block_spmm(a, x) - ref(a, x))))
    rows.append({"kernel": "spmm", "shape": f"{n}x{m}x{d}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})

    # flash attention
    B, S, H, Hkv, hd = (1, 256, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), jnp.float32)
    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = timed(ref, q, k, v)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, block_q=64, block_k=64)
                                - ref(q, k, v))))
    rows.append({"kernel": "flash_attention", "shape": f"B{B}S{S}H{H}kv{Hkv}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})

    # wkv6
    B, T, H, N = (1, 128, 4, 32) if quick else (2, 512, 8, 64)
    r_, k_, v_ = [jnp.asarray(rng.standard_normal((B, T, H, N)) * 0.5, jnp.float32)
                  for _ in range(3)]
    w_ = jnp.asarray(np.exp(-np.exp(rng.standard_normal((B, T, H, N)))), jnp.float32)
    u_ = jnp.asarray(rng.standard_normal((H, N)) * 0.1, jnp.float32)
    ref = jax.jit(lambda *args: wkv6_ref(*args)[0])
    us = timed(ref, r_, k_, v_, w_, u_)
    err = float(jnp.max(jnp.abs(wkv6(r_, k_, v_, w_, u_, chunk=32)[0]
                                - ref(r_, k_, v_, w_, u_))))
    rows.append({"kernel": "wkv6", "shape": f"B{B}T{T}H{H}N{N}",
                 "oracle_us_per_call": round(us, 1), "kernel_max_err": err})
    return rows
