"""RWKV6 WKV recurrence Pallas kernel.

TPU adaptation (DESIGN.md §4): RWKV6's data-dependent per-channel decay makes
the recurrence non-factorable into chunk matmuls without per-channel (Lc, Lc)
decay tensors, so instead of a GPU-style chunked matmul form we keep the
(N x N) state *resident in VMEM* across the whole time axis and stream the
(r, k, v, w) token blocks through it. HBM traffic is O(T*N) per head instead
of O(T*N^2) for a naive XLA scan that spills the state each step; compute is
VPU outer-products on hardware-aligned (N x N) tiles.

Grid: (B*H, T/chunk) — heads parallel, time sequential ("arbitrary").
State scratch persists across the sequential time dimension; reset at t=0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_ref,
                 *, chunk: int, n_chunks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)                     # (N,)

    def step(t, _):
        rt = r_ref[0, t].astype(jnp.float32)             # (N,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        S = state_ref[...]                               # (N, N) fp32
        coef = jnp.sum(rt * u * kt)                      # scalar
        y = coef * vt + rt @ S                           # (N,)
        state_ref[...] = wt[:, None] * S + kt[:, None] * vt[None, :]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == n_chunks - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(
    r: jnp.ndarray,   # (BH, T, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,   # per-channel decay in (0, 1)
    u: jnp.ndarray,   # (BH, N) bonus (pre-expanded per head)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    BH, T, N = r.shape
    assert T % chunk == 0, f"T={T} must be a multiple of chunk={chunk}"
    n_chunks = T // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    rkvw_spec = pl.BlockSpec((1, chunk, N), lambda bh, ti: (bh, ti, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            rkvw_spec, rkvw_spec, rkvw_spec, rkvw_spec,
            pl.BlockSpec((1, N), lambda bh, ti: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, N, N), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_out
