"""Deterministic synthetic token pipeline for LM training/serving examples.

Offline container => no real corpora. We synthesise a *learnable* stream: a
mixture of (a) a fixed-order Markov chain over the vocab (so the model can
reduce loss materially within a few hundred steps) and (b) uniform noise.
Determinism: batch ``i`` depends only on (seed, i), so the pipeline is
restartable from a step counter — the property checkpoint resume relies on.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_states: int = 64      # order-1 chain over vocab % markov_states
    noise_prob: float = 0.1

    def _chain(self) -> np.ndarray:
        """Row-stochastic transition matrix, deterministic in seed."""
        rng = np.random.default_rng(self.seed)
        m = rng.dirichlet(np.ones(self.markov_states) * 0.3, size=self.markov_states)
        return m.astype(np.float32)

    def batch(self, index: int) -> dict[str, np.ndarray]:
        """Batch ``index`` -> {'tokens': (B, S+1) int32}. Host-side numpy."""
        rng = np.random.default_rng((self.seed * 1_000_003 + index) & 0x7FFFFFFF)
        chain = self._chain()
        B, S = self.global_batch, self.seq_len + 1
        states = np.empty((B, S), dtype=np.int64)
        states[:, 0] = rng.integers(0, self.markov_states, size=B)
        for t in range(1, S):
            p = chain[states[:, t - 1]]
            cum = np.cumsum(p, axis=-1)
            u = rng.random(B)[:, None]
            states[:, t] = (u > cum).sum(axis=-1)
        # lift markov state to the vocab via a fixed affine map (deterministic,
        # so the stream stays learnable down to the chain's entropy) + noise
        stride = max(1, self.vocab_size // self.markov_states)
        salt = np.random.default_rng(self.seed).integers(0, stride, size=self.markov_states)
        tokens = states * stride + salt[states]
        noise = rng.random((B, S)) < self.noise_prob
        tokens = np.where(noise, rng.integers(0, self.vocab_size, size=(B, S)), tokens)
        tokens = np.clip(tokens, 0, self.vocab_size - 1).astype(np.int32)
        return {"tokens": tokens}


def make_lm_batch(pipeline: TokenPipeline, index: int) -> dict[str, jnp.ndarray]:
    """Split a (B, S+1) token block into model inputs/labels."""
    raw = pipeline.batch(index)["tokens"]
    return {
        "tokens": jnp.asarray(raw[:, :-1]),
        "labels": jnp.asarray(raw[:, 1:]),
    }


def shard_batch(batch: dict, mesh, pspec) -> dict:
    """Place a host batch onto the mesh with the given PartitionSpec."""
    sharding = jax.sharding.NamedSharding(mesh, pspec)
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
