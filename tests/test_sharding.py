"""Client-axis sharding of the fused executor (repro.sharding.fed).

Parity contract: the shard-mapped executor is **allclose, not
bit-identical**, to the unsharded fused run. Server aggregation becomes a
psum all-reduce whose summation order reassociates with the device count
(sum-of-per-device-partial-sums vs one flat mean), so float32 params —
and everything downstream of them — drift by ~ULP per round. Everything
discrete must still match exactly: selections and the PRNG chain are
host/key-identical by construction, and the mantissa-quantized sampling
keys (PR 2) absorb ULP-level jitter so batch/fanout/sync decisions — and
therefore the integer-derived comm/flops/wall-clock columns — cannot flip.

Multi-device tests skip on a single-device host; CI's ``sharded`` lane
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import numpy as np
import pytest

from repro.api import FedEngine, FedAvg, LossBiasedSelector, SyncScheduler, method_config
from repro.sharding.fed import (
    CLIENT_AXIS,
    client_axis_of,
    cohort_padding,
    make_client_mesh,
)

pytestmark = pytest.mark.sharded

N_DEV = len(jax.devices())
needs_devices = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

EXACT_KEYS = ("tau", "comm_total", "comm_embed", "flops", "wall_clock")
CLOSE_KEYS = ("test_acc", "test_loss")


def _run(g, fed, *, mesh=None, m=4, rounds=5, seed=0, **kw):
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), seed=seed,
                    rounds=rounds, clients_per_round=m, eval_every=2,
                    mesh=mesh, **kw)
    return eng, eng.run()


def _assert_allclose_history(ref, got):
    for k in EXACT_KEYS:
        assert ref.history[k] == got.history[k], f"history[{k!r}] diverged"
    for k in CLOSE_KEYS:
        np.testing.assert_allclose(
            np.asarray(got.history[k], np.float64),
            np.asarray(ref.history[k], np.float64),
            rtol=1e-4, atol=1e-6, err_msg=f"history[{k!r}]")


# ---------------------------------------------------------------------------
# sharded vs unsharded fused parity
# ---------------------------------------------------------------------------

@needs_devices
def test_sharded_matches_unsharded_fused(small_fed):
    g, fed = small_fed
    eng_u, res_u = _run(g, fed, m=4)
    eng_s, res_s = _run(g, fed, mesh=make_client_mesh(2), m=4)
    assert eng_u.last_executor == "fused"
    assert eng_s.last_executor == "sharded_fused"
    _assert_allclose_history(res_u, res_s)


@needs_devices
def test_sharded_matches_unsharded_weighted(small_fed):
    """WeightedFedAvg: the all-reduce must fold the client-size weights."""
    g, fed = small_fed
    kw = dict(aggregator="weighted", scheduler=SyncScheduler(fused=True))
    _, res_u = _run(g, fed, m=4, **kw)
    eng_s, res_s = _run(g, fed, mesh=make_client_mesh(2), m=4, **kw)
    assert eng_s.last_executor == "sharded_fused"
    _assert_allclose_history(res_u, res_s)


@needs_devices
def test_sharded_pairwise_merge_parity(small_fed):
    """merge_reduce='pairwise' on the 1-D client mesh: the fixed fp32
    binary-tree merge is a drop-in for the weighted psum within the same
    allclose contract (the knob the pod mesh already honors)."""
    g, fed = small_fed
    _, res_u = _run(g, fed, m=4)
    eng_s, res_s = _run(g, fed, mesh=make_client_mesh(2), m=4,
                        merge_reduce="pairwise")
    assert eng_s.last_executor == "sharded_fused"
    _assert_allclose_history(res_u, res_s)


def test_single_device_mesh_matches(small_fed):
    """A 1-device mesh still routes through shard_map (runs in the plain
    tier-1 lane too, so the sharded code path has everyday coverage)."""
    g, fed = small_fed
    _, res_u = _run(g, fed, m=3)
    eng_s, res_s = _run(g, fed, mesh=make_client_mesh(1), m=3)
    assert eng_s.last_executor == "sharded_fused"
    _assert_allclose_history(res_u, res_s)


# ---------------------------------------------------------------------------
# ragged-cohort padding is a no-op
# ---------------------------------------------------------------------------

def _one_chunk(g, fed, mesh, m):
    eng = FedEngine(g, fed, method_config("fedais", tau0=4), seed=0, rounds=4,
                    clients_per_round=m, eval_every=2, mesh=mesh)
    state = eng.init_state()
    eng._run_chunk(state, 0, 2)
    return eng, state


@needs_devices
def test_cohort_padding_is_noop(small_fed):
    """m=3 over 2 devices pads one zero-weight dummy client; the full
    client-state tables must match the unsharded run — ages (ints) exactly,
    so a stray dummy write-back to ANY row would be caught."""
    g, fed = small_fed
    assert cohort_padding(3, 2) == 1
    _, st_u = _one_chunk(g, fed, None, 3)
    eng_s, st_s = _one_chunk(g, fed, make_client_mesh(2), 3)
    assert eng_s.last_executor == "sharded_fused"
    np.testing.assert_array_equal(np.asarray(st_s.hist.age),
                                  np.asarray(st_u.hist.age))
    # float tables drift ~ULP-per-round through Adam off the reassociated
    # all-reduce; the exact int ages above are the real dummy-write-back guard
    np.testing.assert_allclose(np.asarray(st_s.hist.hist1),
                               np.asarray(st_u.hist.hist1),
                               rtol=1e-2, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_s.prev_loss),
                               np.asarray(st_u.prev_loss),
                               rtol=1e-2, atol=1e-3)


def test_cohort_padding_math():
    assert cohort_padding(8, 4) == 0
    assert cohort_padding(3, 8) == 5
    assert cohort_padding(9, 4) == 3
    assert cohort_padding(1, 1) == 0


# ---------------------------------------------------------------------------
# eligibility + clean fallback chain (sharded -> fused -> stepwise)
# ---------------------------------------------------------------------------

def test_no_mesh_is_ineligible(small_fed):
    g, fed = small_fed
    eng = FedEngine(g, fed, method_config("fedais"), rounds=1)
    ok, why = eng.sharded_eligibility()
    assert not ok and "no mesh" in why


def test_client_sharding_off_falls_back_to_fused(small_fed):
    g, fed = small_fed
    eng, _ = _run(g, fed, mesh=make_client_mesh(1), m=3, rounds=2,
                  client_sharding="off")
    assert eng.last_executor == "fused"


@needs_devices
def test_divisible_mode_falls_back_on_ragged_cohort(small_fed):
    g, fed = small_fed
    mesh = make_client_mesh(2)
    eng = FedEngine(g, fed, method_config("fedais"), rounds=2,
                    clients_per_round=3, mesh=mesh,
                    client_sharding="divisible")
    ok, why = eng.sharded_eligibility(3)
    assert not ok and "divide" in why
    assert eng.sharded_eligibility(4)[0]
    eng, _ = _run(g, fed, mesh=mesh, m=3, rounds=2,
                  client_sharding="divisible")
    assert eng.last_executor == "fused"       # padded path disabled -> fused


def test_non_mean_aggregator_falls_back_to_fused(small_fed):
    """An aggregator that traces in jit but is not a declared weighted-mean
    family cannot lower to the psum merge; the fused chunk serves it.
    Crucially a subclass overriding aggregate() must NOT inherit the base's
    allreduce_safe — the sharded merge would silently replace its rule with
    the hardcoded weighted mean."""
    g, fed = small_fed

    class TrimmedFedAvg(FedAvg):        # overrides aggregate, inherits flag
        def aggregate(self, stacked_params, weights=None):
            return super().aggregate(stacked_params, weights)

    eng, res = _run(g, fed, mesh=make_client_mesh(1), m=3, rounds=2,
                    aggregator=TrimmedFedAvg())
    ok, why = eng.sharded_eligibility()
    assert not ok and "allreduce_safe" in why
    assert eng.last_executor == "fused"
    assert np.isfinite(res.final["loss"])

    class VouchedMean(FedAvg):          # re-declares: vouches for the psum
        allreduce_safe = True

        def aggregate(self, stacked_params, weights=None):
            return super().aggregate(stacked_params, weights)

    eng = FedEngine(g, fed, method_config("fedais"), rounds=1,
                    mesh=make_client_mesh(1), aggregator=VouchedMean())
    assert eng.sharded_eligibility()[0]


def test_mesh_with_ineligible_fused_runs_stepwise(small_fed):
    """A mesh never forces the fused executor: when fused_eligibility fails
    (LossBiasedSelector reads per-round state) the run stays stepwise."""
    g, fed = small_fed
    eng, res = _run(g, fed, mesh=make_client_mesh(1), m=3, rounds=2,
                    selector=LossBiasedSelector())
    assert eng.last_executor == "stepwise"
    assert np.isfinite(res.final["loss"])


def test_engine_validates_sharding_options(small_fed):
    g, fed = small_fed
    with pytest.raises(ValueError, match="client_sharding"):
        FedEngine(g, fed, method_config("fedais"), rounds=1,
                  client_sharding="sometimes")
    two_axis = jax.make_mesh((1, 1), ("a", "b"), devices=jax.devices()[:1])
    with pytest.raises(ValueError, match="clients"):
        FedEngine(g, fed, method_config("fedais"), rounds=1, mesh=two_axis)


# ---------------------------------------------------------------------------
# mesh construction helpers
# ---------------------------------------------------------------------------

def test_make_client_mesh_and_axis_resolution():
    mesh = make_client_mesh(1)
    assert dict(mesh.shape) == {CLIENT_AXIS: 1}
    assert client_axis_of(mesh) == CLIENT_AXIS
    one_axis = jax.make_mesh((1,), ("shards",), devices=jax.devices()[:1])
    assert client_axis_of(one_axis) == "shards"
    two_axis = jax.make_mesh((1, 1), ("a", "b"), devices=jax.devices()[:1])
    assert client_axis_of(two_axis) is None
    with pytest.raises(ValueError, match="devices"):
        make_client_mesh(len(jax.devices()) + 1)
