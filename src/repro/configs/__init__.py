from repro.configs.base import (
    ModelConfig,
    get_config,
    get_smoke_config,
    list_archs,
    long_context_variant,
    register,
    smoke_variant,
)
from repro.configs.shapes import INPUT_SHAPES, InputShape, input_specs, shape_applicable

__all__ = [
    "ModelConfig",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "register",
    "smoke_variant",
    "INPUT_SHAPES",
    "InputShape",
    "input_specs",
    "shape_applicable",
]
