"""repro.api — the composable federated training surface.

Quick tour::

    from repro.api import FedEngine, method_config

    res = FedEngine(graph, fed, "fedais", rounds=10, clients_per_round=5).run()
    res = FedEngine(graph, fed, method_config("fedall", aggregator="weighted"),
                    rounds=10).run()

Extension points (all string-keyed registries):

    register_method(name, strategy=..., **config_defaults)
    register_strategy_kind(kind, MethodStrategySubclass)
    register_aggregator(name, factory)

plus direct component injection on the engine:

    FedEngine(graph, fed, "fedais",
              selector=LossBiasedSelector(),
              aggregator=WeightedFedAvg(),
              callbacks=[EvalCallback(), HistoryCallback(), MyCallback()])
"""
from repro.api.callbacks import (
    BaseCallback,
    EarlyStopCallback,
    EvalCallback,
    HistoryCallback,
    RoundContext,
    VerboseCallback,
    default_callbacks,
)
from repro.api.engine import EngineState, FedEngine, RunResult
from repro.api.protocols import (
    AdaptiveSyncController,
    Aggregator,
    AsyncScheduler,
    ClientSelector,
    CostModel,
    FedAvg,
    FixedSyncController,
    LossBiasedSelector,
    PaperCostModel,
    RoundCallback,
    RoundScheduler,
    SizeBiasedSelector,
    StalenessWeightedAggregator,
    SyncController,
    SyncScheduler,
    UniformSelector,
    WeightedFedAvg,
    staleness_discount,
)
from repro.api.registry import (
    available_aggregators,
    available_methods,
    available_schedulers,
    build_aggregator,
    build_scheduler,
    build_strategy,
    method_config,
    register_aggregator,
    register_method,
    register_scheduler,
    unregister_method,
)
from repro.api.strategies import (
    BanditStrategy,
    GeneratorStrategy,
    MethodStrategy,
    register_strategy_kind,
    strategy_kind_for,
)

__all__ = [
    "AdaptiveSyncController", "Aggregator", "AsyncScheduler", "BanditStrategy",
    "BaseCallback", "ClientSelector", "CostModel", "EarlyStopCallback",
    "EngineState", "EvalCallback", "FedAvg", "FedEngine",
    "FixedSyncController", "GeneratorStrategy", "HistoryCallback",
    "LossBiasedSelector", "MethodStrategy", "PaperCostModel", "RoundCallback",
    "RoundContext", "RoundScheduler", "RunResult", "SizeBiasedSelector",
    "StalenessWeightedAggregator", "SyncController", "SyncScheduler",
    "UniformSelector", "VerboseCallback", "WeightedFedAvg",
    "available_aggregators", "available_methods", "available_schedulers",
    "build_aggregator", "build_scheduler", "build_strategy",
    "default_callbacks", "method_config", "register_aggregator",
    "register_method", "register_scheduler", "register_strategy_kind",
    "staleness_discount", "strategy_kind_for", "unregister_method",
]
