"""Msgpack-based pytree checkpointing (no orbax/flax in container).

Layout: ``<dir>/step_<n>.msgpack`` — a flat map from '/'-joined key paths to
(dtype, shape, raw bytes) triples, plus a '__treedef__' structural record so
arbitrary pytrees of dict/list/tuple/namedtuple round-trip.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape), "data": v.tobytes()}
        for k, v in flat.items()
    }
    path = os.path.join(directory, f"step_{step:08d}.msgpack")
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            # flush + fsync BEFORE the rename: os.replace is atomic in the
            # namespace but not durable — without the fsync a crash can leave
            # the final name pointing at torn (partially-persisted) bytes
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic
    finally:
        # a failed pack/write must not leave a stray .tmp behind (latest_step
        # ignores it, but the next save would silently clobber it)
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = os.path.join(directory, f"step_{step:08d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat_like, treedef = _flatten_with_paths(like)
    leaves = []
    for key, template in flat_like.items():
        if key not in payload:
            raise KeyError(f"checkpoint {path} missing key {key!r}")
        rec = payload[key]
        # frombuffer returns a READ-ONLY view over the msgpack bytes; copy so
        # callers holding the numpy leaf (e.g. for in-place mutation) don't
        # hit "assignment destination is read-only"
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"]).copy()
        if tuple(arr.shape) != tuple(template.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != template {template.shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# what a torn / corrupt checkpoint file surfaces as: truncated or unreadable
# bytes (OSError, msgpack UnpackException incl. OutOfData/ExtraData), a
# payload that isn't the expected map (TypeError, ValueError from frombuffer
# or a shape mismatch), or one missing leaves (KeyError)
_CORRUPT_ERRORS = (OSError, ValueError, KeyError, TypeError,
                   msgpack.exceptions.UnpackException)


def load_latest(directory: str, like: PyTree,
                *, strict: bool = False) -> tuple[int, PyTree]:
    """Restore the newest *loadable* ``step_*.msgpack`` in ``directory``.

    A torn write (truncated file) or otherwise corrupt checkpoint is
    skipped with a fallback to the next-newest step; ``strict=True``
    restores the old fail-fast behavior (newest or nothing). Raises
    ``FileNotFoundError`` when no checkpoints exist at all, ``ValueError``
    (listing every per-step failure) when none of them load.
    Returns ``(step, tree)``."""
    steps = checkpoint_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no step_*.msgpack checkpoints in {directory!r}")
    failures = []
    for step in reversed(steps):
        try:
            return step, load_checkpoint(directory, step, like)
        except _CORRUPT_ERRORS as e:
            if strict:
                raise
            failures.append(f"step {step}: {type(e).__name__}: {e}")
    raise ValueError(f"no loadable checkpoint in {directory!r}; every "
                     "candidate failed:\n  " + "\n  ".join(failures))


def checkpoint_steps(directory: str) -> list[int]:
    """All checkpoint steps present in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(m.group(1))
        for fname in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)\.msgpack", fname))
    )


def latest_step(directory: str) -> int | None:
    steps = checkpoint_steps(directory)
    return steps[-1] if steps else None
