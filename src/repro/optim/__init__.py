"""Pure-JAX optimizers (container has no optax)."""
from repro.optim.adam import AdamState, adamw_init, adamw_update, sgd_update
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "AdamState",
    "adamw_init",
    "adamw_update",
    "sgd_update",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
]
