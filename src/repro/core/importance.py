"""Adaptive importance-based sampling (paper Eq. 7-8).

The optimal per-node sampling probability minimising gradient variance
(Eq. 7) is p_v ∝ ||∇f_v||, but that needs n_k per-sample gradients per epoch.
The paper's O(n_k) proxy: the loss *difference* between two consecutive local
model updates, Δ_j = f(θ_{j+1}) - f(θ_j) per node, with
p_v = ||Δ_j|| / Σ ||Δ_j|| (Eq. 8). One forward pass per update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def loss_delta_scores(loss_curr: jnp.ndarray, loss_prev: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """|Δ_j| per node, masked. Nodes never seen (prev < 0 sentinel) fall back
    to their current loss so cold-start nodes are still sampled."""
    delta = jnp.abs(loss_curr - loss_prev)
    cold = loss_prev < 0.0
    scores = jnp.where(cold, jnp.abs(loss_curr), delta)
    return scores * mask


def importance_probs(scores: jnp.ndarray, mask: jnp.ndarray, *, floor: float = 1e-8) -> jnp.ndarray:
    """Normalise scores into selection probabilities (Eq. 8).

    A tiny uniform floor keeps every training node reachable (unbiasedness of
    importance sampling needs p_v > 0; also avoids 0/0 on fresh clients).
    """
    s = scores * mask + floor * mask
    total = jnp.maximum(s.sum(), 1e-30)
    return s / total


QUANTIZE_DROP_BITS = 12   # float32 mantissa bits zeroed from the sampling key


def quantize_key(x: jnp.ndarray, drop_bits: int = QUANTIZE_DROP_BITS) -> jnp.ndarray:
    """Zero the low ``drop_bits`` mantissa bits of a float32 array.

    Keys that differ only in the last few ULPs (backend/codegen FP jitter in
    the upstream loss pass) collapse onto the same grid point, so ordering
    decisions made on quantized keys are insensitive to that jitter. The
    remaining 23 - drop_bits mantissa bits still give a ~2^-11 relative grid —
    far finer than any meaningful score difference between two nodes.
    """
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    keep = jnp.uint32(0xFFFFFFFF & ~((1 << drop_bits) - 1))
    return jax.lax.bitcast_convert_type(u & keep, jnp.float32)


def stable_rank(keys: jnp.ndarray) -> jnp.ndarray:
    """Ascending stable rank of every last-axis slot in ONE top-k pass.

    ``stable_rank(x)[..., i]`` is the position slot ``i`` takes when the
    mantissa-quantized keys sort ascending with ties resolved to the lower
    index — the same ordering ``argsort(q).argsort()`` produces, but via a
    single stable ``lax.top_k`` plus an inverse-permutation scatter instead
    of two full sorts. Quantization (the ``sample_batch`` scheme) makes the
    ranking insensitive to last-ULP FP jitter in the key producer.
    """
    q = quantize_key(keys)
    k = keys.shape[-1]
    # top_k of -q lists slots in ascending-q order; stable, so equal keys
    # resolve to the lower slot index — exactly argsort's tie rule
    _, idx = jax.lax.top_k(-q, k)
    ranks = jnp.broadcast_to(jnp.arange(k, dtype=idx.dtype), idx.shape)
    return jnp.put_along_axis(jnp.zeros_like(idx), idx, ranks, axis=-1,
                              inplace=False)


def sample_batch(key, probs: jnp.ndarray, batch_size: int, mask: jnp.ndarray):
    """Sample ``batch_size`` distinct node indices with P(v) ∝ probs.

    Gumbel-top-k gives distinct draws proportional to probs without
    materialising the full categorical-without-replacement chain; masked
    entries can never win. Returns (idx (b,), valid (b,)).

    The perturbed key is mantissa-quantized and ranked by ``lax.top_k``
    (stable: equal keys resolve to the lower index), i.e. a stable argsort on
    a jitter-insensitive key. Exact float ordering of the raw scores would let
    last-ULP FP differences in the loss pass flip which node wins a near-tie
    and silently fork the whole comm/acc trajectory between runs; the Gumbel
    noise itself is counter-based PRNG output and already bit-exact.
    """
    logp = jnp.log(jnp.maximum(probs, 1e-30)) + jnp.where(mask > 0, 0.0, -1e30)
    g = -jnp.log(-jnp.log(jax.random.uniform(key, probs.shape, minval=1e-20, maxval=1.0)))
    _, idx = jax.lax.top_k(quantize_key(logp + g), batch_size)
    valid = mask[idx] > 0   # clients smaller than batch_size yield padded picks
    return idx, valid


def uniform_probs(mask: jnp.ndarray) -> jnp.ndarray:
    return mask / jnp.maximum(mask.sum(), 1.0)


def sampling_variance(probs: jnp.ndarray, grad_norms: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """The Eq. (7) objective: Σ ||∇f_v||² / p_v over valid nodes — the
    quantity importance sampling minimises. Used by tests/diagnostics."""
    p = jnp.maximum(probs, 1e-30)
    return jnp.sum(mask * jnp.square(grad_norms) / p)
