"""Batched serving driver: prefill a batch of prompts, then decode with a
shared KV cache — the serve_step that decode dry-run shapes lower.

Usage:
    PYTHONPATH=src python -m repro.launch.serve_lm_cli --arch mini --batch 4 --prompt-len 64 --gen 32

(Formerly ``repro.launch.serve`` — renamed so the federated graph server,
``repro.launch.serve_fed`` / ``repro.serve``, is unambiguous; a deprecation
shim remains at the old path.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import list_archs
from repro.launch.train import get_train_config
from repro.models import lm


def serve(args) -> dict:
    cfg = get_train_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G + (cfg.n_image_tokens or 0)

    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, jnp.int32)
    kw = {}
    if cfg.n_image_tokens:
        kw["image_embeds"] = jnp.zeros((B, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    if cfg.n_encoder_layers:
        kw["enc_frames"] = jnp.zeros((B, cfg.encoder_seq_len, cfg.d_model), cfg.jnp_dtype)

    t0 = time.time()
    last_logits, state = lm.lm_prefill(params, cfg, prompts, max_len, **kw)
    prefill_s = time.time() - t0

    decode = jax.jit(lambda p, s, t, pos: lm.decode_step(p, cfg, s, t, pos))
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    generated = [tok]
    offset = P + (cfg.n_image_tokens or 0)
    t0 = time.time()
    for i in range(G - 1):
        logits, state = decode(params, state, tok, jnp.asarray(offset + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0

    out_tokens = jnp.concatenate(generated, axis=1)
    tok_per_s = B * (G - 1) / max(decode_s, 1e-9)
    print(f"arch={cfg.arch_id} batch={B} prompt={P} gen={G}")
    print(f"prefill: {prefill_s*1e3:.1f} ms   decode: {tok_per_s:,.0f} tok/s "
          f"({decode_s/max(G-1,1)*1e3:.2f} ms/step)")
    return {
        "prefill_s": prefill_s,
        "decode_tok_s": tok_per_s,
        "tokens": out_tokens,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mini", choices=["mini", *list_archs()])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
