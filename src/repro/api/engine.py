"""FedEngine: the composable federated training engine (Algorithm 1).

The engine owns only the method-agnostic spine of a round:

    select clients -> strategy hooks -> vmapped LocalUpdate -> aggregate
    -> historical write-back -> cost accounting -> callbacks

Everything method- or policy-specific is a pluggable component (see
repro.api.protocols / strategies / callbacks / registry). The per-client
LocalUpdate is jit-compiled once per MethodConfig and vmapped over the m
selected clients; the cross-client ghost pull inside lowers to a gather
over the stacked client axis (on a TPU mesh this is the all-to-all of the
real deployment — see launch/fed_dryrun.py).

Two executors share that compiled client step:

* the **stepwise** path (``run_round`` = ``dispatch`` + ``merge``): one
  XLA call per round plus eager host-side aggregation/write-back. The
  AsyncScheduler's per-event loop always uses it.
* the **fused** path (``run_fused``): the whole round — vmapped
  LocalUpdate, aggregation, historical/ghost/prev_loss write-back — is one
  traced ``round_step``, ``lax.scan``-ned across every round between eval
  boundaries and jitted with ``donate_argnums`` on the big mutable buffers
  (params, hist1, age, ghost_feat, prev_loss, PRNG key), so the (K, n_tot,
  H1) tables update in place instead of being copied every round. Light
  per-round stats stream out as stacked scan outputs and the host tail
  (cost accounting, strategy.post_round, callbacks) replays them at the
  chunk boundary — bit-identical history to the stepwise loop, pinned by
  tests/test_fused.py. ``SyncScheduler`` auto-selects it whenever every
  component declares itself fusable (see ``FedEngine.fused_eligibility``).

When a device ``mesh`` is configured, the fused chunk additionally shards
its vmapped client axis across the mesh's ``("clients",)`` axis
(``repro.sharding.fed.build_sharded_chunk``): each device trains its slice
of the cohort, aggregation lowers to a weighted all-reduce, ragged cohorts
pad with zero-weight dummy clients, and history stays allclose to the
unsharded fused run (see ``FedEngine.sharded_eligibility`` and
tests/test_sharding.py; fp32 all-reduce reassociation forfeits bit-parity).

On a 2-D ``("pods", "clients")`` mesh with ``table_sharding`` allowing it,
EVERY K-sized array shards its K axis over the pod axis
(``repro.sharding.tables.build_pod_sharded_chunk``): each pod owns its
resident clients' hist1/age/ghost_feat/prev_loss rows AND their static
arrays (features/adjacency/labels/masks, cached as pod shards once per
engine together with the bucketed-exchange-built ghost-source feature
table), the cohort's rows are fetched from owner pods per round, the
cross-client ghost pull is a partition-time-bucketed ``all_to_all`` keyed
by ``ghost_owner`` and gated per round on the host-derived tau-sync
predicate (non-sync rounds skip it entirely), and the write-back is a
host-routed cohort-keyed bucket exchange (only touched rows reach their
owner pod) — no per-device resident or per-round collective scales with K
(see ``FedEngine.pod_sharded_eligibility``, the soft fallback chain
pod-sharded -> client-sharded -> fused -> stepwise,
tests/test_pod_sharding.py, and the ``launch/fed_dryrun.py --pods`` byte
ledger). ``merge_reduce="pairwise"`` swaps the merges' psum for a
deterministic fp32 binary-tree over gathered partial sums on BOTH mesh
kinds (1-D client and 2-D pod).

``repro.federated.simulator.run_federated`` is a thin compatibility shim
over ``FedEngine(...).run()`` and is proven history-identical to the legacy
monolith by tests/test_api.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.callbacks import (
    EarlyStopCallback,
    EvalCallback,
    HistoryCallback,
    RoundContext,
    VerboseCallback,
    default_callbacks,
)
from repro.api.protocols import (
    AdaptiveSyncController,
    PaperCostModel,
    UniformSelector,
)
from repro.api.registry import (
    build_aggregator,
    build_scheduler,
    build_strategy,
    method_config,
)
from repro.core.fedais import MethodConfig, batch_size_for, make_vmapped_update
from repro.core.historical import init_historical
from repro.faults import (
    FaultCounters,
    FaultPlan,
    UpdateGuard,
    build_faulty_chunk,
    corrupt_params_stack,
    guard_mask,
)
from repro.federated.costs import CostMeter, DelayModel
from repro.federated.partition import (
    FederatedGraph,
    exchange_ghost_features,
    ghost_exchange_buckets,
    writeback_routing,
)
from repro.federated.quant import check_sync_dtype, quant_roundtrip
from repro.federated.server import build_eval_graph, evaluate_global
from repro.graph.data import GraphData
from repro.models.gcn import (
    AGG_BACKENDS,
    HIDDEN,
    gcn_flops_per_node,
    gcn_init,
    gcn_param_count,
)
from repro.sharding.fed import (
    build_sharded_chunk,
    client_axis_of,
    cohort_padding,
    replicate_to_mesh,
)
from repro.sharding.tables import (
    POD_ARRAY_KEYS,
    build_pod_sharded_chunk,
    pad_tables_to_pods,
    pod_axes_of,
    shard_tables_to_mesh,
    sync_round_gates,
)

_CLIENT_ARRAY_KEYS = (
    "features", "labels", "node_mask", "train_mask",
    "nbr_idx", "nbr_mask", "ghost_owner", "ghost_row", "ghost_mask",
)

# Per-round stats streamed out of the fused scan (everything except the
# (m, n_max) loss_all table, which stays in the on-device carry as prev_loss).
_LIGHT_STATS = ("epoch_losses", "n_sync", "n_ghost_pulled",
                "mean_importance_entropy")

# Default-stack callbacks proven side-effect-free on non-eval rounds (they
# only act when EvalCallback set ctx.metrics, i.e. at chunk boundaries) —
# the exact types, not subclasses: an override could observe mid-chunk state
# the fused executor no longer materializes per round.
_FUSED_SAFE_CALLBACKS = (EvalCallback, HistoryCallback, VerboseCallback,
                         EarlyStopCallback)


@dataclass
class RunResult:
    method: str
    dataset: str
    history: dict = field(default_factory=dict)     # per-round lists
    final: dict = field(default_factory=dict)
    costs: CostMeter = field(default_factory=CostMeter)

    def record(self, **kv):
        for k, v in kv.items():
            self.history.setdefault(k, []).append(v)

    def rounds_to_acc(self, target: float) -> int | None:
        for i, a in enumerate(self.history.get("test_acc", [])):
            if a >= target:
                return i + 1
        return None

    def comm_to_acc(self, target: float) -> float | None:
        for a, c in zip(self.history.get("test_acc", []), self.history.get("comm_total", [])):
            if a >= target:
                return c
        return None


@dataclass
class EngineState:
    """Everything mutable across rounds; components read/write this."""

    rng: np.random.Generator          # host RNG (client selection, ...)
    key: jnp.ndarray                  # device PRNG chain
    params: Any                       # global model pytree
    hist: Any                         # HistoricalState (hist1/age tables)
    ghost_feat: jnp.ndarray           # (K, g_max, F) synced/imputed ghosts
    prev_loss: jnp.ndarray            # (K, n_max) last-seen per-node loss
    arrays: dict                      # device-resident stacked client arrays
    result: RunResult
    tau: int = 1                      # current sync interval
    initial_loss: Optional[float] = None
    round: int = 0
    last_eval: Optional[tuple] = None  # (round, metrics) from EvalCallback
    # per-update staleness of the merge being post-processed (None on the
    # sync paths, where merge order == dispatch order by construction);
    # strategies read it to attribute async rewards to dispatch versions
    last_staleness: Optional[np.ndarray] = None
    # what the engine/scheduler did about faults (dropped uploads,
    # quarantined updates, async timeouts/retries/evictions, ...)
    fault_events: FaultCounters = field(default_factory=FaultCounters)


def _client_slice(arrays: dict, ids: np.ndarray) -> dict:
    return {k: v[ids] for k, v in arrays.items()}


class FedEngine:
    """Composable federated trainer over a partitioned graph.

    ``method`` is a registered method name (see repro.api.registry) or an
    explicit MethodConfig. Any pluggable component can be overridden via
    keyword; the defaults reproduce the paper's Algorithm 1 exactly.
    """

    def __init__(
        self,
        graph: GraphData,
        fed: FederatedGraph,
        method: Union[str, MethodConfig],
        *,
        rounds: int = 30,
        clients_per_round: int = 10,
        seed: int = 0,
        target_acc: float | None = None,
        delay: DelayModel = DelayModel(),
        eval_every: int = 1,
        verbose: bool = False,
        selector=None,
        aggregator=None,
        sync=None,
        cost_model=None,
        strategy=None,
        scheduler=None,
        callbacks: Optional[Sequence] = None,
        eval_backend: str = "gather",
        train_backend: str = "gather",
        mesh=None,
        client_sharding: str = "auto",
        table_sharding: str = "auto",
        merge_reduce: str = "psum",
        sync_dtype: str = "fp32",
        faults: Optional[FaultPlan] = None,
        guard: Union[UpdateGuard, bool, None] = True,
    ):
        self.graph, self.fed = graph, fed
        self.mcfg = method_config(method) if isinstance(method, str) else method
        self.rounds = rounds
        self.clients_per_round = clients_per_round
        self.seed = seed

        # ---- pluggable components ----
        self.strategy = strategy if strategy is not None else build_strategy(self.mcfg)
        self.selector = selector if selector is not None else UniformSelector()
        if aggregator is None:
            aggregator = build_aggregator(self.mcfg.aggregator)
        elif isinstance(aggregator, str):   # registry key, e.g. "weighted"
            aggregator = build_aggregator(aggregator)
        self.aggregator = aggregator
        self.sync = sync if sync is not None else AdaptiveSyncController()
        if cost_model is None:
            cost_model = PaperCostModel(delay)
        elif delay != DelayModel():
            # same fail-fast contract as the callbacks/knobs conflict below
            raise ValueError("`delay` only configures the default "
                             "PaperCostModel; give your explicit cost_model "
                             "its own delay instead")
        self.cost_model = cost_model
        if scheduler is None:
            scheduler = self.mcfg.scheduler     # registry key, "sync" default
        if isinstance(scheduler, str):
            scheduler = build_scheduler(scheduler)
        self.scheduler = scheduler
        if callbacks is None:
            self.callbacks = default_callbacks(eval_every=eval_every, verbose=verbose,
                                               target_acc=target_acc)
        else:
            # an explicit callback stack replaces the default one wholesale;
            # the convenience knobs only parameterize the default stack
            if eval_every != 1 or verbose or target_acc is not None:
                raise ValueError(
                    "eval_every/verbose/target_acc only configure the default "
                    "callback stack; with an explicit `callbacks` list, drop "
                    "them and add EvalCallback/VerboseCallback/"
                    "EarlyStopCallback to your list instead")
            self.callbacks = list(callbacks)

        # ---- client-axis sharding (the fused executor's scale-out knob) ----
        if client_sharding not in ("auto", "divisible", "off"):
            raise ValueError(
                f"unknown client_sharding {client_sharding!r}; known: "
                "auto (pad ragged cohorts) | divisible (shard only when the "
                "cohort splits evenly) | off")
        if table_sharding not in ("auto", "pods", "replicated"):
            raise ValueError(
                f"unknown table_sharding {table_sharding!r}; known: "
                "auto (pod-shard when the mesh has a 'pods' axis) | pods | "
                "replicated")
        if merge_reduce not in ("psum", "pairwise"):
            raise ValueError(
                f"unknown merge_reduce {merge_reduce!r}; known: psum "
                "(weighted all-reduce) | pairwise (fp32 fixed-tree over "
                "gathered partials)")
        # wire format of every historical-embedding exchange (ghost pull,
        # write-back, pod collectives) — repro.federated.quant. "fp32" is
        # bit-inert; bf16/int8 quantize the wire, accumulators stay fp32.
        self.sync_dtype = check_sync_dtype(sync_dtype)
        # batch neighbor aggregation inside every executor's LocalUpdate
        # (models.gcn.gcn_batch_forward backend=...): "gather" is the
        # bit-parity default; "segment" runs the bucketed in-trace CSR and
        # never materializes the (b, K, d) gather; "spmm" the Pallas kernel
        if train_backend not in AGG_BACKENDS:
            raise ValueError(f"unknown train_backend {train_backend!r}; "
                             f"known: {AGG_BACKENDS}")
        self.train_backend = train_backend
        self.mesh = mesh
        self.client_sharding = client_sharding
        self.table_sharding = table_sharding
        self.merge_reduce = merge_reduce
        self.client_axis = None
        self.pod_axes = None
        if mesh is not None:
            self.pod_axes = pod_axes_of(mesh)
            self.client_axis = client_axis_of(mesh)
            if self.client_axis is None and self.pod_axes is None:
                raise ValueError(
                    "client sharding needs a mesh with a 'clients' axis (or "
                    f"a single axis); got axes {tuple(mesh.shape)}")
        if table_sharding == "pods" and self.pod_axes is None:
            raise ValueError(
                "table_sharding='pods' needs a mesh with ('pods', 'clients') "
                f"axes; got {None if mesh is None else tuple(mesh.shape)}")
        # "stepwise"|"fused"|"fused_faulty"|"sharded_fused"|"pod_sharded"
        self.last_executor: Optional[str] = None

        # ---- fault injection + merge guard (repro.faults) ----
        # `faults` is a seeded FaultPlan; an empty plan (or None) is inert
        # by contract — every fault branch below gates on the plan actually
        # firing, so empty-plan runs stay bit-identical to pre-fault code.
        # `guard` is the merge-side finite/norm admission rule: True (the
        # default) checks finiteness only, an UpdateGuard instance adds a
        # delta-norm ceiling, False/None disables guarding entirely (and
        # lets a poisoned update NaN the merge — explicit opt-out).
        if faults is not None and not isinstance(faults, FaultPlan):
            raise ValueError(f"faults must be a FaultPlan or None, got "
                             f"{type(faults).__name__}")
        self.faults = faults
        self._faults_active = faults is not None and not faults.empty
        if guard is True:
            self._guard: Optional[UpdateGuard] = UpdateGuard()
        elif guard is False or guard is None:
            self._guard = None
        elif isinstance(guard, UpdateGuard):
            self._guard = guard
        else:
            raise ValueError("guard must be an UpdateGuard, True (finite "
                             f"check only) or False/None, got {guard!r}")
        self._faulty_chunk = None           # built lazily under a live plan

        # ---- static geometry + compiled LocalUpdate ----
        self.F, self.H1 = fed.n_features, HIDDEN[0]
        self.n_params = gcn_param_count(self.F, fed.n_classes)
        avg_deg = float(fed.nbr_mask.sum() / np.maximum(fed.node_mask.sum(), 1))
        self.fwd_flops_node = gcn_flops_per_node(self.F, fed.n_classes, avg_deg)
        self.bsz = batch_size_for(self.mcfg, fed.n_max)
        # the raw vmapped step is shared by every executor: the stepwise path
        # jits it standalone, the fused path traces it inside the scanned
        # round_step, the sharded path shard_maps it (same computation, one
        # compilation each)
        self._vm_raw = make_vmapped_update(self.mcfg, fed.n_max, fed.g_max,
                                           self.H1, sync_dtype=self.sync_dtype,
                                           train_backend=self.train_backend)
        self._vm = jax.jit(self._vm_raw)
        self._fused_chunk = None            # built lazily by run_fused
        self._sharded_chunk = None          # built lazily when mesh is set
        self._sharded_chunk_m = None        # cohort size it was traced for
        self._pod_chunk = None              # built lazily in pod-table mode
        self._pod_chunk_m = None
        self._ghost_buckets = None          # partition-time all-to-all plan
        self._pod_static = None             # pod-sharded static arrays + gsrc
        self._sizes_f32 = jnp.asarray(fed.client_sizes, jnp.float32)
        self.eval_graph = build_eval_graph(graph, max_deg=fed.max_deg, seed=seed,
                                           backend=eval_backend)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def init_state(self) -> EngineState:
        fed, seed = self.fed, self.seed
        K, n_max, g_max, F = fed.n_clients, fed.n_max, fed.g_max, self.F
        arrays = {k: jnp.asarray(getattr(fed, k)) for k in _CLIENT_ARRAY_KEYS}
        state = EngineState(
            rng=np.random.default_rng(seed),
            key=jax.random.PRNGKey(seed),
            params=gcn_init(jax.random.PRNGKey(seed + 1), F, fed.n_classes),
            hist=init_historical(K, n_max, g_max, F, self.H1),
            ghost_feat=jnp.zeros((K, g_max, F), jnp.float32),
            prev_loss=jnp.full((K, n_max), -1.0, jnp.float32),
            arrays=arrays,
            result=RunResult(method=self.mcfg.name, dataset=self.graph.name),
            tau=self.sync.initial(self.mcfg),
        )
        self.strategy.setup(self, state)
        return state

    def dispatch(self, state: EngineState, sel: np.ndarray, t: int):
        """Client half of a round: RNG split, strategy hooks, vmapped
        LocalUpdate for the cohort ``sel`` departing from server version
        ``t`` (the global batch-epoch offset). Returns the stacked outputs
        ``(params, hist1, age, ghost_feat, stats)``."""
        state.round = t
        sel_j = jnp.asarray(sel)
        state.key, *ks = jax.random.split(state.key, len(sel) + 1)
        keys = jnp.stack(ks)

        fanouts = self.strategy.choose_fanouts(self, sel)
        self.strategy.pre_round(self, state, sel)

        client_data = _client_slice(state.arrays, sel)
        return self._vm(
            state.params, client_data, state.arrays["features"], state.hist.hist1,
            state.hist.hist1[sel_j], state.hist.age[sel_j], state.ghost_feat[sel_j],
            state.prev_loss[sel_j], jnp.asarray(state.tau, jnp.int32), fanouts,
            jnp.asarray(t * self.mcfg.local_epochs, jnp.int32), keys,
        )

    def merge(self, state: EngineState, t: int, sel: np.ndarray, out,
              *, staleness: np.ndarray | None = None, aggregator=None,
              wall_clock_s: float | None = None,
              virtual_time: float | None = None) -> bool:
        """Server half of a round ``t``: aggregation, historical write-back,
        cost accounting, strategy/callback hooks. Async schedulers pass the
        per-update ``staleness`` (for discounted weights), a staleness-aware
        ``aggregator``, and the virtual-clock ``wall_clock_s`` actually
        waited (overriding the lockstep max(compute)+sync billing).

        When an ``UpdateGuard`` is configured (the default), every arriving
        update must be finite (and inside the guard's delta-norm ceiling)
        to aggregate or write back its historical rows; failures are
        quarantined — counted in ``state.fault_events.n_quarantined``,
        never averaged in. An all-pass guard takes the original unfiltered
        code path, so guarded healthy runs stay bit-identical to unguarded
        ones. A merge left with no survivor (everyone dropped out or was
        quarantined) is a server no-op round: params and tables carry over
        unchanged. Returns True if a callback requested stop."""
        state.round = t
        new_params_stack, new_hist1, new_age, new_ghost_feat, stats = out

        # cost/post_round observe the FULL pre-guard cohort below: the
        # client work and its upload happened even when the merge refuses
        # the update (identical to the pre-guard path when nothing fires)
        full_sel, full_stats, full_staleness = np.asarray(sel), stats, staleness
        if self._guard is not None and len(full_sel):
            ok = guard_mask(new_params_stack, state.params,
                            self._guard.max_norm)
            if not ok.all():
                state.fault_events.n_quarantined += int((~ok).sum())
                keep = np.flatnonzero(ok)
                sel = full_sel[keep]
                if staleness is not None:
                    staleness = np.asarray(staleness)[keep]
                (new_params_stack, new_hist1, new_age, new_ghost_feat,
                 stats) = jax.tree_util.tree_map(
                    lambda x: x[keep],
                    (new_params_stack, new_hist1, new_age, new_ghost_feat,
                     stats))

        if len(sel) == 0:
            # every update dropped out or was quarantined: server no-op
            state.fault_events.n_empty_merges += 1
        else:
            sel_j = jnp.asarray(sel)
            agg = self.aggregator if aggregator is None else aggregator
            weights = jnp.asarray(self.fed.client_sizes[sel], jnp.float32)
            if staleness is None:
                state.params = agg.aggregate(new_params_stack, weights)
            else:
                state.params = agg.aggregate(new_params_stack, weights,
                                             staleness)

            # Only an async buffer can merge the same client twice
            # (re-selected while its previous update was still in flight):
            # every update aggregates, but the client-state write-back keeps
            # only the freshest entry (``sel`` arrives sorted by dispatch
            # version, so the last occurrence wins). Sync cohorts are
            # sampled without replacement and never duplicated, so they skip
            # the host np.unique + fancy-index round-trip entirely
            # (``staleness is None`` marks the sync path).
            if staleness is not None and len(np.unique(sel)) != len(sel):
                _, last_rev = np.unique(np.asarray(sel)[::-1],
                                        return_index=True)
                w = np.sort(len(sel) - 1 - last_rev)
                sel_j = jnp.asarray(np.asarray(sel)[w])
                new_hist1, new_age = new_hist1[w], new_age[w]
                new_ghost_feat = new_ghost_feat[w]
                loss_all = stats["loss_all"][w]
            else:
                loss_all = stats["loss_all"]
            if self.sync_dtype != "fp32":
                # the write-back is a wire: float rows round-trip through
                # the codec (age stays int32/exact) on every executor
                new_hist1 = quant_roundtrip(new_hist1, self.sync_dtype)
                new_ghost_feat = quant_roundtrip(new_ghost_feat,
                                                 self.sync_dtype)
                loss_all = quant_roundtrip(loss_all, self.sync_dtype)
            state.hist = state.hist._replace(
                hist1=state.hist.hist1.at[sel_j].set(new_hist1),
                age=state.hist.age.at[sel_j].set(new_age),
            )
            state.ghost_feat = state.ghost_feat.at[sel_j].set(new_ghost_feat)
            state.prev_loss = state.prev_loss.at[sel_j].set(loss_all)

        if len(full_sel):
            cost = self.cost_model.round_cost(self, state, full_sel,
                                              full_stats)
        else:
            cost = CostMeter()          # nothing arrived, nothing billed
        if wall_clock_s is not None:
            cost.wall_clock_s = wall_clock_s    # overlapped (virtual-clock) billing
        state.result.costs.add(cost)
        state.last_staleness = full_staleness   # aligned with full_sel
        try:
            if len(full_sel):
                self.strategy.post_round(self, state, full_sel, full_stats)
        finally:
            state.last_staleness = None

        ctx = RoundContext(engine=self, state=state, t=t, rounds=self.rounds,
                           virtual_time=virtual_time, staleness=staleness)
        for cb in self.callbacks:
            cb.on_round_end(ctx)
        return ctx.stop

    def run_round(self, state: EngineState, t: int) -> bool:
        """One lockstep federated round; True if a callback requested stop."""
        self.last_executor = "stepwise"
        state.round = t
        sel = self.selector.select(self, state)
        out = self.dispatch(state, sel, t)
        wall = None
        if self._faults_active:
            sel, out, wall = self._inject_faults(state, t, sel, out)
        return self.merge(state, t, sel, out, wall_clock_s=wall)

    def _inject_faults(self, state: EngineState, t: int, sel, out):
        """Apply the FaultPlan between dispatch and merge (the stepwise
        sync path): corrupt the marked members' uploaded params (the merge
        guard quarantines them), drop lost members' uploads entirely, and
        re-bill the round's wall clock with straggler delay factors (the
        lockstep server waits for every dispatched member, stragglers
        included, but the merge overhead ``o`` is priced from the
        survivors — dropped uploads never reach the server).
        Returns (surviving_sel, filtered_out, wall_override)."""
        plan = self.faults
        sel = np.asarray(sel)
        full_sel, full_stats = sel, out[-1]
        cmask = plan.corruptions(t, sel)
        if cmask.any():
            out = (corrupt_params_stack(out[0], cmask, plan.corrupt_value()),
                   ) + tuple(out[1:])
        drop = plan.drops(t, sel)
        if drop.any():
            state.fault_events.n_dropped += int(drop.sum())
            keep = np.flatnonzero(~drop)
            sel = sel[keep]
            out = jax.tree_util.tree_map(lambda x: x[keep], out)
        wall = None
        if plan.straggler_frac > 0.0:
            times = np.asarray(self.cost_model.client_compute_times(
                self, state, full_sel, full_stats), np.float64)
            times = times * plan.delay_factors(full_sel)
            o = self.cost_model.sync_overhead(self, sel, out[-1])
            wall = float(np.max(times)) + o / max(state.tau, 1)
        return sel, out, wall

    # ------------------------------------------------------------------
    # fused executor (the SyncScheduler hot path)
    # ------------------------------------------------------------------

    def fused_eligibility(self) -> tuple[bool, str]:
        """Can this engine run the fused scanned executor bit-identically?

        Every component must declare itself safe for deferred host
        observation: the selector precomputes a whole chunk's cohorts from
        the host RNG alone, the aggregator traces inside jit, the strategy
        has no per-round host hooks, the cost model prices rounds purely
        from streamed stats, and the callbacks are the exact default-stack
        types (side-effect-free on non-eval rounds). Returns (ok, reason).
        """
        from repro.api.strategies import MethodStrategy

        scls = type(self.strategy)
        fusable = getattr(self.strategy, "fusable", None)
        if fusable is None:
            fusable = (scls.pre_round is MethodStrategy.pre_round
                       and scls.post_round is MethodStrategy.post_round)
        if not fusable:
            return False, f"strategy {scls.__name__} has per-round host hooks"
        if not getattr(self.selector, "precomputable", False):
            return False, (f"selector {type(self.selector).__name__} reads "
                           "per-round state (not precomputable)")
        if not getattr(self.aggregator, "jit_safe", False):
            return False, (f"aggregator {type(self.aggregator).__name__} "
                           "is not jit-traceable (jit_safe)")
        if not getattr(self.cost_model, "fused_safe",
                       isinstance(self.cost_model, PaperCostModel)):
            return False, (f"cost model {type(self.cost_model).__name__} "
                           "not declared fused_safe")
        for cb in self.callbacks:
            if not getattr(cb, "fused_safe",
                           type(cb) in _FUSED_SAFE_CALLBACKS):
                return False, (f"callback {type(cb).__name__} may observe "
                               "per-round state (not fused_safe)")
        if self._faults_active:
            # the fault-aware fused chunk lowers aggregation to a hardcoded
            # masked weighted mean (like the sharded executors); a custom
            # merge rule must take the stepwise path, which supports the
            # full fault plan through dispatch/merge
            why = self._allreduce_unsafe_reason()
            if why:
                return False, ("fault-aware fused chunk needs a mean-family "
                               "merge: " + why)
        return True, ""

    def sharded_eligibility(self, m: int | None = None) -> tuple[bool, str]:
        """Can the fused chunk shard its client axis over ``self.mesh``?

        Refines ``fused_eligibility`` (which must already hold — the
        sharded executor is a variant of the fused one, never of the
        stepwise loop): server aggregation must lower to a weighted
        all-reduce inside the shard-mapped round body (``allreduce_safe``
        mean-family aggregators), and with ``client_sharding="divisible"``
        the cohort ``m`` must split evenly across the mesh axis instead of
        being padded. Ineligible configs fall back to the unsharded fused
        chunk (and from there to stepwise, per ``fused_eligibility``).
        """
        if self.mesh is None:
            return False, "no mesh configured"
        if self.client_sharding == "off":
            return False, "client_sharding='off'"
        if self.client_axis is None:
            return False, ("mesh has no 'clients' (or single) axis to shard "
                           "the cohort over")
        why = self._allreduce_unsafe_reason()
        if why:
            return False, why
        why = self._sharded_faults_unsafe_reason()
        if why:
            return False, why
        if m is not None and self.client_sharding == "divisible":
            shards = self.mesh.shape[self.client_axis]
            if m % shards:
                return False, (f"cohort size {m} does not divide mesh axis "
                               f"size {shards} (client_sharding='divisible' "
                               "disables padding)")
        return True, ""

    def _sharded_faults_unsafe_reason(self) -> str:
        """Why the active FaultPlan cannot run on the sharded executors
        (empty string when it can). Dropout rides the executors' existing
        zero-weight dummy mechanics; corruption needs the in-trace guard
        only the fault-aware fused chunk (and the stepwise merge) carry."""
        if self._faults_active and self.faults.corrupt > 0.0:
            return ("sharded executors support dropout/straggler faults "
                    "only; corrupt updates need the fault-aware fused "
                    "chunk's in-trace guard")
        return ""

    def _allreduce_unsafe_reason(self) -> str:
        """Why the aggregator cannot lower to the sharded executors' merge
        (empty string when it can). The sharded merges never call
        aggregator.aggregate — they lower to the hardcoded weighted psum /
        pairwise mean — so the flag must be vouched by the class that
        PROVIDES aggregate: a subclass overriding aggregate without
        re-declaring allreduce_safe must not inherit eligibility (its
        override would be silently replaced by the mean)."""
        provider = next((c for c in type(self.aggregator).__mro__
                         if "aggregate" in c.__dict__), None)
        if provider is None or not provider.__dict__.get("allreduce_safe", False):
            return (f"aggregator {type(self.aggregator).__name__} does "
                    "not declare its aggregate() a weighted-mean "
                    "family (allreduce_safe) rule")
        return ""

    def pod_sharded_eligibility(self, m: int | None = None) -> tuple[bool, str]:
        """Can the fused chunk run with pod-sharded historical tables?

        Refines ``sharded_eligibility`` for the ``("pods", "clients")``
        2-D mesh mode (repro.sharding.tables): the mesh must carry both
        axes, ``table_sharding`` must allow it, and — like the
        client-sharded executor — the aggregator must be an
        ``allreduce_safe`` weighted-mean family. Cohorts pad over the FULL
        device count (pods x clients); ``client_sharding="divisible"``
        demands divisibility instead. Ineligible configs fall soft down
        the chain: pod-sharded -> client-sharded -> fused -> stepwise.
        """
        if self.mesh is None:
            return False, "no mesh configured"
        if self.pod_axes is None:
            return False, ("mesh has no ('pods', 'clients') axes "
                           f"(got {tuple(self.mesh.shape)})")
        if self.table_sharding == "replicated":
            return False, "table_sharding='replicated'"
        if self.client_sharding == "off":
            return False, "client_sharding='off'"
        why = self._allreduce_unsafe_reason()
        if why:
            return False, why
        why = self._sharded_faults_unsafe_reason()
        if why:
            return False, why
        if m is not None and self.client_sharding == "divisible":
            shards = self.mesh.devices.size
            if m % shards:
                return False, (f"cohort size {m} does not divide the mesh's "
                               f"{shards} devices (client_sharding="
                               "'divisible' disables padding)")
        return True, ""

    def _build_fused_chunk(self):
        """One jitted chunk: scan the traced round_step over S rounds with
        the big mutable buffers donated (updated in place, never copied)."""
        vm, agg, sizes = self._vm_raw, self.aggregator, self._sizes_f32
        sync_dtype = self.sync_dtype

        def chunk(params, hist1, age, ghost_feat, prev_loss, key,
                  arrays, sel_stack, fan_stack, eoffs, tau):
            m = sel_stack.shape[1]

            def round_step(carry, xs):
                params, hist1, age, ghost_feat, prev_loss, key = carry
                sel, fanouts, eoff = xs
                ks = jax.random.split(key, m + 1)       # same chain as dispatch
                key, keys = ks[0], ks[1:]
                client = {k: v[sel] for k, v in arrays.items()}
                out = vm(params, client, arrays["features"], hist1,
                         hist1[sel], age[sel], ghost_feat[sel], prev_loss[sel],
                         tau, fanouts, eoff, keys)
                new_params, new_hist1, new_age, new_ghost_feat, stats = out
                params = agg.aggregate(new_params, sizes[sel])
                loss_wb = stats["loss_all"]
                if sync_dtype != "fp32":
                    new_hist1 = quant_roundtrip(new_hist1, sync_dtype)
                    new_ghost_feat = quant_roundtrip(new_ghost_feat,
                                                     sync_dtype)
                    loss_wb = quant_roundtrip(loss_wb, sync_dtype)
                hist1 = hist1.at[sel].set(new_hist1)
                age = age.at[sel].set(new_age)
                ghost_feat = ghost_feat.at[sel].set(new_ghost_feat)
                prev_loss = prev_loss.at[sel].set(loss_wb)
                light = {k: stats[k] for k in _LIGHT_STATS}
                return (params, hist1, age, ghost_feat, prev_loss, key), light

            return jax.lax.scan(round_step,
                                (params, hist1, age, ghost_feat, prev_loss, key),
                                (sel_stack, fan_stack, eoffs))

        return jax.jit(chunk, donate_argnums=(0, 1, 2, 3, 4, 5))

    def _call_sharded_chunk(self, state: EngineState, sels, fans, eoffs,
                            drop_stack=None):
        """Run one chunk through the shard-mapped executor
        (repro.sharding.fed.build_sharded_chunk): pad ragged cohorts with
        zero-weight dummy clients, derive per-client aggregation weights
        from the aggregator's semantics (client sizes for WeightedFedAvg,
        uniform for FedAvg), and hand the donated buffers — committed to
        the mesh fully replicated — to the scanned sharded round_step.
        ``drop_stack`` (FaultPlan dropout) turns dropped members into
        zero-weight out-of-range dummies: the same mechanics as ragged
        padding, so their merge weight and write-back vanish exactly."""
        mesh, axis = self.mesh, self.client_axis
        m = len(sels[0])
        if self._sharded_chunk is None or self._sharded_chunk_m != m:
            self._sharded_chunk = build_sharded_chunk(
                self._vm_raw, mesh, axis, m, _LIGHT_STATS,
                reduce=self.merge_reduce, sync_dtype=self.sync_dtype)
            self._sharded_chunk_m = m
        pad = cohort_padding(m, mesh.shape[axis])
        sel_stack = np.stack(sels).astype(np.int32)
        fan_stack = np.stack([np.asarray(f) for f in fans])
        w_stack = self._cohort_weights(sel_stack)
        if drop_stack is not None and drop_stack.any():
            w_stack[drop_stack] = 0.0
            sel_stack[drop_stack] = self.fed.n_clients
        if pad:
            # out-of-range id: gathers clamp (dummy trains on real data,
            # harmlessly), scatters drop (its write-back never lands);
            # weight 0 keeps it out of the aggregation all-reduce
            sel_stack = np.pad(sel_stack, ((0, 0), (0, pad)),
                               constant_values=self.fed.n_clients)
            fan_stack = np.pad(fan_stack, ((0, 0), (0, pad)), mode="edge")
            w_stack = np.pad(w_stack, ((0, 0), (0, pad)))
        (state.params, hist1, age, state.ghost_feat, state.prev_loss,
         state.key, state.arrays) = replicate_to_mesh(
            (state.params, state.hist.hist1, state.hist.age, state.ghost_feat,
             state.prev_loss, state.key, state.arrays), mesh)
        return self._sharded_chunk(
            state.params, hist1, age, state.ghost_feat, state.prev_loss,
            state.key, state.arrays, jnp.asarray(sel_stack),
            jnp.asarray(fan_stack), jnp.asarray(w_stack), jnp.asarray(eoffs),
            jnp.asarray(state.tau, jnp.int32))

    def _cohort_weights(self, sel_stack: np.ndarray) -> np.ndarray:
        """Per-client aggregation weights for the sharded merges: client
        sizes when the aggregator folds them in (WeightedFedAvg), uniform
        otherwise (FedAvg)."""
        if getattr(self.aggregator, "uses_weights", False):
            return self.fed.client_sizes[sel_stack].astype(np.float32)
        return np.ones(sel_stack.shape, np.float32)

    def _pod_static_arrays(self, buckets, n_pods: int):
        """The pod-sharded STATIC residents, built once per engine (per pod
        split): the client arrays the prefetched LocalUpdate reads
        (``POD_ARRAY_KEYS`` — ghost_owner/ghost_row stay off the mesh)
        padded to the pod grid and committed as ``P("pods")`` shards, plus
        the (Kp, g_max, F) ghost-source feature table from the bucketed
        owner exchange. Never written back — reused across chunks, so the
        per-device resident cost is K/P rows for the life of the run."""
        if self._pod_static is None:
            statics = pad_tables_to_pods(
                {k: jnp.asarray(getattr(self.fed, k))
                 for k in POD_ARRAY_KEYS}, n_pods)
            gsrc = jnp.asarray(
                exchange_ghost_features(buckets, self.fed.features,
                                        dtype=self.sync_dtype))
            self._pod_static = shard_tables_to_mesh((statics, gsrc),
                                                    self.mesh)
        return self._pod_static

    def _call_pod_chunk(self, state: EngineState, sels, fans, eoffs,
                        drop_stack=None):
        """Run one chunk with every K-sized array sharded over the pod axis
        (repro.sharding.tables.build_pod_sharded_chunk): pad the K axis to
        the pod grid, commit the four tables + static arrays as pod shards,
        pad ragged cohorts with dummy clients whose id has no owner pod
        (fetches zero, write-backs drop), route the cohort-keyed write-back
        and the tau-sync gates on the host, and slice the tables back to K
        rows after."""
        mesh = self.mesh
        n_pods = mesh.shape[self.pod_axes[0]]
        n_dev = mesh.devices.size
        if self._ghost_buckets is None or self._ghost_buckets.n_pods != n_pods:
            self._ghost_buckets = ghost_exchange_buckets(
                self.fed.ghost_owner, self.fed.ghost_row,
                self.fed.ghost_mask, n_pods)
            self._pod_static = None         # re-shard for the new pod split
        buckets = self._ghost_buckets
        m = len(sels[0])
        if self._pod_chunk is None or self._pod_chunk_m != m:
            vm = make_vmapped_update(self.mcfg, self.fed.n_max,
                                     self.fed.g_max, self.H1,
                                     ghost_source="prefetched",
                                     sync_dtype=self.sync_dtype,
                                     train_backend=self.train_backend)
            self._pod_chunk = build_pod_sharded_chunk(
                vm, mesh, m, buckets, _LIGHT_STATS,
                reduce=self.merge_reduce, sync_dtype=self.sync_dtype)
            self._pod_chunk_m = m
        pad = cohort_padding(m, n_dev)
        sel_stack = np.stack(sels).astype(np.int32)
        fan_stack = np.stack([np.asarray(f) for f in fans])
        w_stack = self._cohort_weights(sel_stack)
        if drop_stack is not None and drop_stack.any():
            # dropped members become ownerless dummies (same id as ragged
            # padding): fetch zero rows, zero merge weight, no write-back
            w_stack[drop_stack] = 0.0
            sel_stack[drop_stack] = buckets.n_clients_padded
        if pad:
            sel_stack = np.pad(sel_stack, ((0, 0), (0, pad)),
                               constant_values=buckets.n_clients_padded)
            fan_stack = np.pad(fan_stack, ((0, 0), (0, pad)), mode="edge")
            w_stack = np.pad(w_stack, ((0, 0), (0, pad)))
        plan = writeback_routing(sel_stack, n_pods, n_dev // n_pods,
                                 buckets.rows_per_pod)
        gates = sync_round_gates(
            eoffs, state.tau, self.mcfg.local_epochs,
            enabled=self.mcfg.use_ghosts and not self.mcfg.use_generator)
        arrays_sh, gsrc_sh = self._pod_static_arrays(buckets, n_pods)
        K = self.fed.n_clients
        tables = pad_tables_to_pods(
            (state.hist.hist1, state.hist.age, state.ghost_feat,
             state.prev_loss), n_pods)
        hist1, age, ghost_feat, prev_loss = shard_tables_to_mesh(tables, mesh)
        state.params, state.key = replicate_to_mesh(
            (state.params, state.key), mesh)
        carry, light = self._pod_chunk(
            state.params, hist1, age, ghost_feat, prev_loss, state.key,
            arrays_sh, gsrc_sh, jnp.asarray(sel_stack),
            jnp.asarray(fan_stack), jnp.asarray(w_stack), jnp.asarray(eoffs),
            jnp.asarray(state.tau, jnp.int32), jnp.asarray(gates),
            jnp.asarray(plan.dst), jnp.asarray(plan.pos),
            jnp.asarray(plan.recv))
        if buckets.n_clients_padded == K:
            # divisible K: the carried tables come back pod-sharded and feed
            # the next chunk's (no-op) pad + device_put directly — shards
            # stay resident on their pods across chunk boundaries
            return carry, light
        (params, hist1, age, ghost_feat, prev_loss, key) = carry
        # ragged K: drop the pod-padding rows again; state keeps the K-row
        # view every host-side consumer (selectors, eval, fallback) expects
        return ((params, hist1[:K], age[:K], ghost_feat[:K], prev_loss[:K],
                 key), light)

    def _call_faulty_chunk(self, state: EngineState, sels, fans, eoffs,
                           drop_stack, cmask_stack):
        """Run one chunk through the fault-aware fused executor
        (repro.faults.build_faulty_chunk): dropped members get weight 0,
        corrupted members get a poison multiplier, and the in-trace guard
        zeroes + counts non-finite/norm-exploded updates — reproducing the
        stepwise dispatch -> corrupt -> drop -> guarded-merge path inside
        one scanned XLA call."""
        if self._faulty_chunk is None:
            g = self._guard
            self._faulty_chunk = build_faulty_chunk(
                self._vm_raw, _LIGHT_STATS,
                uses_weights=getattr(self.aggregator, "uses_weights", False),
                finite_guard=g is not None,
                max_norm=None if g is None else g.max_norm,
                sync_dtype=self.sync_dtype)
        sel_stack = np.stack(sels).astype(np.int32)
        w_stack = self._cohort_weights(sel_stack)
        w_stack[drop_stack] = 0.0
        cmult_stack = np.ones(sel_stack.shape, np.float32)
        cmult_stack[cmask_stack] = self.faults.corrupt_value()
        return self._faulty_chunk(
            state.params, state.hist.hist1, state.hist.age, state.ghost_feat,
            state.prev_loss, state.key, state.arrays,
            jnp.asarray(sel_stack), jnp.stack(fans), jnp.asarray(w_stack),
            jnp.asarray(cmult_stack), jnp.asarray(eoffs),
            jnp.asarray(state.tau, jnp.int32))

    def _run_chunk(self, state: EngineState, t0: int, n_rounds: int) -> bool:
        """Select cohorts for rounds [t0, t0+n_rounds) on the host, run them
        as ONE donated scanned XLA call, then replay the host tail (cost
        accounting, post_round, callbacks) per round from the streamed
        stats. Returns True if a callback requested stop.

        Under an active FaultPlan, per-round dropout/corruption masks are
        drawn on the host for the whole chunk (the plan's (round, client)
        coordinates make them executor-independent) and threaded into the
        executor: the sharded paths absorb dropout through their
        zero-weight dummy mechanics, corruption routes to the fault-aware
        fused chunk (``fused_faulty``), and the replay tail mirrors the
        stepwise merge's billing — dropped members are billed nothing,
        stragglers stretch the round's wall clock, survivor-free rounds
        count as empty merges."""
        sels, fans = [], []
        for t in range(t0, t0 + n_rounds):
            state.round = t
            sel = np.asarray(self.selector.select(self, state))
            sels.append(sel)
            fans.append(self.strategy.choose_fanouts(self, sel))
        if any(len(s) != len(sels[0]) for s in sels):
            raise ValueError(
                "fused executor needs constant cohort sizes across a chunk; "
                "precomputable selectors must return fixed-size cohorts")
        eoffs = np.arange(t0, t0 + n_rounds, dtype=np.int32) * self.mcfg.local_epochs

        drop_stack = cmask_stack = None
        if self._faults_active:
            ts = range(t0, t0 + n_rounds)
            drop_stack = np.stack(
                [self.faults.drops(t, s) for t, s in zip(ts, sels)])
            cmask_stack = np.stack(
                [self.faults.corruptions(t, s) for t, s in zip(ts, sels)])
            state.fault_events.n_dropped += int(drop_stack.sum())

        if self.mesh is not None and self.pod_sharded_eligibility(len(sels[0]))[0]:
            self.last_executor = "pod_sharded"
            carry, light = self._call_pod_chunk(state, sels, fans, eoffs,
                                                drop_stack=drop_stack)
        elif self.mesh is not None and self.sharded_eligibility(len(sels[0]))[0]:
            self.last_executor = "sharded_fused"
            carry, light = self._call_sharded_chunk(state, sels, fans, eoffs,
                                                    drop_stack=drop_stack)
        elif self._faults_active:
            self.last_executor = "fused_faulty"
            carry, light = self._call_faulty_chunk(state, sels, fans, eoffs,
                                                   drop_stack, cmask_stack)
        else:
            self.last_executor = "fused"
            if self._fused_chunk is None:
                self._fused_chunk = self._build_fused_chunk()
            carry, light = self._fused_chunk(
                state.params, state.hist.hist1, state.hist.age, state.ghost_feat,
                state.prev_loss, state.key, state.arrays,
                jnp.asarray(np.stack(sels)), jnp.stack(fans), jnp.asarray(eoffs),
                jnp.asarray(state.tau, jnp.int32))
        (state.params, hist1, age, state.ghost_feat, state.prev_loss,
         state.key) = carry
        state.hist = state.hist._replace(hist1=hist1, age=age)

        light = jax.device_get(light)       # one host transfer per chunk
        n_quar_rounds = light.pop("n_quarantined", None)
        if n_quar_rounds is not None:
            state.fault_events.n_quarantined += int(np.sum(n_quar_rounds))
        for i, t in enumerate(range(t0, t0 + n_rounds)):
            state.round = t
            stats_t = {k: v[i] for k, v in light.items()}
            sel_t, stats_b, wall = sels[i], stats_t, None
            if self._faults_active:
                plan = self.faults
                if drop_stack is not None and drop_stack[i].any():
                    # dropped uploads never reach the server: bill survivors
                    keep = np.flatnonzero(~drop_stack[i])
                    sel_t = sels[i][keep]
                    stats_b = {k: v[keep] for k, v in stats_t.items()}
                if plan.straggler_frac > 0.0:
                    # same formula as the stepwise _inject_faults billing:
                    # the lockstep server waits for every dispatched member
                    # (stragglers included; compute times are stats-free in
                    # PaperCostModel, so the sharded executor's dummy rows
                    # for dropped members don't leak in), while the merge
                    # overhead o prices only the survivor uploads
                    times = np.asarray(self.cost_model.client_compute_times(
                        self, state, sels[i], stats_t), np.float64)
                    times = times * plan.delay_factors(sels[i])
                    o = self.cost_model.sync_overhead(self, sel_t, stats_b)
                    wall = float(np.max(times)) + o / max(state.tau, 1)
                n_quar_t = (0 if n_quar_rounds is None
                            else int(n_quar_rounds[i]))
                if len(sel_t) - n_quar_t <= 0:
                    state.fault_events.n_empty_merges += 1
            if len(sel_t):
                cost = self.cost_model.round_cost(self, state, sel_t, stats_b)
            else:
                cost = CostMeter()
            if wall is not None:
                cost.wall_clock_s = wall
            state.result.costs.add(cost)
            if len(sel_t):
                self.strategy.post_round(self, state, sel_t, stats_b)
            ctx = RoundContext(engine=self, state=state, t=t, rounds=self.rounds)
            for cb in self.callbacks:
                cb.on_round_end(ctx)
            if ctx.stop:
                return True
        return False

    def run_fused(self, state: EngineState) -> None:
        """Run all rounds through the scanned executor, chunked at eval
        boundaries so the EvalCallback cadence (server eval + tau update +
        early stop) observes exactly the rounds the stepwise loop would."""
        eval_every = next((cb.eval_every for cb in self.callbacks
                           if isinstance(cb, EvalCallback)), None)
        t = 0
        while t < self.rounds:
            if eval_every is None:          # no eval: one chunk for the run
                t_end = self.rounds - 1
            else:                           # chunk ends at the next eval round
                nxt = t if t % eval_every == 0 else (t // eval_every + 1) * eval_every
                t_end = min(nxt, self.rounds - 1)
            if self._run_chunk(state, t, t_end - t + 1):
                return
            t = t_end + 1

    def run(self, state: EngineState | None = None) -> RunResult:
        if state is None:
            state = self.init_state()
        for cb in self.callbacks:
            cb.on_run_start(self, state)
        self.scheduler.run(self, state)
        if state.last_eval is not None and state.last_eval[0] == state.round:
            # EvalCallback already scored this round's (unchanged) params;
            # don't pay for the same server eval twice
            final_eval = state.last_eval[1]
        else:
            final_eval = evaluate_global(state.params, self.eval_graph, "test")
        state.result.final = dict(final_eval, **state.result.costs.snapshot())
        for cb in self.callbacks:
            cb.on_run_end(self, state)
        return state.result
