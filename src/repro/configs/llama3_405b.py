"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256, 128k vocab GQA. [arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3-405b",
        family="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        source="arXiv:2407.21783",
        block_pattern=("attn",),
        activation="silu",
        gated_mlp=True,
        rope_theta=500_000.0,
        max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("llama3-405b", config, smoke)
