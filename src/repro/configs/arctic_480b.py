"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual path.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.base import ModelConfig, register, smoke_variant


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,          # 56 not divisible by 16-way model axis: feature-axis sharding (DESIGN.md §6.5)
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        source="hf:Snowflake/snowflake-arctic-base",
        block_pattern=("attn",),
        n_experts=128,
        top_k=2,
        capacity_factor=1.25,
        moe_dense_residual=True,
        dense_ff_dim=4864,
        activation="silu",
        gated_mlp=True,
        rope_theta=10_000.0,
        max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return smoke_variant(config())


register("arctic-480b", config, smoke)
