"""Block-sparse SpMM Pallas kernel: Y = A @ X with block skipping.

This is the FedGCN neighbor-aggregation hot spot adapted to TPU
(DESIGN.md §4): instead of PyG's irregular row gather/scatter, the
(normalised) adjacency is viewed as a grid of (bn x bm) dense tiles; tiles
that contain no edges are skipped via a host-computed block mask, and live
tiles run as dense MXU matmuls with all operands resident in VMEM.

Grid: (n_row_blocks, n_col_blocks, n_contract_blocks) — the contraction
dimension is innermost so the fp32 accumulator scratch is revisited.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(mask_ref, a_ref, x_ref, y_ref, acc_ref, *, n_contract: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[0, 0] != 0)
    def _accumulate():
        a = a_ref[...].astype(jnp.float32)
        x = x_ref[...].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            a, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(mi == n_contract - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "block_d", "interpret")
)
def spmm_pallas(
    a: jnp.ndarray,        # (N, M) adjacency tile source (already padded)
    x: jnp.ndarray,        # (M, D) features (already padded)
    block_mask: jnp.ndarray,  # (N/bn, M/bm) int32 — 1 where the A tile has edges
    *,
    block_n: int = 128,
    block_m: int = 128,
    block_d: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    N, M = a.shape
    D = x.shape[1]
    grid = (N // block_n, D // block_d, M // block_m)
    kernel = functools.partial(_spmm_kernel, n_contract=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda ni, di, mi: (ni, mi)),              # block mask
            pl.BlockSpec((block_n, block_m), lambda ni, di, mi: (ni, mi)),  # A tile
            pl.BlockSpec((block_m, block_d), lambda ni, di, mi: (mi, di)),  # X tile
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda ni, di, mi: (ni, di)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, block_d), jnp.float32)],
        interpret=interpret,
    )(block_mask, a, x)
