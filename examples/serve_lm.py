"""Batched LM serving: prefill + KV-cache decode (the serve_step the decode
dry-run shapes lower), on the reduced config of any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch mini
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b   # O(1)-state decode
"""
import argparse

from repro.configs import list_archs
from repro.launch.serve_lm_cli import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mini", choices=["mini", *list_archs()])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    args.seed = 0
    serve(args)


if __name__ == "__main__":
    main()
