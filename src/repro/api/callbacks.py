"""The default RoundCallback stack: server eval + adaptive tau, history
recording, verbose logging, early stop — the tail of the legacy round loop
split into composable pieces.

Callbacks run in list order after each round's merge + cost accounting; a
callback that sets ``ctx.stop = True`` ends the run after the round.
EvalCallback must precede the callbacks that consume ``ctx.metrics``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.federated.server import evaluate_global

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.engine import EngineState, FedEngine


@dataclass
class RoundContext:
    """What a callback sees at a round boundary."""

    engine: "FedEngine"
    state: "EngineState"
    t: int                          # round index
    rounds: int                     # total planned rounds
    metrics: Optional[dict] = None  # server eval (set by EvalCallback)
    stop: bool = False              # set True to end the run
    # async-scheduler extras (None under the lockstep SyncScheduler):
    virtual_time: Optional[float] = None       # server virtual clock at merge
    staleness: Optional[np.ndarray] = None     # per-merged-update staleness τ


class BaseCallback:
    """No-op base; subclass and override what you need."""

    def on_run_start(self, engine, state):
        pass

    def on_round_end(self, ctx: RoundContext):
        pass

    def on_run_end(self, engine, state):
        pass


class EvalCallback(BaseCallback):
    """Server-side test eval every ``eval_every`` rounds (and on the last
    round), followed by the SyncController tau update (Algorithm 1 line 8)."""

    def __init__(self, eval_every: int = 1):
        self.eval_every = eval_every

    def on_round_end(self, ctx):
        if ctx.t % self.eval_every == 0 or ctx.t == ctx.rounds - 1:
            st, eng = ctx.state, ctx.engine
            ev = evaluate_global(st.params, eng.eval_graph, "test")
            if st.initial_loss is None:
                st.initial_loss = max(ev["loss"], 1e-6)
            st.tau = eng.sync.update(eng.mcfg, ev["loss"], st.initial_loss)
            ctx.metrics = ev
            st.last_eval = (ctx.t, ev)   # lets FedEngine.run skip a re-eval


class HistoryCallback(BaseCallback):
    """Append the per-round (acc, loss, tau, cumulative cost) history rows;
    under an async scheduler also the virtual-clock/staleness columns."""

    def on_round_end(self, ctx):
        if ctx.metrics is None:
            return
        st, ev = ctx.state, ctx.metrics
        st.result.record(
            round=ctx.t, test_acc=ev["acc"], test_loss=ev["loss"], f1=ev["f1"],
            auc=ev["auc"], tau=st.tau,
            comm_total=st.result.costs.comm_total_bytes,
            comm_embed=st.result.costs.comm_embed_bytes,
            flops=st.result.costs.compute_flops,
            wall_clock=st.result.costs.wall_clock_s,
        )
        if ctx.staleness is not None:
            st.result.record(
                virtual_time=ctx.virtual_time,
                staleness_mean=float(np.mean(ctx.staleness)),
                staleness_max=int(np.max(ctx.staleness)),
                merged=len(ctx.staleness),
            )


class VerboseCallback(BaseCallback):
    """Legacy ``verbose=True`` one-liner per evaluated round."""

    def on_round_end(self, ctx):
        if ctx.metrics is None:
            return
        st, ev = ctx.state, ctx.metrics
        print(f"[{ctx.engine.mcfg.name}] round {ctx.t:3d} acc={ev['acc']:.4f} "
              f"loss={ev['loss']:.4f} tau={st.tau} "
              f"comm={st.result.costs.comm_total_bytes/1e6:.1f}MB")


class EarlyStopCallback(BaseCallback):
    """Stop once test accuracy first reaches ``target_acc``."""

    def __init__(self, target_acc: float):
        self.target_acc = target_acc

    def on_round_end(self, ctx):
        if ctx.metrics is not None and ctx.metrics["acc"] >= self.target_acc:
            ctx.stop = True


def default_callbacks(*, eval_every: int = 1, verbose: bool = False,
                      target_acc: float | None = None) -> list:
    """The stack reproducing the legacy loop's eval/record/print/stop tail."""
    cbs: list = [EvalCallback(eval_every), HistoryCallback()]
    if verbose:
        cbs.append(VerboseCallback())
    if target_acc is not None:
        cbs.append(EarlyStopCallback(target_acc))
    return cbs
