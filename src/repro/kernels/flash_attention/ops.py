"""Public wrapper: (B, S, H, hd) layout, padding, GQA head mapping.

``interpret=None`` auto-detects (compiled on TPU, interpreter elsewhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, S, H, hd)
    k: jnp.ndarray,   # (B, S, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))

    pad_q = (-S) % bq
    pad_k = (-S) % bk
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_pallas(
        qr, kr, vr,
        n_q_heads=H, seq_len=S, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out
