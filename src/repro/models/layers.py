"""Common neural building blocks (pure JAX, params = plain dicts).

Sharding hooks: ``shard_activation(x, *logical_axes)`` applies a
``with_sharding_constraint`` when a logical->mesh rule set is installed via
``activation_sharding_ctx`` (used by launch/), and is a no-op otherwise so all
CPU tests run unannotated.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding_ctx(rules: dict[str, object] | None):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = prev


def shard_activation(x, *logical_axes):
    rules = getattr(_TLS, "rules", None)
    if not rules:
        return x
    spec = P(*(rules.get(a) for a in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def groupnorm(x: jnp.ndarray, n_groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """Per-head group norm used by RWKV time-mix output; no learned affine."""
    *lead, d = x.shape
    g = x.reshape(*lead, n_groups, d // n_groups).astype(jnp.float32)
    mean = g.mean(axis=-1, keepdims=True)
    var = g.var(axis=-1, keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    return g.reshape(*lead, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "sqrelu":  # nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_in": dense_init(k1, d, ff, dtype), "w_out": dense_init(k2, ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d, ff, dtype)
    return p


def mlp_apply(params: dict, x: jnp.ndarray, act_name: str) -> jnp.ndarray:
    act = activation_fn(act_name)
    h = x @ params["w_in"]
    if "w_gate" in params:
        h = act(x @ params["w_gate"]) * h
    else:
        h = act(h)
    h = shard_activation(h, "batch", "seq", "ff")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, mask=None):
    """Next-token cross entropy. logits (..., V) fp-any; labels (...) int."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
